"""Pure-jnp / numpy oracles for the Pallas kernels and the padded solve.

These are the CORE correctness signal: every kernel and the full scan model
are asserted allclose against these references in python/tests/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def level_solve_ref(x, vals, cols, b_lvl, inv_diag):
    """Reference for kernels.level_solve: one padded level, pure jnp."""
    gathered = x[cols]                          # (R, K)
    partial = jnp.sum(vals * gathered, axis=1)  # (R,)
    return (b_lvl - partial) * inv_diag


def level_step_ref(x, rows, vals, cols, b_ext, inv_diag):
    """Reference for kernels.level_step."""
    x_lvl = level_solve_ref(x, vals, cols, b_ext[rows], inv_diag)
    return x.at[rows].set(x_lvl)


def solve_padded_ref(rows, vals, cols, inv_diag, b):
    """Reference full solve over padded levels, pure jnp scan.

    rows (L,R) i32, vals/cols (L,R,K), inv_diag (L,R), b (N,) -> x (N,)
    Padded rows index the dummy slot N.
    """
    n = b.shape[0]
    b_ext = jnp.concatenate([b, jnp.zeros((1,), b.dtype)])
    x0 = jnp.zeros((n + 1,), b.dtype)

    def body(x, lvl):
        r, v, c, d = lvl
        x = level_step_ref(x, r, v, c, b_ext, d)
        return x, None

    x, _ = jax.lax.scan(body, x0, (rows, vals, cols, inv_diag))
    return x[:n]


def sptrsv_csr_ref(indptr, indices, data, b):
    """Serial CSR forward substitution (Algorithm 1 of the paper), numpy.

    The ground-truth solver for building test cases: no padding, no levels.
    Assumes each row's last stored nonzero is the diagonal (sorted CSR of a
    lower-triangular matrix with full diagonal).
    """
    n = len(indptr) - 1
    x = np.zeros(n, dtype=np.float64)
    for i in range(n):
        s = 0.0
        lo, hi = indptr[i], indptr[i + 1]
        for j in range(lo, hi - 1):
            s += data[j] * x[indices[j]]
        x[i] = (b[i] - s) / data[hi - 1]
    return x


def build_padded_levels(indptr, indices, data, levels, pad_r, pad_k, pad_l=None):
    """Build the padded-level representation from CSR + a level partition.

    Mirrors what the Rust preprocessing pipeline produces; used by tests to
    cross-check the python model against the serial reference.

    levels: list of lists of row ids (topological level sets).
    Returns dict of numpy arrays: rows (L,R), vals (L,R,K), cols (L,R,K),
    inv_diag (L,R).
    """
    n = len(indptr) - 1
    nlev = len(levels) if pad_l is None else pad_l
    if pad_l is not None and len(levels) > pad_l:
        raise ValueError(f"{len(levels)} levels exceed pad_l={pad_l}")
    rows = np.full((nlev, pad_r), n, dtype=np.int32)
    vals = np.zeros((nlev, pad_r, pad_k), dtype=np.float64)
    cols = np.zeros((nlev, pad_r, pad_k), dtype=np.int32)
    inv_diag = np.zeros((nlev, pad_r), dtype=np.float64)
    for li, lev in enumerate(levels):
        if len(lev) > pad_r:
            raise ValueError(f"level {li} has {len(lev)} rows > pad_r={pad_r}")
        for ri, i in enumerate(lev):
            lo, hi = indptr[i], indptr[i + 1]
            ndep = hi - 1 - lo
            if ndep > pad_k:
                raise ValueError(f"row {i} has {ndep} deps > pad_k={pad_k}")
            rows[li, ri] = i
            vals[li, ri, :ndep] = data[lo : hi - 1]
            cols[li, ri, :ndep] = indices[lo : hi - 1]
            inv_diag[li, ri] = 1.0 / data[hi - 1]
    return {"rows": rows, "vals": vals, "cols": cols, "inv_diag": inv_diag}


def random_lower_csr(rng, n, max_deps=3, density=0.7):
    """Random well-conditioned lower-triangular CSR for tests."""
    indptr = [0]
    indices = []
    data = []
    for i in range(n):
        ndep = 0
        if i > 0 and rng.random() < density:
            ndep = int(rng.integers(1, min(max_deps, i) + 1))
        deps = sorted(rng.choice(i, size=ndep, replace=False)) if ndep else []
        for j in deps:
            indices.append(int(j))
            data.append(float(rng.uniform(-1.0, 1.0)))
        # dominant diagonal keeps the solve well-conditioned
        indices.append(i)
        data.append(float(rng.uniform(1.0, 2.0) * (1 + ndep)))
        indptr.append(len(indices))
    return (
        np.asarray(indptr, dtype=np.int64),
        np.asarray(indices, dtype=np.int32),
        np.asarray(data, dtype=np.float64),
    )


def level_sets(indptr, indices):
    """Anderson–Saad level-set construction (reference implementation)."""
    n = len(indptr) - 1
    lvl = np.zeros(n, dtype=np.int64)
    for i in range(n):
        m = 0
        for j in range(indptr[i], indptr[i + 1] - 1):
            m = max(m, lvl[indices[j]] + 1)
        lvl[i] = m
    out = [[] for _ in range(int(lvl.max()) + 1 if n else 0)]
    for i in range(n):
        out[int(lvl[i])].append(i)
    return out
