"""L1 — Pallas kernel: solve one level-set level of SpTRSV on a padded block.

The level-set method computes, for every row ``i`` in a level,

    x[i] = (b[i] - sum_j L[i][j] * x[j]) / L[i][i]        (j < i, j solved)

Rows within a level are independent, so a level is a pure data-parallel
gather + fused-multiply-accumulate + scale. The coordinator (Rust, L3) owns
the level loop and the barriers; this kernel is the per-level hot spot.

Padded representation (built by the Rust preprocessing pipeline):
  vals     (R, K) f64 — off-diagonal coefficients, 0.0 on padding slots
  cols     (R, K) i32 — column index of each coefficient, 0 on padding
                        (harmless: the matching ``vals`` entry is 0)
  b_lvl    (R,)   f64 — right-hand side gathered for the level's rows,
                        0.0 on padded rows
  inv_diag (R,)   f64 — 1 / L[i][i] per row, 0.0 on padded rows
  x        (N1,)  f64 — current solution vector (N real slots + 1 dummy
                        slot at index N that padded rows scatter into)

Output:
  x_lvl    (R,)   f64 — solved values for the level's rows (garbage 0.0 on
                        padding, which the caller scatters into the dummy)

TPU adaptation (DESIGN.md §Hardware-Adaptation): a level is memory-bound
gather+FMA, not a matmul — it targets the VPU, not the MXU. BlockSpec tiles
the R rows into VMEM-resident blocks of ``block_r`` rows while the gather
source ``x`` stays in ANY/HBM memory space; K is kept whole per block (K is
small: the padded indegree). On CPU we run interpret=True (the CPU PJRT
plugin cannot execute Mosaic custom-calls); the structure is what carries
to real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128


def _level_kernel(x_ref, vals_ref, cols_ref, b_ref, inv_diag_ref, o_ref):
    """One grid step: solve ``block_r`` rows of the level.

    x_ref is the full solution vector (not blocked): the gather indices are
    data-dependent, so every block may touch any prefix of x.
    """
    vals = vals_ref[...]                      # (block_r, K)
    cols = cols_ref[...]                      # (block_r, K)
    gathered = x_ref[cols]                    # (block_r, K) gather from x
    partial = jnp.sum(vals * gathered, axis=1)  # (block_r,)
    o_ref[...] = (b_ref[...] - partial) * inv_diag_ref[...]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def level_solve(
    x: jax.Array,
    vals: jax.Array,
    cols: jax.Array,
    b_lvl: jax.Array,
    inv_diag: jax.Array,
    *,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = True,
) -> jax.Array:
    """Solve one padded level; returns x_lvl of shape (R,).

    R must be a multiple of ``block_r`` (the Rust side pads to the shape
    registry's block shapes, so this holds by construction).
    """
    r, k = vals.shape
    if r % block_r:
        raise ValueError(f"R={r} not a multiple of block_r={block_r}")
    grid = (r // block_r,)
    return pl.pallas_call(
        _level_kernel,
        grid=grid,
        in_specs=[
            # x: full vector visible to every block (gather is data-dependent).
            pl.BlockSpec(x.shape, lambda i: (0,)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), x.dtype),
        interpret=interpret,
    )(x, vals, cols, b_lvl, inv_diag)


def level_step(
    x: jax.Array,
    rows: jax.Array,
    vals: jax.Array,
    cols: jax.Array,
    b_ext: jax.Array,
    inv_diag: jax.Array,
    *,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = True,
) -> jax.Array:
    """Solve one level and scatter the result back into x.

    rows  (R,) i32 — row index per slot, N (the dummy) on padding
    b_ext (N1,) f64 — b with the dummy slot appended
    Returns the updated x (N1,).
    """
    b_lvl = b_ext[rows]
    x_lvl = level_solve(
        x, vals, cols, b_lvl, inv_diag, block_r=block_r, interpret=interpret
    )
    return x.at[rows].set(x_lvl)
