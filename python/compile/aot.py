"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.
(See /opt/xla-example/load_hlo/ and gen_hlo.py there.)

Exports a registry grid of statically-shaped executables plus a
``manifest.json`` the Rust runtime uses to pick the smallest fitting shape:

  step_*   — one level (Rust owns the level loop / barriers)
  solve_*  — full solve as a scan over levels
  batch_*  — full solve over B right-hand sides
  resid_*  — ||Lx - b||_inf validation graph

Run once by ``make artifacts``; never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402

F64 = jnp.float64
I32 = jnp.int32


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Shape registry. Small grid: artifacts must cover (a) the fat-level shapes a
# transformed matrix produces, (b) the thin-chain shapes of untransformed
# graphs, (c) a batched-RHS variant for the coordinator's batcher. The Rust
# runtime falls back to the native solver when nothing fits.
# ---------------------------------------------------------------------------

STEP_SHAPES = [  # (R, K, N)
    (8, 2, 8192),
    (128, 4, 8192),
    (4096, 4, 8192),
]
SOLVE_SHAPES = [  # (L, R, K, N)
    # Transformed systems: few levels, very wide fat levels.
    (4, 2560, 2, 4096),
    (16, 4096, 4, 8192),
    (16, 4096, 4, 16384),
    (64, 512, 4, 8192),
    # Untransformed thin chains (e.g. tridiagonal, lung2 tail).
    (512, 8, 2, 8192),
]
BATCH_SHAPES = [  # (B, L, R, K, N)
    (8, 4, 2560, 2, 4096),
    (8, 16, 4096, 4, 8192),
]


def lower_step(r, k, n):
    fn = lambda x, rows, vals, cols, b_ext, inv_diag: model.level_step_fn(
        x, rows, vals, cols, b_ext, inv_diag
    )
    return jax.jit(fn).lower(
        spec((n + 1,), F64),      # x
        spec((r,), I32),          # rows
        spec((r, k), F64),        # vals
        spec((r, k), I32),        # cols
        spec((n + 1,), F64),      # b_ext
        spec((r,), F64),          # inv_diag
    )


def lower_solve(l, r, k, n):
    fn = lambda rows, vals, cols, inv_diag, b: model.solve_fn(
        rows, vals, cols, inv_diag, b
    )
    return jax.jit(fn).lower(
        spec((l, r), I32),
        spec((l, r, k), F64),
        spec((l, r, k), I32),
        spec((l, r), F64),
        spec((n,), F64),
    )


def lower_batch(bsz, l, r, k, n):
    fn = lambda rows, vals, cols, inv_diag, b: model.solve_batched_fn(
        rows, vals, cols, inv_diag, b
    )
    return jax.jit(fn).lower(
        spec((l, r), I32),
        spec((l, r, k), F64),
        spec((l, r, k), I32),
        spec((l, r), F64),
        spec((bsz, n), F64),
    )


def lower_resid(l, r, k, n):
    fn = lambda rows, vals, cols, inv_diag, b, x: model.residual_fn(
        rows, vals, cols, inv_diag, b, x
    )
    return jax.jit(fn).lower(
        spec((l, r), I32),
        spec((l, r, k), F64),
        spec((l, r, k), I32),
        spec((l, r), F64),
        spec((n,), F64),
        spec((n,), F64),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="primary artifact path (Makefile stamp); its "
                         "directory receives the whole registry")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest = []

    def emit(name, lowered, **meta):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        manifest.append({"name": name, "file": fname, **meta})
        print(f"  {fname}: {len(text)} chars")

    for r, k, n in STEP_SHAPES:
        emit(f"step_r{r}_k{k}_n{n}", lower_step(r, k, n),
             entry="level_step", r=r, k=k, n=n)

    for l, r, k, n in SOLVE_SHAPES:
        emit(f"solve_l{l}_r{r}_k{k}_n{n}", lower_solve(l, r, k, n),
             entry="solve", l=l, r=r, k=k, n=n)

    for bsz, l, r, k, n in BATCH_SHAPES:
        emit(f"batch_b{bsz}_l{l}_r{r}_k{k}_n{n}", lower_batch(bsz, l, r, k, n),
             entry="solve_batched", b=bsz, l=l, r=r, k=k, n=n)

    l, r, k, n = SOLVE_SHAPES[0]
    emit(f"resid_l{l}_r{r}_k{k}_n{n}", lower_resid(l, r, k, n),
         entry="residual", l=l, r=r, k=k, n=n)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Makefile stamp: --out names the primary artifact; make it the first
    # solve executable so `make artifacts` dependency tracking works.
    primary = os.path.join(outdir, f"solve_l{l}_r{r}_k{k}_n{n}.hlo.txt")
    if os.path.abspath(args.out) != primary:
        with open(primary) as src, open(args.out, "w") as dst:
            dst.write(src.read())
    print(f"wrote {len(manifest)} artifacts + manifest.json to {outdir}")


if __name__ == "__main__":
    main()
