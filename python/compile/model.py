"""L2 — JAX compute graph: padded-level-set SpTRSV built on the L1 kernel.

Three entry points, each AOT-lowered to HLO text by ``aot.py`` and executed
from the Rust runtime (Python is never on the request path):

  * ``level_step_fn``   — one level: gather + kernel + scatter. The Rust
                          coordinator owns the level loop and barriers (that
                          IS the level-set method) and calls this once per
                          level.
  * ``solve_fn``        — the whole solve as ``lax.scan`` over padded
                          levels, for matrices that fit a registry shape.
  * ``solve_batched_fn``— same, with B right-hand sides solved at once
                          (what the coordinator's RHS batcher feeds).

All shapes are static per artifact; the shape registry in aot.py exports a
small grid of (L, R, K, N[, B]) configurations and the Rust side pads its
transformed level structure to the smallest fitting one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.level_solve import level_solve

jax.config.update("jax_enable_x64", True)


def level_step_fn(x, rows, vals, cols, b_ext, inv_diag, *, block_r=None):
    """One level of the solve: returns updated x (shape (N+1,)).

    x     (N+1,) f64   rows (R,) i32   vals/cols (R,K)   b_ext (N+1,) f64
    inv_diag (R,) f64. Padded rows index the dummy slot N.
    """
    r = rows.shape[0]
    block_r = block_r or min(r, 128)
    b_lvl = b_ext[rows]
    x_lvl = level_solve(x, vals, cols, b_lvl, inv_diag, block_r=block_r)
    return (x.at[rows].set(x_lvl),)


def solve_fn(rows, vals, cols, inv_diag, b, *, block_r=None):
    """Full SpTRSV as a scan over padded levels.

    rows (L,R) i32, vals/cols (L,R,K) f64/i32, inv_diag (L,R) f64, b (N,).
    Returns (x,) with x (N,) f64.
    """
    n = b.shape[0]
    r = rows.shape[1]
    block = block_r or min(r, 128)
    b_ext = jnp.concatenate([b, jnp.zeros((1,), b.dtype)])
    x0 = jnp.zeros((n + 1,), b.dtype)

    def body(x, lvl):
        rw, v, c, d = lvl
        b_lvl = b_ext[rw]
        x_lvl = level_solve(x, v, c, b_lvl, d, block_r=block)
        return x.at[rw].set(x_lvl), None

    x, _ = jax.lax.scan(body, x0, (rows, vals, cols, inv_diag))
    return (x[:n],)


def solve_batched_fn(rows, vals, cols, inv_diag, b, *, block_r=None):
    """Batched-RHS SpTRSV: b (B, N) -> x (B, N).

    The level structure is shared across the batch, so the solve is vmapped
    over the RHS axis only — the gather indices are broadcast.
    """
    solve = lambda b1: solve_fn(rows, vals, cols, inv_diag, b1, block_r=block_r)[0]
    return (jax.vmap(solve)(b),)


def residual_fn(rows, vals, cols, inv_diag, b, x):
    """||Lx - b||_inf over the padded representation (validation graph).

    Computes, per real row, diag*x[i] + sum vals*x[cols] - b[i]; padded rows
    (marked by inv_diag == 0) contribute 0.
    """
    x_ext = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
    b_ext = jnp.concatenate([b, jnp.zeros((1,), b.dtype)])
    gathered = x_ext[cols]                                # (L,R,K)
    partial = jnp.sum(vals * gathered, axis=2)            # (L,R)
    real = inv_diag != 0.0
    diag = jnp.where(real, 1.0 / jnp.where(real, inv_diag, 1.0), 0.0)
    lhs = diag * x_ext[rows] + partial                    # (L,R)
    err = jnp.where(real, lhs - b_ext[rows], 0.0)
    return (jnp.max(jnp.abs(err)),)
