"""L1 correctness: the Pallas level kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: every shape,
dtype and padding configuration the runtime can feed the kernel is swept
here (directed cases + hypothesis).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.level_solve import level_solve, level_step
from compile.kernels import ref


def make_case(rng, n, r, k, dtype=np.float64):
    x = jnp.asarray(rng.normal(size=n + 1), dtype=dtype)
    x = x.at[n].set(0.0)
    vals = jnp.asarray(rng.normal(size=(r, k)), dtype=dtype)
    cols = jnp.asarray(rng.integers(0, n, size=(r, k)), dtype=jnp.int32)
    b = jnp.asarray(rng.normal(size=r), dtype=dtype)
    inv_d = jnp.asarray(rng.uniform(0.5, 2.0, size=r), dtype=dtype)
    return x, vals, cols, b, inv_d


@pytest.mark.parametrize("r,k,block_r", [
    (8, 2, 8),
    (128, 4, 128),
    (256, 8, 128),
    (64, 1, 8),
    (16, 16, 16),
])
def test_kernel_matches_ref(r, k, block_r):
    rng = np.random.default_rng(r * 1000 + k)
    x, vals, cols, b, inv_d = make_case(rng, 300, r, k)
    out = level_solve(x, vals, cols, b, inv_d, block_r=block_r)
    expect = ref.level_solve_ref(x, vals, cols, b, inv_d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-13)


def test_kernel_grid_partitioning():
    # Multiple grid steps must agree with a single-block run.
    rng = np.random.default_rng(0)
    x, vals, cols, b, inv_d = make_case(rng, 100, 64, 4)
    one = level_solve(x, vals, cols, b, inv_d, block_r=64)
    many = level_solve(x, vals, cols, b, inv_d, block_r=8)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many), rtol=1e-15)


def test_kernel_rejects_bad_block():
    rng = np.random.default_rng(1)
    x, vals, cols, b, inv_d = make_case(rng, 50, 12, 2)
    with pytest.raises(ValueError):
        level_solve(x, vals, cols, b, inv_d, block_r=8)  # 12 % 8 != 0


def test_padding_slots_are_inert():
    # Padded slots (vals row = 0, inv_diag = 0) must produce 0 and not
    # perturb real slots.
    rng = np.random.default_rng(2)
    x, vals, cols, b, inv_d = make_case(rng, 80, 16, 3)
    vals = vals.at[10:].set(0.0)
    inv_d = inv_d.at[10:].set(0.0)
    b = b.at[10:].set(0.0)
    out = np.asarray(level_solve(x, vals, cols, b, inv_d, block_r=16))
    assert np.all(out[10:] == 0.0)
    expect = np.asarray(ref.level_solve_ref(x, vals, cols, b, inv_d))
    np.testing.assert_allclose(out[:10], expect[:10], rtol=1e-13)


def test_level_step_scatters():
    rng = np.random.default_rng(3)
    n, r, k = 60, 8, 2
    x, vals, cols, _, inv_d = make_case(rng, n, r, k)
    rows = jnp.asarray(
        np.concatenate([rng.choice(n, size=6, replace=False), [n, n]]),
        dtype=jnp.int32,
    )
    b_ext = jnp.asarray(np.append(rng.normal(size=n), 0.0))
    out = level_step(x, rows, vals, cols, b_ext, inv_d, block_r=8)
    expect = ref.level_step_ref(x, rows, vals, cols, b_ext, inv_d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-13)
    # Dummy slot absorbs padded writes; real untouched slots unchanged.
    touched = set(np.asarray(rows).tolist())
    for i in range(n):
        if i not in touched:
            assert out[i] == x[i]


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(10, 200),
    logr=st.integers(0, 5),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(n, logr, k, seed):
    r = 2 ** logr * 8  # 8..256, always divisible by 8
    rng = np.random.default_rng(seed)
    x, vals, cols, b, inv_d = make_case(rng, n, r, k)
    out = level_solve(x, vals, cols, b, inv_d, block_r=8)
    expect = ref.level_solve_ref(x, vals, cols, b, inv_d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_f32_dtype(seed):
    rng = np.random.default_rng(seed)
    x, vals, cols, b, inv_d = make_case(rng, 64, 16, 2, dtype=np.float32)
    out = level_solve(x, vals, cols, b, inv_d, block_r=16)
    assert out.dtype == jnp.float32
    expect = ref.level_solve_ref(x, vals, cols, b, inv_d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5)
