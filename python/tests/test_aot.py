"""AOT path: lowering to HLO text must succeed for every registry shape
and produce parseable artifacts + a consistent manifest."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


def test_lowering_each_entry_kind():
    sys.path.insert(0, PYDIR)
    from compile import aot

    for fn, label in [
        (lambda: aot.lower_step(8, 2, 64), "step"),
        (lambda: aot.lower_solve(4, 8, 2, 64), "solve"),
        (lambda: aot.lower_batch(2, 4, 8, 2, 64), "batch"),
        (lambda: aot.lower_resid(4, 8, 2, 64), "resid"),
    ]:
        text = aot.to_hlo_text(fn())
        assert text.startswith("HloModule"), f"{label}: {text[:40]!r}"
        assert "ENTRY" in text


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "model.hlo.txt"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=PYDIR,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest) >= 5
    names = set()
    for entry in manifest:
        assert entry["entry"] in {"level_step", "solve", "solve_batched", "residual"}
        p = tmp_path / entry["file"]
        assert p.exists(), entry["file"]
        head = p.read_text()[:64]
        assert head.startswith("HloModule")
        assert entry["name"] not in names
        names.add(entry["name"])
    assert out.exists()
