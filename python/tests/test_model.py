"""L2 correctness: the padded-level scan model vs serial forward
substitution, including batched-RHS and the residual graph."""

import numpy as np
import pytest
import jax
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def build_system(seed, n, max_deps=3, pad_k=4):
    rng = np.random.default_rng(seed)
    indptr, indices, data = ref.random_lower_csr(rng, n, max_deps=max_deps)
    levels = ref.level_sets(indptr, indices)
    max_w = max(len(l) for l in levels)
    pad_r = max(8, 1 << (max_w - 1).bit_length())
    p = ref.build_padded_levels(indptr, indices, data, levels, pad_r, pad_k)
    b = rng.normal(size=n)
    return (indptr, indices, data), p, b


def test_solve_matches_serial():
    csr, p, b = build_system(0, 300)
    x = np.asarray(model.solve_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b)[0])
    xs = ref.sptrsv_csr_ref(*csr, b)
    np.testing.assert_allclose(x, xs, rtol=1e-10)


def test_solve_matches_scan_ref():
    _, p, b = build_system(1, 150)
    x = np.asarray(model.solve_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b)[0])
    xr = np.asarray(ref.solve_padded_ref(p["rows"], p["vals"], p["cols"], p["inv_diag"], b))
    np.testing.assert_allclose(x, xr, rtol=1e-13)


def test_batched_rhs():
    csr, p, b0 = build_system(2, 120)
    rng = np.random.default_rng(99)
    bs = np.stack([b0] + [rng.normal(size=len(b0)) for _ in range(3)])
    xs = np.asarray(
        model.solve_batched_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], bs)[0]
    )
    for i in range(bs.shape[0]):
        expect = ref.sptrsv_csr_ref(*csr, bs[i])
        np.testing.assert_allclose(xs[i], expect, rtol=1e-10, err_msg=f"rhs {i}")


def test_residual_small_for_true_solution():
    csr, p, b = build_system(3, 200)
    x = ref.sptrsv_csr_ref(*csr, b)
    r = float(model.residual_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b, x)[0])
    assert r < 1e-9


def test_residual_flags_wrong_solution():
    _, p, b = build_system(4, 100)
    xbad = np.ones(len(b))
    r = float(model.residual_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b, xbad)[0])
    assert r > 1e-3


def test_level_step_fn_sequential_equals_scan():
    # Driving level_step_fn level-by-level (what the Rust coordinator
    # does) must equal the fused scan.
    _, p, b = build_system(5, 150)
    import jax.numpy as jnp

    n = len(b)
    b_ext = jnp.concatenate([jnp.asarray(b), jnp.zeros((1,))])
    x = jnp.zeros((n + 1,))
    for l in range(p["rows"].shape[0]):
        (x,) = model.level_step_fn(
            x,
            jnp.asarray(p["rows"][l]),
            jnp.asarray(p["vals"][l]),
            jnp.asarray(p["cols"][l]),
            b_ext,
            jnp.asarray(p["inv_diag"][l]),
        )
    scan = np.asarray(
        model.solve_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b)[0]
    )
    np.testing.assert_allclose(np.asarray(x[:n]), scan, rtol=1e-13)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(20, 250))
def test_solve_hypothesis(seed, n):
    csr, p, b = build_system(seed, n)
    x = np.asarray(model.solve_fn(p["rows"], p["vals"], p["cols"], p["inv_diag"], b)[0])
    xs = ref.sptrsv_csr_ref(*csr, b)
    np.testing.assert_allclose(x, xs, rtol=1e-9, atol=1e-12)


def test_padding_extra_levels_harmless():
    # Padding the level axis (pad_l > actual) must not change the result:
    # extra levels are all-dummy rows.
    rng = np.random.default_rng(7)
    indptr, indices, data = ref.random_lower_csr(rng, 80)
    levels = ref.level_sets(indptr, indices)
    b = rng.normal(size=80)
    p1 = ref.build_padded_levels(indptr, indices, data, levels, 64, 4)
    p2 = ref.build_padded_levels(indptr, indices, data, levels, 64, 4,
                                 pad_l=len(levels) + 5)
    x1 = np.asarray(model.solve_fn(p1["rows"], p1["vals"], p1["cols"], p1["inv_diag"], b)[0])
    x2 = np.asarray(model.solve_fn(p2["rows"], p2["vals"], p2["cols"], p2["inv_diag"], b)[0])
    np.testing.assert_allclose(x1, x2, rtol=0, atol=0)


def test_build_padded_levels_validation():
    rng = np.random.default_rng(8)
    indptr, indices, data = ref.random_lower_csr(rng, 50)
    levels = ref.level_sets(indptr, indices)
    with pytest.raises(ValueError):
        ref.build_padded_levels(indptr, indices, data, levels, 1, 4)  # pad_r too small
    with pytest.raises(ValueError):
        ref.build_padded_levels(indptr, indices, data, levels, 64, 0)  # pad_k too small
