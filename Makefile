# Convenience wrappers for the multi-step flows CI runs. The workspace
# itself builds with plain cargo; nothing here is required for `cargo
# build` / `cargo test`.

CARGO ?= cargo
BIN   := target/release/sptrsv

.PHONY: build test bench-smoke bench-precond artifacts refresh-baseline

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench-smoke: build
	$(BIN) bench --scenario scenarios/smoke.json --bench-out-dir bench-out

bench-precond: build
	$(BIN) bench --scenario scenarios/precond_serving.json --bench-out-dir bench-out

# The binary artifact round trip: persist an analysis as a `.spa`
# container, inspect and verify it (sections, CRCs, stored placements),
# then warm-start a checked solve from it. Finishes with the warm-start
# bench in smoke mode (informational timings, no ratio gate).
artifacts: build
	mkdir -p bench-out
	$(BIN) gen --kind lung2 --scale 0.05 --out bench-out/lung2.mtx
	$(BIN) analyze --matrix bench-out/lung2.mtx --plan avgcost+scheduled --save bench-out/lung2.spa
	$(BIN) artifact inspect bench-out/lung2.spa
	$(BIN) artifact verify bench-out/lung2.spa
	$(BIN) solve --matrix bench-out/lung2.mtx --analysis bench-out/lung2.spa --check
	SPTRSV_ARTIFACT_SMOKE=1 $(CARGO) bench --bench artifact_perf

# Re-capture the checked-in trend baseline from a fresh smoke run on
# THIS machine. The baseline is the reference shape for the trend gate
# (`sptrsv bench --compare`), so refresh it deliberately — on a quiet
# machine — and commit the diff this produces. CI exposes the same flow
# behind a manual workflow_dispatch run.
refresh-baseline: build
	$(BIN) bench --scenario scenarios/smoke.json --bench-out-dir bench-out
	cp bench-out/BENCH_smoke.json scenarios/BASELINE_smoke.json
	@echo "scenarios/BASELINE_smoke.json refreshed; review and commit the diff"
