//! Integration tests for the binary analysis artifact (`.spa`): the
//! corruption ladder must surface *typed* [`ArtifactError`]s (and the
//! analysis cache must treat every one of them as a miss, falling back
//! to a fresh analysis — never an error), saved artifacts must solve
//! bitwise-identically to the JSON persistence path, and a pool smaller
//! than the one the analysis was placed for must adopt a stored
//! placement instead of re-running coarsening or ETF placement.

use std::path::PathBuf;
use std::sync::Arc;

use sptrsv_gt::analysis::{analyze, Analysis, AnalysisCache, AnalysisFormat, AnalyzeOptions};
use sptrsv_gt::artifact::{container, ArtifactError, ArtifactReader, FORMAT_VERSION, MAGIC};
use sptrsv_gt::error::Error;
use sptrsv_gt::sched::SchedOptions;
use sptrsv_gt::solver::pool::Pool;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::PlanSpec;
use sptrsv_gt::tuner::Fingerprint;
use sptrsv_gt::util::rng::Rng;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sptrsv_it_{name}_{}.spa", std::process::id()))
}

fn opts(workers: usize) -> AnalyzeOptions {
    AnalyzeOptions {
        workers,
        ..Default::default()
    }
}

/// A saved artifact plus the matrix it was analyzed from.
fn saved(name: &str, plan: &str, workers: usize) -> (PathBuf, sptrsv_gt::sparse::Csr) {
    let m = generate::lung2_like(&GenOptions::with_scale(0.04));
    let a = analyze(&m, &PlanSpec::parse(plan).unwrap(), &opts(workers)).unwrap();
    let path = tmp(name);
    a.save_format(&path, AnalysisFormat::Binary).unwrap();
    (path, m)
}

#[test]
fn corruption_surfaces_typed_errors() {
    let (path, m) = saved("corrupt", "avgcost+scheduled", 2);
    let bytes = std::fs::read(&path).unwrap();

    // Truncation: the header's total-length guard catches a short file.
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    match Analysis::load(&path, &m, &opts(2)) {
        Err(Error::Artifact(ArtifactError::Truncated(_))) => {}
        other => panic!("expected typed Truncated, got {other:?}", other = other.err()),
    }

    // A flipped payload byte: that section's CRC-32 must catch it. The
    // first section's payload starts at its table offset.
    let r = ArtifactReader::from_bytes(&bytes).unwrap();
    let payload_off = r.sections()[0].offset as usize;
    drop(r);
    let mut bad = bytes.clone();
    bad[payload_off] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    match Analysis::load(&path, &m, &opts(2)) {
        Err(Error::Artifact(ArtifactError::BadChecksum { .. })) => {}
        other => panic!("expected typed BadChecksum, got {other:?}", other = other.err()),
    }

    // A future format version is refused before any payload is read.
    let mut bad = bytes.clone();
    bad[8] = FORMAT_VERSION as u8 + 9;
    std::fs::write(&path, &bad).unwrap();
    match Analysis::load(&path, &m, &opts(2)) {
        Err(Error::Artifact(ArtifactError::BadVersion { expected, .. })) => {
            assert_eq!(expected, FORMAT_VERSION);
        }
        other => panic!("expected typed BadVersion, got {other:?}", other = other.err()),
    }

    // Stale magic: the reader reports it as not-an-artifact. (The
    // sniffing Analysis::load would route such a file to the JSON
    // loader, so the typed check drives the reader directly — the path
    // `artifact verify` takes.)
    let mut bad = bytes.clone();
    bad[..8].copy_from_slice(b"NOTSPTRS");
    assert!(matches!(
        ArtifactReader::from_bytes(&bad),
        Err(ArtifactError::BadMagic)
    ));
    assert_ne!(&bad[..8], &MAGIC);

    // A section offset knocked off the 8-byte grid the zero-copy views
    // require (the table is not CRC'd — alignment is its own check).
    let mut bad = bytes.clone();
    let entry_off = container::HEADER_LEN + 8;
    let mut off = u64::from_le_bytes(bad[entry_off..entry_off + 8].try_into().unwrap());
    off += 4;
    bad[entry_off..entry_off + 8].copy_from_slice(&off.to_le_bytes());
    std::fs::write(&path, &bad).unwrap();
    match Analysis::load(&path, &m, &opts(2)) {
        Err(Error::Artifact(ArtifactError::Misaligned { section: 0, .. })) => {}
        other => panic!("expected typed Misaligned, got {other:?}", other = other.err()),
    }

    std::fs::remove_file(&path).ok();
}

#[test]
fn cache_treats_corrupt_artifacts_as_misses_and_falls_back_fresh() {
    let dir = std::env::temp_dir().join(format!("sptrsv_it_acache_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = AnalysisCache::new(&dir);
    let pool = Arc::new(Pool::new(2));
    let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.04)));
    let fp = Fingerprint::of(&m);
    let plan = sptrsv_gt::transform::SolvePlan::parse("avgcost+scheduled").unwrap();

    let a = analyze(&m, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts(2)).unwrap();
    cache.save(&a).unwrap();
    let entry = cache.path_for(fp, &plan);
    assert!(entry.exists());

    // Corrupt the cached artifact in every class the reader types; a
    // load must come back None (fall back to fresh analysis), never Err
    // and never a panic.
    let good = std::fs::read(&entry).unwrap();
    // First section's payload offset: a guaranteed-checksummed byte (the
    // file's very last bytes may be alignment padding, which no CRC
    // covers).
    let payload_off = ArtifactReader::from_bytes(&good).unwrap().sections()[0].offset as usize;
    let corruptions: Vec<Vec<u8>> = vec![
        // truncated
        good[..good.len() / 3].to_vec(),
        // future version
        {
            let mut b = good.clone();
            b[8] = 77;
            b
        },
        // payload bit rot
        {
            let mut b = good.clone();
            b[payload_off] ^= 0xff;
            b
        },
        // magic only, no header
        b"SPTRSVA\0".to_vec(),
    ];
    for (i, bad) in corruptions.iter().enumerate() {
        std::fs::write(&entry, bad).unwrap();
        assert!(
            cache
                .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
                .is_none(),
            "corruption {i} should be a miss"
        );
        // The fallback: a fresh analysis still serves and re-saving
        // repairs the cache entry.
        let fresh = analyze(&m, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts(2)).unwrap();
        cache.save(&fresh).unwrap();
        assert!(
            cache
                .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
                .is_some(),
            "re-saved entry should hit again after corruption {i}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn binary_load_solves_bitwise_like_the_json_path() {
    // Property-style sweep: across structures and plans, a binary
    // save->load must produce solutions bitwise identical to a JSON
    // save->load of the same analysis (both replay the same skeleton
    // through the same renumeric pass), with zero structural passes.
    let dir = std::env::temp_dir();
    for (i, (kind, plan)) in [
        ("lung2", "avgcost+scheduled"),
        ("lung2", "avgcost+levelset"),
        ("torso2", "guarded:8+syncfree"),
        ("tri", "manual:4+reorder"),
        ("tri", "none"),
    ]
    .iter()
    .enumerate()
    {
        let g = GenOptions::with_scale(0.03);
        let m = match *kind {
            "lung2" => generate::lung2_like(&g),
            "torso2" => generate::torso2_like(&g),
            _ => generate::tridiagonal(300, &Default::default()),
        };
        let a = analyze(&m, &PlanSpec::parse(plan).unwrap(), &opts(2)).unwrap();
        let pj = dir.join(format!("sptrsv_it_eq_{i}_{}.analysis.json", std::process::id()));
        let pb = dir.join(format!("sptrsv_it_eq_{i}_{}.spa", std::process::id()));
        a.save_format(&pj, AnalysisFormat::Json).unwrap();
        a.save_format(&pb, AnalysisFormat::Binary).unwrap();
        let from_json = Analysis::load(&pj, &m, &opts(2)).unwrap();
        let from_bin = Analysis::load(&pb, &m, &opts(2)).unwrap();
        for (label, l) in [("json", &from_json), ("binary", &from_bin)] {
            let c = l.rebuilds();
            assert_eq!(c.rewrite_passes, 0, "{kind}+{plan} {label}: rewrite re-ran");
            assert_eq!(c.coarsen_passes, 0, "{kind}+{plan} {label}: coarsen re-ran");
            assert_eq!(c.placement_passes, 0, "{kind}+{plan} {label}: placement re-ran");
            assert_eq!(c.renumeric_passes, 1, "{kind}+{plan} {label}: exactly one replay");
        }
        let mut rng = Rng::new(17 + i as u64);
        for _ in 0..3 {
            let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let xb = from_bin.solve(&b);
            assert_eq!(xb, from_json.solve(&b), "{kind}+{plan}: formats diverge");
            assert!(m.residual_inf(&xb, &b) < 1e-9, "{kind}+{plan}");
        }
        std::fs::remove_file(&pj).ok();
        std::fs::remove_file(&pb).ok();
    }
}

#[test]
fn smaller_pool_adopts_a_stored_placement_without_replacing() {
    // The acceptance path: an artifact placed for W workers warm-starts
    // a W-1 pool from the stored W-1 placement — zero coarsening, zero
    // placement, and the adopted schedule actually runs at W-1.
    let (path, m) = saved("shrink", "avgcost+scheduled", 4);
    let r = ArtifactReader::open(&path).unwrap();
    // One SCHEDULE section per stored worker count: 4, 3, 2, 1.
    assert_eq!(r.sections_of(container::SEC_SCHEDULE).count(), 4);
    drop(r);
    let loaded = Analysis::load(&path, &m, &opts(3)).unwrap();
    let c = loaded.rebuilds();
    assert_eq!(c.coarsen_passes, 0, "W-1 load re-ran coarsening");
    assert_eq!(c.placement_passes, 0, "W-1 load re-ran placement");
    assert_eq!(loaded.schedule().unwrap().nworkers, 3);
    let b = vec![1.0; m.nrows];
    assert!(m.residual_inf(&loaded.solve(&b), &b) < 1e-9);
    std::fs::remove_file(&path).ok();
}
