//! Integration tests across modules: generator -> levels -> transform ->
//! solvers -> codegen -> coordinator, on realistic matrices.

use sptrsv_gt::codegen::{self, CodegenOptions};
use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::{Service, SolveOptions};
use sptrsv_gt::transform::PlanSpec;
use sptrsv_gt::graph::{analyze::LevelStats, Levels};
use sptrsv_gt::report::{figures, table1};
use sptrsv_gt::solver::executor::TransformedSolver;
use sptrsv_gt::solver::levelset::LevelSetSolver;
use sptrsv_gt::solver::syncfree::SyncFreeSolver;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::sparse::matrix_market;
use sptrsv_gt::transform::{Rewrite, SolvePlan};
use sptrsv_gt::util::prop::assert_allclose;
use sptrsv_gt::util::rng::Rng;

/// The full Table I pipeline at reduced scale: every metric must have the
/// paper's qualitative shape.
#[test]
fn table1_shape_lung2() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.1));
    let cells = table1::run_matrix(&m, true);
    let (none, avg, man) = (&cells[0], &cells[1], &cells[2]);
    // Strong level reduction, avgcost > manual.
    assert!(avg.num_levels < none.num_levels / 4);
    assert!(man.num_levels < none.num_levels / 2);
    assert!(avg.num_levels <= man.num_levels);
    // Average level cost multiplies accordingly.
    assert!(avg.avg_level_cost > 4.0 * none.avg_level_cost);
    // Total cost approximately preserved (paper: ~1% lower).
    let drift =
        (avg.total_level_cost as f64 / none.total_level_cost as f64 - 1.0).abs();
    assert!(drift < 0.05, "total cost drift {drift}");
    // Code size in the same ballpark as the original.
    assert!(avg.code_size_mb > 0.0 && avg.code_size_mb < 2.0 * none.code_size_mb);
    // Few rows rewritten (paper: ~1%).
    assert!((avg.rows_rewritten as f64) < 0.1 * m.nrows as f64);
}

#[test]
fn table1_shape_torso2() {
    let m = generate::torso2_like(&GenOptions::with_scale(0.05));
    let cells = table1::run_matrix(&m, false);
    let (none, avg, man) = (&cells[0], &cells[1], &cells[2]);
    // Milder reduction than lung2 (paper: 34% / 45% vs 95% / 86%).
    assert!(avg.num_levels < none.num_levels);
    assert!(man.num_levels < none.num_levels);
    let red_avg = 1.0 - avg.num_levels as f64 / none.num_levels as f64;
    assert!(red_avg < 0.9, "torso2 reduction {red_avg} too strong");
    // Manual inflates total cost more than avgcost (paper: +40% vs +0.2%).
    assert!(man.total_level_cost >= avg.total_level_cost);
    // More rows rewritten than lung2, relatively (paper: 13-16%).
    assert!(man.rows_rewritten > avg.rows_rewritten / 2);
}

/// All four solver backends agree on all strategies.
#[test]
fn solver_backends_agree() {
    let m = generate::torso2_like(&GenOptions::with_scale(0.02));
    let mut rng = Rng::new(5);
    let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let x_serial = sptrsv_gt::solver::serial::solve(&m, &b);
    let x_level = LevelSetSolver::from_matrix(m.clone(), 3).solve(&b);
    let x_sync = SyncFreeSolver::from_matrix(m.clone(), 3).solve(&b);
    assert_allclose(&x_level, &x_serial, 1e-12, 1e-14).unwrap();
    assert_allclose(&x_sync, &x_serial, 1e-12, 1e-14).unwrap();
    for strat in ["none", "avgcost", "manual:7"] {
        let t = SolvePlan::parse(strat).unwrap().apply(&m);
        let s = TransformedSolver::from_parts(m.clone(), t, 3);
        let x = s.solve(&b);
        assert_allclose(&x, &x_serial, 1e-8, 1e-10)
            .unwrap_or_else(|e| panic!("{strat}: {e}"));
    }
}

/// Matrix Market roundtrip preserves solutions end-to-end.
#[test]
fn matrix_market_roundtrip_solve() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.02));
    let path = std::env::temp_dir().join(format!("sptrsv_it_{}.mtx", std::process::id()));
    matrix_market::write_path(&m, &path).unwrap();
    let m2 = matrix_market::read_path(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(m, m2);
    let b = vec![1.0; m.nrows];
    let x1 = sptrsv_gt::solver::serial::solve(&m, &b);
    let x2 = sptrsv_gt::solver::serial::solve(&m2, &b);
    assert_eq!(x1, x2);
}

/// Codegen Fig-3 reproduction: the three snippets differ as published.
#[test]
fn fig3_codegen_variants() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.05));
    let mut rng = Rng::new(7);
    let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let bake = CodegenOptions {
        bake_b: Some(b),
        ..Default::default()
    };
    let g_none = codegen::generate(&m, &Rewrite::None.apply(&m), &bake);
    let t_avg = SolvePlan::parse("avgcost").unwrap().apply(&m);
    let g_avg = codegen::generate(&m, &t_avg, &bake);
    let t_man = SolvePlan::parse("manual").unwrap().apply(&m);
    let g_man = codegen::generate(&m, &t_man, &bake);
    // Paper: code shrinks slightly for avgcost (fewer divisions/levels).
    assert!(g_avg.size_bytes < g_none.size_bytes);
    assert!(g_man.size_bytes < g_none.size_bytes * 11 / 10);
    // Fewer functions after rewriting (levels merged).
    assert!(g_avg.num_functions < g_none.num_functions);
}

/// Figures 5/6 series: bumps (fat levels) survive all strategies.
#[test]
fn figures_series_properties() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.05));
    let ss = figures::series(&m);
    assert_eq!(ss.len(), 3);
    let csv = figures::to_csv(&ss);
    assert!(csv.lines().count() > ss[1].level_costs.len());
    // avgLevelCost raises the average the most (paper Fig 5 annotations).
    assert!(ss[1].avg_level_cost > ss[0].avg_level_cost);
    assert!(ss[1].avg_level_cost >= ss[2].avg_level_cost * 0.8);
}

/// Coordinator serves mixed workloads with correct results end-to-end.
#[test]
fn coordinator_end_to_end_native() {
    let svc = Service::start(Config {
        workers: 2,
        use_xla: false,
        batch_size: 4,
        batch_deadline_us: 200,
        ..Default::default()
    });
    let h = svc.handle();
    let m = generate::torso2_like(&GenOptions::with_scale(0.01));
    let n = m.nrows;
    let info = h
        .register("t2", m.clone(), PlanSpec::parse("avgcost").unwrap())
        .unwrap();
    assert!(info.levels_after <= info.levels_before);
    let mut rng = Rng::new(3);
    let reqs: Vec<_> = (0..16)
        .map(|_| {
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (b.clone(), h.solve_async("t2", b, SolveOptions::default()).unwrap())
        })
        .collect();
    for (b, ticket) in reqs {
        let x = ticket.wait().unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
    }
    let snap = h.metrics().unwrap();
    assert_eq!(snap.solves, 16);
    assert!(snap.errors == 0);
    svc.shutdown();
}

/// Transform must be idempotent in effect: re-applying a strategy to an
/// already-chubby system changes little.
#[test]
fn transform_stability_under_reapplication() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.05));
    let t1 = SolvePlan::parse("avgcost").unwrap().apply(&m);
    // The *structure* after transform has few thin levels left: applying
    // the same criterion to the new stats finds little to do.
    let st = LevelStats::from_row_costs(&t1.row_costs, &t1.levels);
    let thin = st.thin_levels();
    assert!(
        thin.len() <= t1.levels.len() / 2 + 1,
        "{} of {} levels still thin",
        thin.len(),
        t1.levels.len()
    );
}

/// Level construction is consistent between the Levels builder and the
/// transform result for the identity strategy.
#[test]
fn identity_transform_levels_match_builder() {
    let m = generate::random_lower(500, 4, 0.8, &Default::default());
    let lv = Levels::build(&m);
    let t = Rewrite::None.apply(&m);
    assert_eq!(t.levels.len(), lv.num_levels());
    for (a, b) in t.levels.iter().zip(&lv.levels) {
        assert_eq!(a, b);
    }
}
