//! Property-based tests over the coordinator-side invariants: level
//! validity, semantic preservation of rewriting, cost-model bookkeeping,
//! batching FIFO order, and solver agreement — swept across random
//! matrices and strategies.

use sptrsv_gt::graph::{analyze::LevelStats, Dag, Levels};
use sptrsv_gt::runtime::PaddedSystem;
use sptrsv_gt::solver::executor::TransformedSolver;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::{Rewrite, SolvePlan};
use sptrsv_gt::util::prop::{assert_allclose, check};
use sptrsv_gt::util::rng::Rng;

fn random_matrix(rng: &mut Rng, case: u64) -> sptrsv_gt::sparse::Csr {
    let n = 20 + (case as usize % 10) * 40 + rng.below(50);
    let max_deps = 1 + rng.below(5);
    let density = rng.uniform(0.3, 0.95);
    generate::random_lower(
        n,
        max_deps,
        density,
        &GenOptions {
            seed: rng.next_u64(),
            ..Default::default()
        },
    )
}

fn random_rewrite(rng: &mut Rng) -> Rewrite {
    match rng.below(3) {
        0 => Rewrite::None,
        1 => Rewrite::AvgLevelCost(Default::default()),
        _ => Rewrite::Manual(sptrsv_gt::transform::manual::ManualOptions {
            distance: 2 + rng.below(12),
        }),
    }
}

/// A random valid plan string straight from the grammar (legacy single
/// names and composed `rewrite+exec` forms alike).
fn random_plan_text(rng: &mut Rng) -> String {
    let rewrite = match rng.below(5) {
        0 => "none".to_string(),
        1 => "avgcost".to_string(),
        2 => format!("manual:{}", 2 + rng.below(30)),
        3 => format!("guarded:{}", 1 + rng.below(40)),
        _ => format!("guarded:{}:{}", 1 + rng.below(40), 10u64.pow(rng.below(13) as u32)),
    };
    let exec = match rng.below(6) {
        0 => "levelset".to_string(),
        1 => "scheduled".to_string(),
        2 => format!("scheduled:{}", 1 + rng.below(1000)),
        3 => format!("scheduled:{}:{}", 1 + rng.below(1000), rng.below(16)),
        4 => format!("scheduled::{}", rng.below(16)),
        _ => ["syncfree", "reorder"][rng.below(2)].to_string(),
    };
    match rng.below(3) {
        0 => rewrite,            // legacy rewrite name
        1 => exec,               // legacy exec name
        _ => format!("{rewrite}+{exec}"),
    }
}

/// Any strategy on any matrix yields a valid topological level structure.
#[test]
fn prop_transform_levels_valid() {
    check("transform-levels-valid", 60, |rng, case| {
        let m = random_matrix(rng, case);
        let t = random_rewrite(rng).apply(&m);
        t.validate(&m)?;
        // Level-of and levels agree.
        for (l, rows) in t.levels.iter().enumerate() {
            for &r in rows {
                if t.level_of[r as usize] as usize != l {
                    return Err(format!("row {r} level mismatch"));
                }
            }
        }
        // No empty levels survive compaction.
        if t.levels.iter().any(Vec::is_empty) {
            return Err("empty level survived".into());
        }
        Ok(())
    });
}

/// The transformed system solves to the serial solution (semantics).
#[test]
fn prop_transform_preserves_solution() {
    check("transform-preserves-solution", 40, |rng, case| {
        let m = random_matrix(rng, case);
        let t = random_rewrite(rng).apply(&m);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        let s = TransformedSolver::from_parts(m, t, 1 + rng.below(4));
        assert_allclose(&s.solve(&b), &x_ref, 1e-8, 1e-10)
    });
}

/// Paper cost-model bookkeeping: total cost of the identity equals
/// 2*nnz - n; each level's cost equals the sum of its row costs.
#[test]
fn prop_cost_bookkeeping() {
    check("cost-bookkeeping", 60, |rng, case| {
        let m = random_matrix(rng, case);
        let t = random_rewrite(rng).apply(&m);
        let st = LevelStats::from_row_costs(&t.row_costs, &t.levels);
        if st.total_cost != t.stats.total_level_cost_after {
            return Err(format!(
                "total {} != stats {}",
                st.total_cost, t.stats.total_level_cost_after
            ));
        }
        if t.stats.rows_rewritten != t.log.len() {
            return Err("rewrite log length mismatch".into());
        }
        // Rewrites only move rows upward.
        for rec in &t.log {
            if rec.to_level >= rec.from_level {
                return Err(format!("rewrite {rec:?} not upward"));
            }
        }
        Ok(())
    });
}

/// Level-set structure invariants vs the DAG: level = longest dep chain.
#[test]
fn prop_levels_equal_critical_depth() {
    check("levels-equal-depth", 60, |rng, case| {
        let m = random_matrix(rng, case);
        let lv = Levels::build(&m);
        lv.validate(&m)?;
        let cp = sptrsv_gt::graph::critical_path::CriticalPath::compute(&m);
        for i in 0..m.nrows {
            if cp.depth[i] != lv.level_of[i] {
                return Err(format!("row {i}: depth != level"));
            }
        }
        if cp.length as usize != lv.num_levels() {
            return Err("critical path length != num levels".into());
        }
        // DAG edge count == off-diagonal nnz.
        let dag = Dag::build(&m);
        if dag.num_edges() != m.nnz() - m.nrows {
            return Err("edge count mismatch".into());
        }
        Ok(())
    });
}

/// Padded-system layout: emulating the scan semantics on the padded
/// arrays reproduces the serial solution for arbitrary fitting shapes.
#[test]
fn prop_padded_layout_correct() {
    check("padded-layout", 30, |rng, case| {
        let m = random_matrix(rng, case);
        let t = random_rewrite(rng).apply(&m);
        let mut shape = PaddedSystem::requirements(&m, &t);
        shape.l += rng.below(4);
        shape.r += rng.below(8);
        shape.k += rng.below(3);
        shape.n += rng.below(16);
        let p = PaddedSystem::build(&m, &t, shape).map_err(|e| e.to_string())?;
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
        // Emulate the L2 scan on CPU.
        let bp = p.map_rhs(&b);
        let mut b_ext = bp.clone();
        b_ext.push(0.0);
        let mut x = vec![0.0; shape.n + 1];
        for li in 0..shape.l {
            let mut xl = vec![0.0; shape.r];
            for ri in 0..shape.r {
                let slot = li * shape.r + ri;
                let mut s = 0.0;
                for d in 0..shape.k {
                    s += p.vals[slot * shape.k + d] * x[p.cols[slot * shape.k + d] as usize];
                }
                xl[ri] = (b_ext[p.rows[slot] as usize] - s) * p.inv_diag[slot];
            }
            for ri in 0..shape.r {
                x[p.rows[li * shape.r + ri] as usize] = xl[ri];
            }
        }
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        assert_allclose(&x[..m.nrows], &x_ref, 1e-8, 1e-10)
    });
}

/// Batcher: no loss, no duplication under random operations across both
/// lanes and multi-RHS blocks.
#[test]
fn prop_batcher_fifo_no_loss() {
    use sptrsv_gt::coordinator::batcher::{Batcher, Lane};
    use std::time::Duration;
    check("batcher-fifo", 60, |rng, _| {
        let mut b: Batcher<u64> = Batcher::new(1 + rng.below(6), Duration::from_secs(60));
        let mut next_token = 0u64;
        let mut taken: Vec<u64> = Vec::new();
        let ids = ["a", "b", "c"];
        for _ in 0..rng.below(60) + 5 {
            if rng.chance(0.7) {
                let id = ids[rng.below(3)];
                let lane = if rng.chance(0.3) {
                    Lane::Interactive
                } else {
                    Lane::Batch
                };
                let block = vec![vec![0.0]; 1 + rng.below(3)];
                b.push(id, block, lane, None, next_token);
                next_token += 1;
            } else {
                let id = ids[rng.below(3)];
                for p in b.take(id) {
                    taken.push(p.token);
                }
            }
        }
        for id in ids {
            loop {
                let batch = b.take(id);
                if batch.is_empty() {
                    break;
                }
                taken.extend(batch.iter().map(|p| p.token));
            }
        }
        if b.pending() != 0 {
            return Err("tokens lost in queues".into());
        }
        taken.sort_unstable();
        let expect: Vec<u64> = (0..next_token).collect();
        if taken != expect {
            return Err(format!("lost/duplicated tokens: {} vs {}", taken.len(), next_token));
        }
        Ok(())
    });
}

/// Equation algebra: substituting in any order gives the same equation
/// (the rearrangement is canonical).
#[test]
fn prop_substitution_order_independent() {
    use sptrsv_gt::transform::Equation;
    check("substitution-order", 60, |rng, _| {
        // x3 depends on x1, x2; both depend on x0.
        let e0 = Equation::original(0, &[], &[], rng.uniform(0.5, 2.0));
        let e1 = Equation::original(1, &[0], &[rng.uniform(-2.0, 2.0)], rng.uniform(0.5, 2.0));
        let e2 = Equation::original(2, &[0], &[rng.uniform(-2.0, 2.0)], rng.uniform(0.5, 2.0));
        let base = Equation::original(
            3,
            &[1, 2],
            &[rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
            rng.uniform(0.5, 2.0),
        );
        let mut a = base.clone();
        a.substitute(&e1);
        a.substitute(&e2);
        a.substitute(&e0);
        let mut b = base.clone();
        b.substitute(&e2);
        b.substitute(&e1);
        b.substitute(&e0);
        if a.coeffs.len() != b.coeffs.len() || a.bcoeffs.len() != b.bcoeffs.len() {
            return Err("structure differs by order".into());
        }
        for (x, y) in a.bcoeffs.iter().zip(&b.bcoeffs) {
            if x.0 != y.0 || (x.1 - y.1).abs() > 1e-12 * x.1.abs().max(1.0) {
                return Err(format!("bcoeff {x:?} vs {y:?}"));
            }
        }
        Ok(())
    });
}

/// Plan grammar: `parse -> display -> parse` is the identity, for every
/// string the grammar can produce. (Display emits the canonical two-axis
/// form, so one extra display round verifies canonicalization is a fixed
/// point.)
#[test]
fn prop_plan_grammar_roundtrip() {
    check("plan-grammar-roundtrip", 400, |rng, _| {
        let text = random_plan_text(rng);
        let plan = SolvePlan::parse(&text).map_err(|e| format!("{text}: {e}"))?;
        let canonical = plan.to_string();
        let reparsed =
            SolvePlan::parse(&canonical).map_err(|e| format!("display '{canonical}': {e}"))?;
        if reparsed != plan {
            return Err(format!("'{text}' -> '{canonical}' reparsed differently"));
        }
        if reparsed.to_string() != canonical {
            return Err(format!("display of '{canonical}' not a fixed point"));
        }
        Ok(())
    });
}

/// Every composed (rewrite, exec) pair solves to the serial solution on
/// the paper-shaped and chain-shaped generators — the acceptance matrix
/// of the solve-plan redesign.
#[test]
fn prop_composed_pairs_match_serial() {
    use sptrsv_gt::solver::ExecSolver;
    use std::sync::Arc;

    let rewrites = ["none", "avgcost", "manual:6", "guarded:5"];
    let execs = ["levelset", "scheduled:64:2", "syncfree", "reorder"];
    let mut rng = Rng::new(0xC0_FFEE);
    for (gi, m) in [
        generate::lung2_like(&GenOptions::with_scale(0.04)),
        generate::tridiagonal(150, &Default::default()),
    ]
    .into_iter()
    .enumerate()
    {
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        let ma = Arc::new(m);
        let pool = Arc::new(sptrsv_gt::solver::pool::Pool::new(3));
        for rw in rewrites {
            for ex in execs {
                let name = format!("{rw}+{ex}");
                let plan = SolvePlan::parse(&name).unwrap();
                let t = plan.apply(&ma);
                t.validate(&ma).unwrap_or_else(|e| panic!("{name}: {e}"));
                let s = ExecSolver::build(
                    Arc::clone(&ma),
                    Arc::new(t),
                    &plan.exec,
                    Arc::clone(&pool),
                    Default::default(),
                )
                .unwrap_or_else(|e| panic!("{name}: {e}"));
                let x = s.solve(&b);
                assert_allclose(&x, &x_ref, 1e-9, 1e-11)
                    .unwrap_or_else(|e| panic!("generator {gi}, {name}: {e}"));
            }
        }
    }
}

/// Scheduler: elastic execution of a coarsened schedule matches the
/// serial solver on arbitrary lower-triangular matrices, across worker
/// counts, block targets and staleness windows — including the
/// unit-diagonal, serial-chain and dense-level corner shapes the
/// coarsening special-cases.
#[test]
fn prop_scheduled_matches_serial() {
    use sptrsv_gt::sched::{SchedOptions, ScheduledSolver};

    check("scheduled-matches-serial", 40, |rng, case| {
        let mut m = match case % 4 {
            // Serial chain: collapses to one block, fully sequential.
            0 => generate::tridiagonal(30 + rng.below(200), &Default::default()),
            // Dense level(s): a shallow banded matrix, wide levels.
            1 => generate::banded(50 + rng.below(200), 2 + rng.below(6), 0.3, &Default::default()),
            // General random structure.
            _ => random_matrix(rng, case),
        };
        if case % 3 == 0 {
            // Unit diagonal: the folded inverse is exact, results must
            // still track the serial solver bit-for-bit close.
            for i in 0..m.nrows {
                let d = m.indptr[i + 1] - 1;
                m.data[d] = 1.0;
            }
        }
        let t = random_rewrite(rng).apply(&m);
        let opts = SchedOptions {
            block_target: Some(1 + rng.below(300)),
            stale_window: Some(rng.below(9)),
        };
        let workers = 1 + rng.below(6);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        let s = ScheduledSolver::from_parts(m, t, workers, &opts);
        s.schedule.validate(&s.m, &s.t).map_err(|e| format!("schedule invalid: {e}"))?;
        let x = s.solve(&b);
        assert_allclose(&x, &x_ref, 1e-9, 1e-11)?;
        // A second solve on the same solver must be bitwise identical:
        // thread timing may reorder who computes a row, never its value.
        if s.solve(&b) != x {
            return Err("scheduled solve not deterministic across runs".into());
        }
        Ok(())
    });
}

/// Schedule construction is a pure function of (matrix, transform,
/// workers, block target): two builds agree structurally, and the block
/// partition always covers every row exactly once.
#[test]
fn prop_schedule_construction_deterministic() {
    use sptrsv_gt::sched::Schedule;

    check("schedule-deterministic", 40, |rng, case| {
        let m = random_matrix(rng, case);
        let t = random_rewrite(rng).apply(&m);
        let workers = 1 + rng.below(6);
        let target = 1 + rng.below(400);
        let a = Schedule::build(&m, &t, workers, target);
        let b = Schedule::build(&m, &t, workers, target);
        if a.blocks != b.blocks
            || a.worker_of != b.worker_of
            || a.worker_lists != b.worker_lists
            || a.preds != b.preds
            || a.stats != b.stats
        {
            return Err("schedule construction not deterministic".into());
        }
        a.validate(&m, &t)?;
        let rows_scheduled: usize = a.blocks.iter().map(|blk| blk.rows.len()).sum();
        if rows_scheduled != m.nrows {
            return Err(format!("{rows_scheduled} rows scheduled of {}", m.nrows));
        }
        if a.stats.total_cost != t.row_costs.iter().sum::<u64>() {
            return Err("coarsening changed total work".into());
        }
        Ok(())
    });
}

/// Analyze/execute split acceptance: for every one of the 16 composed
/// (rewrite, exec) pairs, refreshing an analysis with same-pattern
/// perturbed values matches a from-scratch analysis of the new matrix
/// within 1e-12 — while the structural rebuild counters stay flat (only
/// the renumeric replay runs).
#[test]
fn prop_refresh_values_matches_fresh_analyze_all_16_plans() {
    use sptrsv_gt::analysis::{analyze, AnalyzeOptions};
    use sptrsv_gt::transform::PlanSpec;

    let rewrites = ["none", "avgcost", "manual:6", "guarded:5"];
    let execs = ["levelset", "scheduled:64:2", "syncfree", "reorder"];
    let opts = AnalyzeOptions {
        workers: 2,
        ..Default::default()
    };
    check("refresh-matches-fresh", 4, |rng, case| {
        // Well-conditioned generators: the 1e-12 refresh-vs-fresh gate
        // measures replay fidelity, not amplification of an
        // ill-conditioned system's intrinsic rounding.
        let m = match case % 3 {
            0 => generate::lung2_like(&GenOptions::with_scale(0.02)),
            1 => generate::tridiagonal(120 + rng.below(80), &Default::default()),
            _ => generate::poisson2d_ilu(12 + rng.below(6), 12, &Default::default()),
        };
        // Same pattern, perturbed values: a refreshed factorization.
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 1.0 + 0.1 * rng.uniform(-1.0, 1.0);
        }
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
        for rw in rewrites {
            for ex in execs {
                let name = format!("{rw}+{ex}");
                let spec = PlanSpec::parse(&name).unwrap();
                let mut a = analyze(&m, &spec, &opts).map_err(|e| format!("{name}: {e}"))?;
                let before = a.rebuilds();
                a.refresh_values(&m2).map_err(|e| format!("{name}: {e}"))?;
                let after = a.rebuilds();
                // Structural counters flat; exactly one numeric replay.
                if after.rewrite_passes != before.rewrite_passes
                    || after.coarsen_passes != before.coarsen_passes
                    || after.placement_passes != before.placement_passes
                    || after.renumeric_passes != before.renumeric_passes + 1
                {
                    return Err(format!(
                        "{name}: counters moved {before:?} -> {after:?}"
                    ));
                }
                let fresh = analyze(&m2, &spec, &opts).map_err(|e| format!("{name}: {e}"))?;
                assert_allclose(&a.solve(&b), &fresh.solve(&b), 1e-12, 1e-12)
                    .map_err(|e| format!("{name}: refresh != fresh: {e}"))?;
                // Both are exact solutions of the NEW system.
                let x_ref = sptrsv_gt::solver::serial::solve(&m2, &b);
                assert_allclose(&a.solve(&b), &x_ref, 1e-9, 1e-11)
                    .map_err(|e| format!("{name}: refresh vs serial: {e}"))?;
            }
        }
        Ok(())
    });
}

/// Persistence acceptance: save -> load -> solve is deterministic (two
/// independent loads produce bitwise-identical solutions) and agrees
/// with the original in-memory analysis within 1e-12.
#[test]
fn prop_analysis_save_load_roundtrip_deterministic() {
    use sptrsv_gt::analysis::{analyze, Analysis, AnalyzeOptions};
    use sptrsv_gt::transform::PlanSpec;

    let opts = AnalyzeOptions {
        workers: 2,
        ..Default::default()
    };
    check("analysis-save-load-roundtrip", 12, |rng, case| {
        let m = random_matrix(rng, case);
        let name = random_plan_text(rng);
        let spec = PlanSpec::parse(&name).unwrap();
        let a = analyze(&m, &spec, &opts).map_err(|e| format!("{name}: {e}"))?;
        let path = std::env::temp_dir().join(format!(
            "sptrsv_prop_analysis_{}_{case}.json",
            std::process::id()
        ));
        a.save(&path).map_err(|e| format!("{name}: save: {e}"))?;
        let l1 = Analysis::load(&path, &m, &opts).map_err(|e| format!("{name}: load: {e}"))?;
        let l2 = Analysis::load(&path, &m, &opts).map_err(|e| format!("{name}: load2: {e}"))?;
        std::fs::remove_file(&path).ok();
        // Loading pays no structural pass.
        let c = l1.rebuilds();
        if c.rewrite_passes + c.coarsen_passes + c.placement_passes != 0 {
            return Err(format!("{name}: load re-ran structural work: {c:?}"));
        }
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let x1 = l1.solve(&b);
        // Determinism: independent loads solve bitwise identically (and
        // a repeat solve on one load too).
        if x1 != l2.solve(&b) || x1 != l1.solve(&b) {
            return Err(format!("{name}: load -> solve not deterministic"));
        }
        assert_allclose(&x1, &a.solve(&b), 1e-9, 1e-11)
            .map_err(|e| format!("{name}: loaded != original: {e}"))?;
        Ok(())
    });
}

/// Rendezvous routing stays put under shard-count changes of one: adding
/// a shard only pulls keys onto the newcomer, removing the last shard
/// only evicts its own keys, and every route is a pure function of
/// `(fingerprint, nshards)`.
/// Inexact tier semantics, swept across rewrite compositions: running
/// the Jacobi iteration for the transformed level count reproduces the
/// serial solution (the iteration matrix is nilpotent), so the relative
/// residual against the ORIGINAL system certifies tight tolerances.
/// This is the invariant the serving tier's accuracy ladder leans on
/// when it escalates sweeps toward `exact_sweeps`. Mixed precision gets
/// the same sweep budget but a looser bound: its f32 state caps what
/// the f64 correction sweep can recover.
#[test]
fn prop_jacobi_exact_sweeps_certify_tolerance_across_rewrites() {
    use sptrsv_gt::iterative::{relative_residual, JacobiSolver};
    use std::sync::Arc;

    check("jacobi-exact-sweeps-certify", 30, |rng, case| {
        let m = random_matrix(rng, case);
        let rw = ["none", "avgcost", "manual:4", "guarded:5"][rng.below(4)];
        let plan = SolvePlan::parse(&format!("{rw}+jacobi:1")).map_err(|e| e.to_string())?;
        let t = plan.apply(&m);
        let ma = Arc::new(m);
        let pool = Arc::new(sptrsv_gt::solver::pool::Pool::new(1 + rng.below(4)));
        let mixed = rng.below(2) == 1;
        let s = JacobiSolver::build(&ma, Arc::new(t), pool, 1, mixed).map_err(|e| e.to_string())?;
        let b: Vec<f64> = (0..ma.nrows).map(|_| rng.uniform(-3.0, 3.0)).collect();

        let mut x = vec![0.0; ma.nrows];
        s.solve_with_sweeps(&b, s.exact_sweeps(), &mut x);
        let r = relative_residual(&ma, &x, &b);
        let bound = if mixed { 1e-4 } else { 1e-8 };
        if r > bound || !r.is_finite() {
            return Err(format!(
                "{rw}+jacobi (mixed={mixed}): exact-sweep residual {r:.3e} over {bound:.0e}"
            ));
        }
        if !mixed {
            let x_ref = sptrsv_gt::solver::serial::solve(&ma, &b);
            assert_allclose(&x, &x_ref, 1e-7, 1e-9)?;
        }

        // An under-budgeted run may be inexact, but its residual is
        // still a finite, honest certificate — exactly what the ladder
        // compares against the request tolerance before escalating.
        let mut x1 = vec![0.0; ma.nrows];
        s.solve_with_sweeps(&b, 1, &mut x1);
        let r1 = relative_residual(&ma, &x1, &b);
        if !r1.is_finite() {
            return Err(format!("{rw}+jacobi: 1-sweep residual not finite"));
        }
        Ok(())
    });
}

#[test]
fn prop_rendezvous_routing_stable_under_pool_resize() {
    use sptrsv_gt::exec_tier::rendezvous::route;
    use sptrsv_gt::tuner::Fingerprint;

    check("rendezvous-resize-stability", 200, |rng, _case| {
        let fp = Fingerprint(rng.next_u64());
        let n = 1 + rng.below(15);
        let home = route(fp, n);
        if home >= n {
            return Err(format!("{fp:?}: route {home} out of range for {n}"));
        }
        if route(fp, n) != home {
            return Err(format!("{fp:?}: route not deterministic at {n}"));
        }
        // Grow by one: either unmoved, or moved onto the new shard `n`.
        let grown = route(fp, n + 1);
        if grown != home && grown != n {
            return Err(format!(
                "{fp:?}: grow {n}->{} moved {home} -> {grown} (not the new shard)",
                n + 1
            ));
        }
        // Shrink by one (when possible): survivors keep their home, and
        // only keys that lived on the removed top shard relocate.
        if n > 1 {
            let shrunk = route(fp, n - 1);
            if home < n - 1 && shrunk != home {
                return Err(format!(
                    "{fp:?}: shrink {n}->{} moved a surviving key {home} -> {shrunk}",
                    n - 1
                ));
            }
            if shrunk >= n - 1 {
                return Err(format!("{fp:?}: shrink route {shrunk} out of range"));
            }
        }
        Ok(())
    });
}
