//! Integration tests for the plan-portfolio autotuner: fingerprint
//! stability, plan-cache behaviour (memory and disk), cost-model /
//! measured-ordering agreement over the rewrite × exec cross product,
//! and `auto` end-to-end through the coordinator.

use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::Service;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::sparse::Csr;
use sptrsv_gt::transform::{Exec, PlanSpec, Rewrite, SolvePlan};
use sptrsv_gt::tuner::cost_model::{plan_cost, CostModel};
use sptrsv_gt::tuner::{Fingerprint, MatrixFeatures, PlanSource, Tuner, TunerOptions};
use sptrsv_gt::util::rng::Rng;

fn quick_opts() -> TunerOptions {
    TunerOptions {
        race_solves: 2,
        workers: 2,
        ..Default::default()
    }
}

#[test]
fn fingerprint_stable_across_value_perturbation() {
    let m = generate::torso2_like(&GenOptions::with_scale(0.02));
    let fp = Fingerprint::of(&m);
    // Same structure, perturbed values (a refreshed factorization).
    let mut m2 = m.clone();
    let mut rng = Rng::new(99);
    for v in &mut m2.data {
        *v *= 1.0 + 0.01 * rng.uniform(-1.0, 1.0);
    }
    assert_ne!(m.data, m2.data);
    assert_eq!(Fingerprint::of(&m2), fp);
    // A structurally different matrix fingerprints differently.
    let other = generate::torso2_like(&GenOptions {
        seed: 1,
        ..GenOptions::with_scale(0.02)
    });
    assert_ne!(Fingerprint::of(&other), fp);
}

#[test]
fn cache_hit_returns_identical_plan() {
    let m = generate::lung2_like(&GenOptions::with_scale(0.03));
    let mut tuner = Tuner::new(quick_opts());
    let p1 = tuner.choose(&m).unwrap();
    assert_eq!(p1.source, PlanSource::Raced);
    // Re-registration of the same structure with perturbed values.
    let mut m2 = m.clone();
    for v in &mut m2.data {
        *v *= 1.001;
    }
    let p2 = tuner.choose(&m2).unwrap();
    assert_eq!(p2.source, PlanSource::CacheHit);
    assert_eq!(p2.fingerprint, p1.fingerprint);
    assert_eq!(p2.plan_name, p1.plan_name);
    // Identical plan shape: same level partition sizes.
    assert_eq!(p2.transform.num_levels(), p1.transform.num_levels());
    let widths1: Vec<usize> = p1.transform.levels.iter().map(Vec::len).collect();
    let widths2: Vec<usize> = p2.transform.levels.iter().map(Vec::len).collect();
    assert_eq!(widths1, widths2);
    assert_eq!(tuner.cache_stats(), (1, 1));
    // The cached plan still solves the perturbed system correctly.
    p2.transform.validate(&m2).unwrap();
}

#[test]
fn plan_cache_survives_restart_via_disk_spill() {
    let path = std::env::temp_dir().join(format!(
        "sptrsv_tuner_it_{}.json",
        std::process::id()
    ));
    std::fs::remove_file(&path).ok();
    let m = generate::lung2_like(&GenOptions::with_scale(0.03));
    let chosen = {
        let mut tuner = Tuner::new(TunerOptions {
            cache_path: Some(path.clone()),
            ..quick_opts()
        });
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.source, PlanSource::Raced);
        p.plan_name
    };
    // A fresh tuner (fresh process, same cache file) skips the race.
    let mut tuner2 = Tuner::new(TunerOptions {
        cache_path: Some(path.clone()),
        ..quick_opts()
    });
    let p = tuner2.choose(&m).unwrap();
    assert_eq!(p.source, PlanSource::CacheHit);
    assert_eq!(p.plan_name, chosen);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(sptrsv_gt::tuner::calibration::path_for(&path)).ok();
}

/// The cost model predicts from features alone (before any transform
/// runs). For every candidate pair whose *actual* post-transform cost —
/// the same level/work formula applied to the really-transformed stats —
/// differs by a wide margin, the model must order the pair the same way.
/// Near-ties are skipped: the race, not the model, settles those.
#[test]
fn cost_model_ranking_agrees_with_measured_ordering() {
    let workers = 4;
    let candidates = ["none", "avgcost", "manual:10", "guarded:20"];
    let matrices: Vec<(&str, Csr)> = vec![
        ("lung2-like", generate::lung2_like(&GenOptions::with_scale(0.05))),
        ("torso2-like", generate::torso2_like(&GenOptions::with_scale(0.03))),
        ("tridiagonal", generate::tridiagonal(400, &Default::default())),
    ];
    let model = CostModel::new(workers);
    let mut pairs_checked = 0usize;
    for (name, m) in &matrices {
        let f = MatrixFeatures::of(m);
        let predicted: Vec<f64> = candidates
            .iter()
            .map(|s| model.predict(&f, s).unwrap())
            .collect();
        let actual: Vec<f64> = candidates
            .iter()
            .map(|s| {
                let t = SolvePlan::parse(s).unwrap().apply(m);
                plan_cost(
                    t.stats.levels_after,
                    t.stats.total_level_cost_after as f64,
                    m.nrows,
                    workers,
                )
            })
            .collect();
        for a in 0..candidates.len() {
            for b in (a + 1)..candidates.len() {
                let (lo, hi) = if actual[a] < actual[b] { (a, b) } else { (b, a) };
                if actual[hi] < actual[lo] * 1.3 {
                    continue; // near-tie: the race decides, not the model
                }
                pairs_checked += 1;
                assert!(
                    predicted[lo] < predicted[hi],
                    "{name}: model ranks {} ({:.0}) above {} ({:.0}) but measured \
                     order is {:.0} vs {:.0}",
                    candidates[hi],
                    predicted[hi],
                    candidates[lo],
                    predicted[lo],
                    actual[lo],
                    actual[hi]
                );
            }
        }
    }
    assert!(pairs_checked >= 3, "only {pairs_checked} decisive pairs");
}

#[test]
fn auto_strategy_end_to_end_through_service() {
    let svc = Service::start(Config {
        workers: 2,
        // config default, no per-register override
        plan: PlanSpec::parse("auto").unwrap(),
        use_xla: false,
        batch_size: 4,
        batch_deadline_us: 200,
        ..Default::default()
    });
    let h = svc.handle();
    let lung = generate::lung2_like(&GenOptions::with_scale(0.02));
    let tri = generate::tridiagonal(300, &Default::default());
    let n = lung.nrows;

    let i1 = h.register("lung", lung.clone(), PlanSpec::Default).unwrap();
    assert_eq!(i1.tuner_cache_hit, Some(false));
    // The decision crossing the service boundary is a parseable plan.
    SolvePlan::parse(&i1.plan).unwrap();
    let i2 = h
        .register("lung-again", lung.clone(), PlanSpec::Default)
        .unwrap();
    assert_eq!(i2.tuner_cache_hit, Some(true));
    assert_eq!(i2.plan, i1.plan);
    let i3 = h.register("tri", tri.clone(), PlanSpec::Default).unwrap();
    assert_eq!(i3.tuner_cache_hit, Some(false));

    let mut rng = Rng::new(17);
    for id in ["lung", "lung-again"] {
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = h.solve(id, b.clone()).unwrap();
        assert!(lung.residual_inf(&x, &b) < 1e-9, "{id}");
    }
    let b = vec![2.0; 300];
    let x = h.solve("tri", b.clone()).unwrap();
    assert!(tri.residual_inf(&x, &b) < 1e-9);

    let snap = h.metrics().unwrap();
    assert_eq!(snap.tuner_cache_hits, 1);
    assert_eq!(snap.tuner_cache_misses, 2);
    let total_wins: u64 = snap.plan_wins.iter().map(|(_, n)| n).sum();
    assert_eq!(total_wins, 3);
    assert!(snap.to_string().contains("tuner cache hit/miss=1/2"));
    svc.shutdown();
}

#[test]
fn auto_plans_solve_correctly_on_random_structures() {
    for seed in 0..3u64 {
        let m = generate::random_lower(
            250,
            4,
            0.85,
            &GenOptions {
                seed,
                ..Default::default()
            },
        );
        let mut tuner = Tuner::new(quick_opts());
        let plan = tuner.choose(&m).unwrap();
        plan.transform.validate(&m).unwrap();
        let mut rng = Rng::new(seed + 1000);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        let solver = sptrsv_gt::solver::executor::TransformedSolver::new(
            std::sync::Arc::new(m.clone()),
            plan.transform,
            std::sync::Arc::new(sptrsv_gt::solver::pool::Pool::new(2)),
        );
        let x = solver.solve(&b);
        sptrsv_gt::util::prop::assert_allclose(&x, &x_ref, 1e-9, 1e-11)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn cross_product_portfolio_prices_every_pair() {
    use std::sync::Arc;

    let m = generate::tridiagonal(300, &Default::default());
    let mut tuner = Tuner::new(quick_opts());
    let p = tuner.choose(&m).unwrap();
    let names: Vec<&str> = p.predictions.iter().map(|(s, _)| s.as_str()).collect();
    // The whole portfolio is priced (none dropped as unknown): the 12
    // non-scheduled cross-product members plus the scheduled members
    // expanded into the configured shape neighborhood.
    let shapes = sptrsv_gt::tuner::sched_shape_neighborhood(&Default::default()).len();
    assert_eq!(names.len(), 12 + 4 * shapes, "{names:?}");
    for s in ["avgcost+syncfree", "guarded:20+reorder", "none+scheduled:256:4"] {
        assert!(names.contains(&s), "{s} missing from {names:?}");
    }
    // A pure serial chain is the coarsened schedule's home game: the
    // composed cost model must rank a scheduled plan first (chains
    // collapse into blocks with no barriers and no cross-worker waits).
    assert!(
        names[0].contains("+scheduled"),
        "expected a scheduled plan first, got {}",
        names[0]
    );
    // Whatever the race measured fastest, the tuned plan must solve
    // correctly on the backend its exec axis calls for.
    let solver = sptrsv_gt::solver::ExecSolver::build(
        Arc::new(m.clone()),
        p.transform,
        &p.plan.exec,
        Arc::new(sptrsv_gt::solver::pool::Pool::new(2)),
        Default::default(),
    )
    .unwrap();
    let b = vec![1.0; 300];
    let x = solver.solve(&b);
    assert!(m.residual_inf(&x, &b) < 1e-9);
}

/// Acceptance: the race over a (pruned) cross product returns a composed
/// plan when one wins on a thin-level matrix, and the winner solves
/// correctly on its composed backend.
#[test]
fn race_returns_a_composed_plan_when_one_wins() {
    use std::sync::Arc;

    let m = generate::lung2_like(&GenOptions::with_scale(0.03));
    // A candidate set where every lane is composed: whichever wins, the
    // tuner must hand back a two-axis plan (rewrite != none AND a
    // non-levelset backend) — unreachable through the old fused enum.
    let mut tuner = Tuner::new(TunerOptions {
        candidates: vec![
            "avgcost+scheduled".to_string(),
            "avgcost+syncfree".to_string(),
        ],
        top_k: 2,
        race_solves: 1,
        workers: 2,
        ..Default::default()
    });
    let p = tuner.choose(&m).unwrap();
    assert_eq!(p.source, PlanSource::Raced);
    assert!(matches!(p.plan.rewrite, Rewrite::AvgLevelCost(_)));
    assert!(matches!(p.plan.exec, Exec::Scheduled(_) | Exec::Syncfree));
    assert!(p.transform.stats.rows_rewritten > 0, "rewrite axis ran");
    let solver = sptrsv_gt::solver::ExecSolver::build(
        Arc::new(m.clone()),
        p.transform,
        &p.plan.exec,
        Arc::new(sptrsv_gt::solver::pool::Pool::new(2)),
        Default::default(),
    )
    .unwrap();
    let mut rng = Rng::new(42);
    let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let x = solver.solve(&b);
    let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
    sptrsv_gt::util::prop::assert_allclose(&x, &x_ref, 1e-9, 1e-11).unwrap();
}
