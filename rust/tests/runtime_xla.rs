//! XLA runtime integration: requires `make artifacts` (the tests skip
//! with a note when artifacts are missing, so `cargo test` stays green in
//! a fresh checkout; `make test` always builds artifacts first).

use std::path::Path;
use std::sync::Arc;

use sptrsv_gt::runtime::{PaddedSystem, Registry, XlaSolver};
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::SolvePlan;
use sptrsv_gt::util::prop::assert_allclose;
use sptrsv_gt::util::rng::Rng;

fn registry() -> Option<Arc<Registry>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping XLA test: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Registry::load(&dir).expect("load registry")))
}

#[test]
fn xla_solve_matches_serial_transformed() {
    let Some(reg) = registry() else { return };
    let solver = XlaSolver::new(Arc::clone(&reg));
    for (name, m) in [
        ("lung2", generate::lung2_like(&GenOptions::with_scale(0.02))),
        ("tridiagonal", generate::tridiagonal(500, &Default::default())),
    ] {
        for strat in ["none", "avgcost"] {
            let t = SolvePlan::parse(strat).unwrap().apply(&m);
            let req = PaddedSystem::requirements(&m, &t);
            let Some(meta) = reg.best_fit("solve", &req) else {
                eprintln!("skip {name}/{strat}: no fit for {req:?}");
                continue;
            };
            let p = PaddedSystem::build(&m, &t, meta.pad_shape()).unwrap();
            let mut rng = Rng::new(9);
            let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let x = solver.solve(&p, &b).unwrap();
            let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
            assert_allclose(&x, &x_ref, 1e-9, 1e-11)
                .unwrap_or_else(|e| panic!("{name}/{strat}: {e}"));
        }
    }
}

#[test]
fn xla_batched_solve() {
    let Some(reg) = registry() else { return };
    let solver = XlaSolver::new(Arc::clone(&reg));
    let m = generate::lung2_like(&GenOptions::with_scale(0.02));
    let t = SolvePlan::parse("avgcost").unwrap().apply(&m);
    // The batched artifact is exact-shape; fit against the batch entry.
    let req = PaddedSystem::requirements(&m, &t);
    let meta = reg
        .metas
        .iter()
        .find(|a| a.entry == "solve_batched" && a.fits(&req))
        .expect("batched artifact fits");
    let bsz = meta.b.unwrap();
    let p = PaddedSystem::build(&m, &t, meta.pad_shape()).unwrap();
    let mut rng = Rng::new(4);
    let bs: Vec<Vec<f64>> = (0..bsz)
        .map(|_| (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    let xs = solver.solve_batched(&p, &bs).unwrap();
    assert_eq!(xs.len(), bsz);
    for (b, x) in bs.iter().zip(&xs) {
        let x_ref = sptrsv_gt::solver::serial::solve(&m, b);
        assert_allclose(x, &x_ref, 1e-9, 1e-11).unwrap();
    }
}

#[test]
fn xla_residual_graph() {
    let Some(reg) = registry() else { return };
    let solver = XlaSolver::new(Arc::clone(&reg));
    let m = generate::lung2_like(&GenOptions::with_scale(0.02));
    let t = SolvePlan::parse("avgcost").unwrap().apply(&m);
    let meta = reg
        .metas
        .iter()
        .find(|a| a.entry == "residual" && a.fits(&PaddedSystem::requirements(&m, &t)))
        .expect("residual artifact");
    let p = PaddedSystem::build(&m, &t, meta.pad_shape()).unwrap();
    let mut rng = Rng::new(5);
    let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let x = sptrsv_gt::solver::serial::solve(&m, &b);
    // Residual of the true solution ~ 0; of a corrupted one, large.
    // (Measured against the TRANSFORMED system's b' = W b.)
    let r_good = solver.residual(&p, &b, &x).unwrap();
    assert!(r_good < 1e-9, "{r_good}");
    let mut x_bad = x.clone();
    x_bad[0] += 1.0;
    let r_bad = solver.residual(&p, &b, &x_bad).unwrap();
    assert!(r_bad > 1e-3, "{r_bad}");
}

#[test]
fn coordinator_uses_xla_backend() {
    let Some(_reg) = registry() else { return };
    use sptrsv_gt::config::Config;
    use sptrsv_gt::coordinator::Service;
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let svc = Service::start(Config {
        workers: 2,
        use_xla: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        batch_size: 4,
        batch_deadline_us: 200,
        ..Default::default()
    });
    let h = svc.handle();
    let m = generate::lung2_like(&GenOptions::with_scale(0.02));
    let info = h
        .register("lung", m.clone(), sptrsv_gt::transform::PlanSpec::Default)
        .unwrap();
    assert_eq!(info.backend, "xla");
    let b = vec![1.0; m.nrows];
    let x = h.solve("lung", b.clone()).unwrap();
    assert!(m.residual_inf(&x, &b) < 1e-9);
    svc.shutdown();
}
