//! End-to-end: the whole pipeline exactly as the e2e example runs it,
//! asserted for CI — generator -> coordinator -> (XLA | native) backend ->
//! batched solves -> residual checks -> metrics.

use std::time::Duration;

use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::{Lane, Service, SolveOptions};
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::PlanSpec;
use sptrsv_gt::util::rng::Rng;

#[test]
fn mixed_workload_end_to_end() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_xla = artifacts.join("manifest.json").exists();
    let svc = Service::start(Config {
        workers: 2,
        plan: PlanSpec::parse("avgcost").unwrap(),
        use_xla,
        artifacts_dir: artifacts.to_str().unwrap().to_string(),
        batch_size: 8,
        batch_deadline_us: 500,
        ..Default::default()
    });
    let h = svc.handle();

    let lung = generate::lung2_like(&GenOptions::with_scale(0.02));
    let torso = generate::torso2_like(&GenOptions::with_scale(0.01));
    let tri = generate::tridiagonal(400, &Default::default());
    h.register("lung", lung.clone(), PlanSpec::Default).unwrap();
    h.register("torso", torso.clone(), PlanSpec::Default).unwrap();
    h.register("tri", tri.clone(), PlanSpec::parse("manual:10").unwrap())
        .unwrap();

    let mats: [(&str, &sptrsv_gt::sparse::Csr); 3] =
        [("lung", &lung), ("torso", &torso), ("tri", &tri)];
    let mut rng = Rng::new(77);
    let mut inflight = Vec::new();
    for i in 0..48 {
        let (id, m) = mats[i % 3];
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        // Mixed lanes and a generous deadline, exercising the full v2
        // request path end to end.
        let opts = if i % 5 == 0 {
            SolveOptions::new()
                .priority(Lane::Interactive)
                .deadline(Duration::from_secs(30))
        } else {
            SolveOptions::default()
        };
        inflight.push((id, b.clone(), h.solve_async(id, b, opts).unwrap()));
    }
    for (id, b, ticket) in inflight {
        let x = ticket.wait().unwrap();
        let m = mats.iter().find(|(n, _)| *n == id).unwrap().1;
        let r = m.residual_inf(&x, &b);
        assert!(r < 1e-8, "{id}: residual {r}");
    }

    // A multi-RHS block through the same service, batched as one unit.
    let bs: Vec<Vec<f64>> = (0..8)
        .map(|_| (0..lung.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    let xs = h
        .solve_many("lung", bs.clone(), SolveOptions::default())
        .unwrap()
        .wait()
        .unwrap();
    for (b, x) in bs.iter().zip(&xs) {
        assert!(lung.residual_inf(x, b) < 1e-8);
    }

    let snap = h.metrics().unwrap();
    assert_eq!(snap.solves, 56);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.deadline_misses, 0);
    assert!(snap.batches > 0);
    svc.shutdown();
}
