//! End-to-end: the whole pipeline exactly as the e2e example runs it,
//! asserted for CI — generator -> coordinator -> (XLA | native) backend ->
//! batched solves -> residual checks -> metrics.

use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::Service;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::util::rng::Rng;

#[test]
fn mixed_workload_end_to_end() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_xla = artifacts.join("manifest.json").exists();
    let svc = Service::start(Config {
        workers: 2,
        strategy: "avgcost".into(),
        use_xla,
        artifacts_dir: artifacts.to_str().unwrap().to_string(),
        batch_size: 8,
        batch_deadline_us: 500,
        ..Default::default()
    });
    let h = svc.handle();

    let lung = generate::lung2_like(&GenOptions::with_scale(0.02));
    let torso = generate::torso2_like(&GenOptions::with_scale(0.01));
    let tri = generate::tridiagonal(400, &Default::default());
    h.register("lung", lung.clone(), None).unwrap();
    h.register("torso", torso.clone(), None).unwrap();
    h.register("tri", tri.clone(), Some("manual:10")).unwrap();

    let mats: [(&str, &sptrsv_gt::sparse::Csr); 3] =
        [("lung", &lung), ("torso", &torso), ("tri", &tri)];
    let mut rng = Rng::new(77);
    let mut inflight = Vec::new();
    for i in 0..48 {
        let (id, m) = mats[i % 3];
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        inflight.push((id, b.clone(), h.solve_async(id, b).unwrap()));
    }
    for (id, b, rx) in inflight {
        let x = rx.recv().unwrap().unwrap();
        let m = mats.iter().find(|(n, _)| *n == id).unwrap().1;
        let r = m.residual_inf(&x, &b);
        assert!(r < 1e-8, "{id}: residual {r}");
    }
    let snap = h.metrics().unwrap();
    assert_eq!(snap.solves, 48);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches > 0);
    svc.shutdown();
}
