//! Integration tests for the sharded executor tier: real `shard-worker`
//! child processes (the test binary's own `sptrsv` build, via
//! `CARGO_BIN_EXE_sptrsv`), driven through the public `Service` API.
//!
//! The failure-path tests are the heart: a worker killed mid-serving must
//! resolve its in-flight tickets with `ServiceError::Backend` (never hang
//! them), respawn exactly once, and re-register its roster **warm** from
//! the shard's analysis-cache subdirectory — observable as flat
//! coarsen/placement counters across the crash.

use std::time::{Duration, Instant};

use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::{RegisterOptions, Service, SolveOptions};
use sptrsv_gt::error::ServiceError;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::PlanSpec;

/// A config that serves through two real shard worker processes.
fn sharded_cfg() -> Config {
    Config {
        workers: 1,
        use_xla: false,
        batch_size: 4,
        batch_deadline_us: 500,
        executor: "sharded:2".to_string(),
        // The integration-test harness does not run inside the sptrsv
        // binary, so current_exe() would point at the test runner; name
        // the built CLI explicitly.
        shard_worker_bin: env!("CARGO_BIN_EXE_sptrsv").to_string(),
        shard_timeout_ms: 20_000,
        ..Default::default()
    }
}

fn spec(s: &str) -> PlanSpec {
    PlanSpec::parse(s).unwrap()
}

#[test]
fn sharded_pool_serves_multiple_matrices_and_refreshes() {
    let svc = Service::start(sharded_cfg());
    let h = svc.handle();

    let a = generate::random_lower(80, 3, 0.8, &Default::default());
    let b = generate::tridiagonal(50, &Default::default());
    let ha = h.register("a", a.clone(), spec("avgcost")).unwrap();
    let hb = h.register("b", b.clone(), spec("none")).unwrap();
    assert_eq!(ha.backend, "native");

    let rhs_a = vec![1.0; 80];
    let xa = ha.solve(rhs_a.clone()).unwrap();
    assert!(a.residual_inf(&xa, &rhs_a) < 1e-9);
    let rhs_b = vec![2.0; 50];
    let xb = hb.solve(rhs_b.clone()).unwrap();
    assert!(b.residual_inf(&xb, &rhs_b) < 1e-9);

    // Same-pattern value refresh crosses the wire and sticks.
    let mut a2 = a.clone();
    for v in &mut a2.data {
        *v *= 1.5;
    }
    let info = ha.update_values(a2.clone()).unwrap();
    assert_eq!(info.source.as_str(), "refreshed");
    let xa2 = ha.solve(rhs_a.clone()).unwrap();
    assert!(a2.residual_inf(&xa2, &rhs_a) < 1e-9);

    // Typed errors survive the protocol: unknown id, wrong-length rhs.
    assert!(matches!(
        h.solve("ghost", vec![1.0; 80]),
        Err(ServiceError::NotRegistered(id)) if id == "ghost"
    ));
    assert!(matches!(
        ha.solve(vec![1.0; 3]),
        Err(ServiceError::InvalidRequest(_))
    ));

    // A healthy pool reports structural work but zero shard incidents.
    let snap = h.metrics().unwrap();
    assert!(snap.rewrite_passes >= 1, "avgcost paid a rewrite pass");
    assert_eq!(snap.shard_crashes, 0);
    assert_eq!(snap.shard_respawns, 0);
    svc.shutdown();
}

#[test]
fn killed_worker_resolves_tickets_respawns_once_and_reregisters_warm() {
    let cache = std::env::temp_dir().join(format!(
        "sptrsv_shard_chaos_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&cache).ok();
    let cfg = Config {
        analysis_cache: cache.to_str().unwrap().to_string(),
        // Kill the routed worker right before the first solve dispatch.
        chaos_kill_shard_after: 1,
        ..sharded_cfg()
    };
    let svc = Service::start(cfg);
    let h = svc.handle();

    let m = generate::lung2_like(&GenOptions::with_scale(0.03));
    let n = m.nrows;
    // A scheduled plan pays real coarsening + placement passes, so a cold
    // re-register after the crash would be visible in the counters.
    let handle = h.register("m", m.clone(), spec("avgcost+scheduled")).unwrap();
    let before = h.metrics().unwrap();
    assert!(before.coarsen_passes >= 1, "fresh analysis coarsened");
    assert!(before.placement_passes >= 1, "fresh analysis placed");

    // The chaos hook kills the worker before this dispatch; the ticket
    // must come back as a typed Backend failure, not hang.
    let t = handle
        .solve_async(vec![1.0; n], SolveOptions::default())
        .unwrap();
    match t.wait_timeout(Duration::from_secs(30)) {
        Some(Err(ServiceError::Backend(_))) => {}
        other => panic!("expected Backend failure for the killed shard, got {other:?}"),
    }

    // The supervisor already respawned and re-registered; the next solve
    // lands on the fresh worker and succeeds.
    let rhs = vec![1.0; n];
    let x = handle.solve(rhs.clone()).unwrap();
    assert!(m.residual_inf(&x, &rhs) < 1e-9);

    let after = h.metrics().unwrap();
    assert_eq!(after.shard_crashes, 1, "exactly one crash");
    assert_eq!(after.shard_respawns, 1, "exactly one respawn");
    assert_eq!(after.shard_reregistered, 1, "roster of one re-registered");
    // Warm re-registration from the shard's analysis-cache subdirectory:
    // recovery paid ZERO additional coarsening or placement passes.
    assert_eq!(after.coarsen_passes, before.coarsen_passes, "coarsen flat");
    assert_eq!(
        after.placement_passes, before.placement_passes,
        "placement flat"
    );
    assert_eq!(after.rewrite_passes, before.rewrite_passes, "rewrite flat");

    svc.shutdown();
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn sharded_trace_report_carries_worker_execute_across_respawn() {
    let cfg = Config {
        trace_enabled: true,
        // Kill the routed worker right before the third solve dispatch:
        // two solves land pre-crash, the rest after the respawn.
        chaos_kill_shard_after: 3,
        ..sharded_cfg()
    };
    let svc = Service::start(cfg);
    let h = svc.handle();

    let a = generate::lung2_like(&GenOptions::with_scale(0.03));
    let b = generate::tridiagonal(3000, &Default::default());
    let (na, nb) = (a.nrows, b.nrows);
    let ha = h.register("a", a, spec("avgcost")).unwrap();
    let hb = h.register("b", b, spec("none")).unwrap();

    ha.solve(vec![1.0; na]).unwrap();
    hb.solve(vec![1.0; nb]).unwrap();

    let before = h.trace_report().unwrap();
    let (ba, bb) = (*before.get("a").unwrap(), *before.get("b").unwrap());
    // Execute is measured inside the worker process and carried back on
    // the solve response; a coordinator that never folded worker deltas
    // would report flat zero-execute totals here.
    assert!(ba.execute_us > 0, "worker-sourced execute for 'a': {ba:?}");
    assert!(bb.execute_us > 0, "worker-sourced execute for 'b': {bb:?}");
    assert!(ba.spans >= 1 && bb.spans >= 1, "per-matrix spans attributed");

    // The third dispatch hits the chaos hook; its ticket resolves as a
    // typed Backend failure while the supervisor respawns the shard.
    match ha.solve(vec![1.0; na]) {
        Err(ServiceError::Backend(_)) => {}
        other => panic!("expected Backend failure from the killed shard, got {other:?}"),
    }

    // Post-respawn traffic lands on a fresh worker whose own cumulative
    // counters restart at zero; the supervisor's retirement bookkeeping
    // must keep the folded report monotone — pre-crash spans stay
    // counted, new worker deltas keep accumulating.
    for _ in 0..3 {
        ha.solve(vec![1.0; na]).unwrap();
        hb.solve(vec![1.0; nb]).unwrap();
    }
    let after = h.trace_report().unwrap();
    let (aa, ab) = (*after.get("a").unwrap(), *after.get("b").unwrap());
    assert!(aa.execute_us > ba.execute_us, "'a' execute grew past the respawn");
    assert!(ab.execute_us > bb.execute_us, "'b' execute grew past the respawn");
    assert!(aa.spans >= ba.spans + 3, "no 'a' spans lost across the respawn");
    assert!(ab.spans >= bb.spans + 3, "no 'b' spans lost across the respawn");

    let snap = h.metrics().unwrap();
    assert_eq!(snap.shard_crashes, 1, "exactly one chaos crash");
    assert_eq!(snap.shard_respawns, 1, "exactly one respawn");
    svc.shutdown();
}

#[test]
fn residual_certificates_survive_the_shard_wire() {
    let svc = Service::start(sharded_cfg());
    let h = svc.handle();

    let m = generate::random_lower(120, 3, 0.8, &Default::default());
    let handle = h
        .register_with(
            "pc",
            m.clone(),
            RegisterOptions::new()
                .plan(spec("none+jacobi:2"))
                .default_tolerance(1e-8),
        )
        .unwrap();

    // A toleranced solve through a real worker process: the worker's
    // accuracy ladder certifies the answer, and the achieved residual
    // rides back on the solve response frame into the coordinator's
    // accuracy ledger — a coordinator that dropped the frame's accuracy
    // fields would report zero residual solves here.
    let b = vec![1.0; 120];
    let x = handle.solve(b.clone()).unwrap();
    assert!(m.residual_inf(&x, &b) <= 1e-8);
    let snap = h.metrics().unwrap();
    assert!(snap.residual_solves >= 1, "certified solve counted");
    assert!(
        snap.residual_max <= 1e-8,
        "worst certified residual {:.3e} over the registered bound",
        snap.residual_max
    );

    // A per-request bound tighter than the registered default drives
    // the ladder (escalation or exact fallback) inside the worker; the
    // certificate still crosses back under the tighter bound.
    let x2 = handle
        .solve_with(b.clone(), SolveOptions::new().tolerance(1e-10))
        .unwrap();
    assert!(m.residual_inf(&x2, &b) <= 1e-10);
    let snap2 = h.metrics().unwrap();
    assert!(snap2.residual_solves >= 2, "both certificates counted");

    // An impossible bound comes back as the typed accuracy rejection —
    // the protocol preserves the variant, not a stringly Backend error.
    match handle.solve_with(b.clone(), SolveOptions::new().tolerance(1e-300)) {
        Err(ServiceError::AccuracyUnsatisfiable(why)) => {
            assert!(why.contains("tolerance"), "{why}");
        }
        other => panic!("expected AccuracyUnsatisfiable over the wire, got {other:?}"),
    }
    svc.shutdown();
}

#[test]
fn planned_shutdown_drains_workers_without_burning_the_deadline() {
    use sptrsv_gt::exec_tier::{Executor, ShardPoolExecutor};
    let cfg = sharded_cfg();
    let timeout_ms = cfg.shard_timeout_ms;
    let mut pool = ShardPoolExecutor::start(cfg, 2).unwrap();
    let m = generate::random_lower(60, 2, 0.8, &Default::default());
    let b = vec![1.0; 60];
    pool.register("d", m.clone(), &spec("none")).unwrap();
    let out = pool.solve_block("d", &[b.clone()], None).unwrap();
    assert!(m.residual_inf(&out.xs[0], &b) < 1e-9);

    // Drain-based shutdown ends on each worker's bye-ack, so it returns
    // far inside the per-shard `shard_timeout_ms` deadline. A supervisor
    // that never recognized the ack would sit out the full deadline per
    // shard (2 x 20s here) before killing.
    let t = Instant::now();
    pool.shutdown();
    let elapsed = t.elapsed();
    assert!(
        elapsed < Duration::from_millis(timeout_ms / 2),
        "drained shutdown took {elapsed:?}, suspiciously close to the {timeout_ms}ms deadline"
    );
    // Idempotent: a second shutdown (and the eventual Drop) finds every
    // slot already reaped and returns immediately.
    pool.shutdown();
}

#[test]
fn unstartable_pool_degrades_to_in_process_serving() {
    let cfg = Config {
        shard_worker_bin: "/nonexistent/sptrsv-worker".to_string(),
        ..sharded_cfg()
    };
    // make_executor warns and falls back; the service still serves.
    let svc = Service::start(cfg);
    let h = svc.handle();
    let m = generate::tridiagonal(40, &Default::default());
    h.register("t", m.clone(), spec("none")).unwrap();
    let b = vec![1.0; 40];
    let x = h.solve("t", b.clone()).unwrap();
    assert!(m.residual_inf(&x, &b) < 1e-9);
    svc.shutdown();
}
