//! Bench: the warm-start story for persisted analyses — what restoring
//! one from disk costs in the binary `.spa` container versus the legacy
//! JSON format. The binary path exists to make cache hits and shard
//! re-registration near-free, so this bench gates the load-time ratio:
//!
//!     cargo bench --bench artifact_perf
//!     SPTRSV_ARTIFACT_SMOKE=1 cargo bench --bench artifact_perf   # CI: tiny, no gate
//!
//! Full mode requires the binary load to be at least 5x faster than the
//! JSON load on every matrix/plan pair (median of repeated loads, so a
//! single slow page-in does not fail the run); smoke mode reports the
//! sizes and timings without gating. Both modes always assert that the
//! loads skip the structural passes and solve correctly — speed that
//! re-analyzes would be cheating.

use std::time::Instant;

use sptrsv_gt::analysis::{analyze, Analysis, AnalysisFormat, AnalyzeOptions};
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::PlanSpec;
use sptrsv_gt::util::rng::Rng;

/// Median wall time of `reps` loads, in milliseconds.
fn median_load_ms(
    path: &std::path::Path,
    m: &sptrsv_gt::sparse::Csr,
    opts: &AnalyzeOptions,
    reps: usize,
) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let a = Analysis::load(path, m, opts).unwrap();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        let c = a.rebuilds();
        assert_eq!(c.coarsen_passes, 0, "load re-ran coarsening");
        assert_eq!(c.placement_passes, 0, "load re-ran placement");
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let smoke = std::env::var("SPTRSV_ARTIFACT_SMOKE").is_ok_and(|v| v != "0");
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 0.03 } else { 0.3 });
    let workers: usize = std::env::var("SPTRSV_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let reps = if smoke { 3 } else { 9 };
    let opts = AnalyzeOptions {
        workers,
        ..Default::default()
    };
    println!("artifact warm start (scale {scale}, {workers} workers, smoke={smoke})");
    println!(
        "{:<28} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "matrix/plan", "json KiB", "spa KiB", "json ms", "spa ms", "ratio"
    );

    let mats = [
        ("lung2-like", generate::lung2_like(&GenOptions::with_scale(scale))),
        ("torso2-like", generate::torso2_like(&GenOptions::with_scale(scale))),
    ];
    let mut failures = Vec::new();
    for (mname, m) in &mats {
        for plan in ["avgcost+levelset", "avgcost+scheduled"] {
            let a = analyze(m, &PlanSpec::parse(plan).unwrap(), &opts).unwrap();
            let pid = std::process::id();
            let pj = std::env::temp_dir().join(format!("sptrsv_bench_art_{pid}.analysis.json"));
            let pb = std::env::temp_dir().join(format!("sptrsv_bench_art_{pid}.spa"));
            a.save_format(&pj, AnalysisFormat::Json).unwrap();
            a.save_format(&pb, AnalysisFormat::Binary).unwrap();
            let json_kib = std::fs::metadata(&pj).unwrap().len() as f64 / 1024.0;
            let spa_kib = std::fs::metadata(&pb).unwrap().len() as f64 / 1024.0;

            let json_ms = median_load_ms(&pj, m, &opts, reps);
            let spa_ms = median_load_ms(&pb, m, &opts, reps);
            let ratio = json_ms / spa_ms.max(1e-6);

            // Either restored analysis must still solve; take the binary one.
            let loaded = Analysis::load(&pb, m, &opts).unwrap();
            let mut rng = Rng::new(11);
            let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert!(
                m.residual_inf(&loaded.solve(&b), &b) < 1e-8,
                "{mname}/{plan}: binary-loaded solve inaccurate"
            );
            std::fs::remove_file(&pj).ok();
            std::fs::remove_file(&pb).ok();

            println!(
                "{:<28} {:>10.1} {:>10.1} {:>10.2} {:>10.2} {:>7.1}x",
                format!("{mname}/{plan}"),
                json_kib,
                spa_kib,
                json_ms,
                spa_ms,
                ratio
            );
            if !smoke && ratio < 5.0 {
                failures.push(format!(
                    "{mname}/{plan}: binary load only {ratio:.1}x faster \
                     (json {json_ms:.2}ms vs spa {spa_ms:.2}ms; need 5x)"
                ));
            }
        }
    }
    if failures.is_empty() {
        println!("artifact bench OK");
    } else {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
}
