//! Bench: regenerate the Fig 5 / Fig 6 per-level cost series and write
//! the CSVs; times the series computation per strategy.
//!
//!     cargo bench --bench figures
//!     SPTRSV_BENCH_SCALE=1.0 cargo bench --bench figures

use sptrsv_gt::report::figures;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::util::timer::bench;

fn main() {
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let opts = GenOptions::with_scale(scale);
    std::fs::create_dir_all("target/figures").ok();
    println!("== figures bench (scale {scale}) ==\n");
    for (fig, name, m, log, clip) in [
        ("fig5", "lung2-like", generate::lung2_like(&opts), true, None),
        (
            "fig6",
            "torso2-like",
            generate::torso2_like(&opts),
            false,
            Some(8000u64),
        ),
    ] {
        let mm = m.clone();
        bench(&format!("{fig}/{name}/series"), move || {
            std::hint::black_box(figures::series(&mm).len());
        });
        let ss = figures::series(&m);
        let path = format!("target/figures/{fig}_{name}.csv");
        std::fs::write(&path, figures::to_csv(&ss)).unwrap();
        println!("\n{fig} ({name}) -> {path}");
        for s in &ss {
            println!(
                "  {:<14} levels={:<5} avg={:<12.2} max={:<8} {}",
                s.strategy,
                s.level_costs.len(),
                s.avg_level_cost,
                s.max_level_cost,
                figures::sparkline(&s.level_costs, 72, log, clip)
            );
        }
        println!();
    }
}
