//! Bench: solver backends on both evaluation matrices, before and after
//! transformation — the runtime consequence of the barrier reduction the
//! paper's metrics predict (the paper itself reports no runtimes; this is
//! the extra validation layer, see EXPERIMENTS.md).
//!
//! Backends: serial (Algorithm 1), level-set (barriers), sync-free
//! (atomic counters), transformed executor (none/avgcost/manual), and the
//! XLA solve when artifacts fit.

use std::sync::Arc;

use sptrsv_gt::runtime::{PaddedSystem, Registry, XlaSolver};
use sptrsv_gt::solver::executor::TransformedSolver;
use sptrsv_gt::solver::levelset::LevelSetSolver;
use sptrsv_gt::solver::syncfree::SyncFreeSolver;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::SolvePlan;
use sptrsv_gt::util::rng::Rng;
use sptrsv_gt::util::timer::bench;

fn main() {
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let workers: usize = std::env::var("SPTRSV_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let opts = GenOptions::with_scale(scale);
    let registry = Registry::load(std::path::Path::new("artifacts"))
        .ok()
        .map(Arc::new);

    println!("== solvers bench (scale {scale}, {workers} workers) ==\n");
    for (name, m) in [
        ("lung2-like", generate::lung2_like(&opts)),
        ("torso2-like", generate::torso2_like(&opts)),
    ] {
        let n = m.nrows;
        let mut rng = Rng::new(13);
        let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        println!("-- {name}: {} rows, {} nnz --", n, m.nnz());

        {
            let (m, b) = (m.clone(), b.clone());
            let mut x = vec![0.0; n];
            bench(&format!("{name}/serial"), move || {
                sptrsv_gt::solver::serial::solve_into(&m, &b, &mut x);
            });
        }
        {
            let s = LevelSetSolver::from_matrix(m.clone(), workers);
            let b = b.clone();
            let mut x = vec![0.0; n];
            println!("   (levelset barriers: {})", s.num_barriers());
            bench(&format!("{name}/levelset"), move || {
                s.solve_into(&b, &mut x);
            });
        }
        {
            // Busy-waiting threads beyond the physical cores livelock the
            // scheduler; cap sync-free at the real parallelism (its whole
            // premise is thousands of hardware threads — see paper §V).
            let cores = std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1);
            let s = SyncFreeSolver::from_matrix(m.clone(), workers.min(cores));
            let b = b.clone();
            let mut x = vec![0.0; n];
            bench(&format!("{name}/syncfree"), move || {
                s.solve_into(&b, &mut x);
            });
        }
        for strat in ["none", "avgcost", "manual"] {
            let t = SolvePlan::parse(strat).unwrap().apply(&m);
            let s = TransformedSolver::from_parts(m.clone(), t, workers);
            let b = b.clone();
            let mut x = vec![0.0; n];
            println!("   (transformed/{strat} barriers: {})", s.num_barriers());
            bench(&format!("{name}/transformed/{strat}"), move || {
                s.solve_into(&b, &mut x);
            });
        }
        if let Some(reg) = &registry {
            let t = SolvePlan::parse("avgcost").unwrap().apply(&m);
            let req = PaddedSystem::requirements(&m, &t);
            if let Some(meta) = reg.best_fit("solve", &req) {
                let p = PaddedSystem::build(&m, &t, meta.pad_shape()).unwrap();
                let solver = XlaSolver::new(Arc::clone(reg));
                let b = b.clone();
                bench(&format!("{name}/xla/avgcost"), move || {
                    std::hint::black_box(solver.solve(&p, &b).unwrap());
                });
            } else {
                println!("   (xla: no artifact fits {req:?})");
            }
        }
        println!();
    }
}
