//! Bench: the strategy-portfolio autotuner against every fixed strategy
//! on the three built-in generator families.
//!
//!     cargo bench --bench tuner_perf
//!     SPTRSV_BENCH_SCALE=0.2 SPTRSV_BENCH_WORKERS=8 cargo bench --bench tuner_perf
//!
//! For each matrix the harness measures the per-solve time of each fixed
//! strategy, then lets `auto` decide (cost model + race + plan cache) and
//! measures the tuned plan the same way. `auto` must land within 5% of
//! the best fixed strategy; a second `choose` on the same structure must
//! come from the plan cache.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sptrsv_gt::solver::executor::TransformedSolver;
use sptrsv_gt::solver::pool::Pool;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::sparse::Csr;
use sptrsv_gt::transform::{SolvePlan, TransformResult};
use sptrsv_gt::tuner::{PlanSource, Tuner, TunerOptions};
use sptrsv_gt::util::rng::Rng;
use sptrsv_gt::util::timer::Table;

const FIXED: [&str; 4] = ["none", "avgcost", "manual:10", "guarded:20"];

/// Best-of-N wall-clock (µs) of `solve` within a fixed budget.
fn best_of(mut solve: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    let budget = Duration::from_millis(250);
    let start = Instant::now();
    let mut iters = 0u32;
    while start.elapsed() < budget || iters < 5 {
        let s0 = Instant::now();
        solve();
        best = best.min(s0.elapsed().as_secs_f64() * 1e6);
        iters += 1;
        if iters >= 10_000 {
            break;
        }
    }
    best
}

/// Best-of-N per-solve time (µs) of a prepared plan, on a shared pool.
fn measure_us(m: &Arc<Csr>, t: TransformResult, pool: &Arc<Pool>, b: &[f64]) -> f64 {
    let solver = TransformedSolver::new(Arc::clone(m), Arc::new(t), Arc::clone(pool));
    let mut x = vec![0.0; m.nrows];
    solver.solve_into(b, &mut x); // warm-up
    best_of(|| solver.solve_into(b, &mut x))
}

fn main() {
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let workers: usize = std::env::var("SPTRSV_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let opts = GenOptions::with_scale(scale);
    let n_tri = ((4000.0 * scale).round() as usize).max(200);

    println!("== tuner bench (scale {scale}, {workers} workers) ==\n");
    let mut failures = 0usize;
    for (name, m) in [
        ("lung2-like", generate::lung2_like(&opts)),
        ("torso2-like", generate::torso2_like(&opts)),
        ("tridiagonal", generate::tridiagonal(n_tri, &opts)),
    ] {
        println!("-- {name}: {} rows, {} nnz --", m.nrows, m.nnz());
        let mc = Arc::new(m);
        let pool = Arc::new(Pool::new(workers));
        let mut rng = Rng::new(0x7E57_BE11C);
        let b: Vec<f64> = (0..mc.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();

        let mut table = Table::new(&["plan", "levels", "solve (us)", "vs best"]);
        let mut best_fixed = f64::INFINITY;
        let mut rows: Vec<(String, usize, f64)> = Vec::new();
        for s in FIXED {
            let t = SolvePlan::parse(s).unwrap().apply(&mc);
            let levels = t.num_levels();
            let us = measure_us(&mc, t, &pool, &b);
            best_fixed = best_fixed.min(us);
            rows.push((s.to_string(), levels, us));
        }

        let mut tuner = Tuner::new(TunerOptions {
            workers,
            // Race a wider shortlist than the serving default: the bench
            // asserts a 5% window, so give the model's runner-up a lane.
            top_k: 3,
            ..Default::default()
        });
        let plan = tuner.choose_arc(&mc).unwrap();
        let auto_label = format!("auto -> {}", plan.plan_name);
        let auto_levels = plan.transform.num_levels();
        // Time the tuned plan on the backend its exec axis actually
        // uses (a scheduled/syncfree/reordered winner would misprice on
        // the level-set executor).
        let auto_solver = sptrsv_gt::solver::ExecSolver::build(
            Arc::clone(&mc),
            Arc::clone(&plan.transform),
            &plan.plan.exec,
            Arc::clone(&pool),
            Default::default(),
        )
        .unwrap();
        let mut x = vec![0.0; mc.nrows];
        auto_solver.solve_into(&b, &mut x); // warm-up
        let auto_us = best_of(|| auto_solver.solve_into(&b, &mut x));
        rows.push((auto_label, auto_levels, auto_us));

        for (s, levels, us) in &rows {
            table.row(&[
                s.clone(),
                levels.to_string(),
                format!("{us:.1}"),
                format!("{:.2}x", us / best_fixed),
            ]);
        }
        print!("{}", table.render());

        // Acceptance: auto within 5% of the best fixed strategy (plus a
        // microsecond of absolute slack for timer noise on tiny solves).
        let ok = auto_us <= best_fixed * 1.05 + 1.0;
        println!(
            "auto {:.1}us vs best fixed {:.1}us -> {}",
            auto_us,
            best_fixed,
            if ok { "PASS (within 5%)" } else { "FAIL (worse than 5%)" }
        );
        if !ok {
            failures += 1;
        }

        // Re-choosing the same structure must hit the plan cache.
        let again = tuner.choose_arc(&mc).unwrap();
        assert_eq!(again.source, PlanSource::CacheHit);
        let (hits, misses) = tuner.cache_stats();
        println!("plan cache: hits={hits} misses={misses}\n");
    }
    if failures > 0 {
        eprintln!("{failures} matrix family(ies) exceeded the 5% window");
        std::process::exit(1);
    }
    println!("tuner bench OK: auto within 5% of best fixed everywhere");
}
