//! Bench: the analyze/execute split's amortization story — what one
//! full analysis costs versus the reuse paths that replace it:
//!
//! * `analyze`        — the one-time structural cost (rewrite +
//!   coarsening + placement + backend build)
//! * `refresh_values` — the same-pattern value-update path (numeric
//!   replay only; the dominant scenario in preconditioned iterative
//!   solves)
//! * `load`           — restoring a persisted analysis from disk
//! * `solve`          — one execution, for scale
//!
//!     cargo bench --bench analysis
//!     SPTRSV_ANALYSIS_SMOKE=1 cargo bench --bench analysis   # CI: tiny, no gate
//!
//! Full mode enforces the acceptance shape: `refresh_values` must not
//! re-pay the structural passes (counter-asserted, always) and must be
//! cheaper than a from-scratch `analyze` on the scheduled plans, where
//! skipping coarsening + placement is the whole point (generous slack
//! for timer noise; smoke mode reports timings without gating).

use std::time::Instant;

use sptrsv_gt::analysis::{analyze, Analysis, AnalyzeOptions};
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::PlanSpec;
use sptrsv_gt::util::rng::Rng;

fn main() {
    let smoke = std::env::var("SPTRSV_ANALYSIS_SMOKE").is_ok_and(|v| v != "0");
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 0.03 } else { 0.2 });
    let workers: usize = std::env::var("SPTRSV_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let opts = AnalyzeOptions {
        workers,
        ..Default::default()
    };
    println!("analysis amortization (scale {scale}, {workers} workers, smoke={smoke})");
    println!(
        "{:<28} {:>12} {:>12} {:>12} {:>12}",
        "matrix/plan", "analyze ms", "refresh ms", "load ms", "solve us"
    );

    let mats = [
        ("lung2-like", generate::lung2_like(&GenOptions::with_scale(scale))),
        (
            "tridiagonal",
            generate::tridiagonal(if smoke { 2_000 } else { 40_000 }, &Default::default()),
        ),
    ];
    let mut failures = Vec::new();
    for (mname, m) in &mats {
        for plan in ["avgcost+levelset", "avgcost+scheduled", "manual:10+scheduled"] {
            let spec = PlanSpec::parse(plan).unwrap();

            let t0 = Instant::now();
            let mut a = analyze(m, &spec, &opts).unwrap();
            let analyze_ms = t0.elapsed().as_secs_f64() * 1e3;

            // Same-pattern value perturbation -> refresh.
            let mut m2 = m.clone();
            let mut rng = Rng::new(7);
            for v in &mut m2.data {
                *v *= 1.0 + 0.05 * rng.uniform(-1.0, 1.0);
            }
            let before = a.rebuilds();
            let t0 = Instant::now();
            a.refresh_values(&m2).unwrap();
            let refresh_ms = t0.elapsed().as_secs_f64() * 1e3;
            let after = a.rebuilds();
            assert_eq!(after.coarsen_passes, before.coarsen_passes, "{mname}/{plan}");
            assert_eq!(after.placement_passes, before.placement_passes, "{mname}/{plan}");
            assert_eq!(after.rewrite_passes, before.rewrite_passes, "{mname}/{plan}");

            // Persist + reload.
            let path = std::env::temp_dir().join(format!(
                "sptrsv_bench_analysis_{}.json",
                std::process::id()
            ));
            a.save(&path).unwrap();
            let t0 = Instant::now();
            let loaded = Analysis::load(&path, &m2, &opts).unwrap();
            let load_ms = t0.elapsed().as_secs_f64() * 1e3;
            std::fs::remove_file(&path).ok();
            assert_eq!(loaded.rebuilds().coarsen_passes, 0, "{mname}/{plan}");
            assert_eq!(loaded.rebuilds().placement_passes, 0, "{mname}/{plan}");

            let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let t0 = Instant::now();
            let x = a.solve(&b);
            let solve_us = t0.elapsed().as_secs_f64() * 1e6;
            assert!(
                m2.residual_inf(&x, &b) < 1e-8,
                "{mname}/{plan}: refreshed solve inaccurate"
            );

            println!(
                "{:<28} {:>12.2} {:>12.2} {:>12.2} {:>12.1}",
                format!("{mname}/{plan}"),
                analyze_ms,
                refresh_ms,
                load_ms,
                solve_us
            );
            // Timing gate (full mode, scheduled plans only): the refresh
            // must beat re-analyzing, with wide slack for timer noise.
            if !smoke && plan.contains("scheduled") && refresh_ms > analyze_ms * 1.25 + 2.0 {
                failures.push(format!(
                    "{mname}/{plan}: refresh {refresh_ms:.2}ms vs analyze {analyze_ms:.2}ms"
                ));
            }
        }
    }
    if failures.is_empty() {
        println!("analysis bench OK");
    } else {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
}
