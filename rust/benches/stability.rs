//! Bench: the §IV numerical-stability observation — rewriting distance vs
//! folded-constant magnitude vs forward error, on an ill-scaled matrix
//! (Fig 3 middle's exploding constants, quantified).

use sptrsv_gt::solver::validate;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::SolvePlan;
use sptrsv_gt::util::rng::Rng;
use sptrsv_gt::util::timer::{bench, Table};

fn main() {
    let n: usize = std::env::var("SPTRSV_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let opts = GenOptions {
        ill_scaled: true,
        scale: 1.0,
        seed: 7,
    };
    let m = generate::tridiagonal(n, &opts);
    let mut rng = Rng::new(1);
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();

    println!("== stability bench (ill-scaled tridiagonal, n = {n}) ==\n");
    let mut table = Table::new(&[
        "distance",
        "levels",
        "max |const|",
        "forward err",
        "residual",
    ]);
    for d in [2usize, 3, 5, 10, 20, 50, 100, n / 4] {
        let strat = SolvePlan::parse(&format!("manual:{d}")).unwrap();
        let t = strat.apply(&m);
        let q = validate::assess(&m, &t, &b);
        table.row(&[
            d.to_string(),
            t.num_levels().to_string(),
            format!("{:.3e}", q.max_bcoeff_magnitude),
            format!("{:.3e}", q.forward_error),
            format!("{:.3e}", q.residual_inf),
        ]);
        let (m2, s2) = (m.clone(), strat);
        bench(&format!("transform/manual:{d}"), move || {
            std::hint::black_box(s2.apply(&m2).stats.rows_rewritten);
        });
    }
    println!("\n{}", table.render());
    println!("expectation (paper §IV): |const| and error grow with distance;");
    println!("a magnitude guard (RowConstraints::max_bcoeff_magnitude) caps it.");

    // The guard ablation: avgcost needs thin-vs-fat contrast, so use the
    // same ill-scaled chain behind a fat head and compare unguarded vs
    // magnitude-guarded rewriting.
    use sptrsv_gt::sparse::generate::{from_level_plan, LevelPlan};
    let mut widths = vec![4000usize];
    widths.extend(std::iter::repeat(1).take(n.min(1000)));
    let m2 = from_level_plan(&LevelPlan { widths }, &opts, |_, _, _| 0, 0.0);
    let b2: Vec<f64> = (0..m2.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
    for (label, guard) in [("unguarded", None), ("guarded@1e12", Some(1e12))] {
        let o = sptrsv_gt::transform::avg_cost::AvgCostOptions {
            constraints: sptrsv_gt::transform::row_strategies::RowConstraints {
                max_bcoeff_magnitude: guard,
                ..Default::default()
            },
            ..Default::default()
        };
        let t = sptrsv_gt::transform::avg_cost::apply(&m2, &o);
        let q = validate::assess(&m2, &t, &b2);
        println!(
            "avgcost {label:<13} levels {:>5}, rewritten {:>5}, max |const| {:.3e}, forward err {:.3e}",
            t.num_levels(),
            t.stats.rows_rewritten,
            q.max_bcoeff_magnitude,
            q.forward_error
        );
    }
}
