//! Bench: regenerate Table I (both matrices, all strategies) and time
//! each transformation.
//!
//!     cargo bench --bench table1                 # scale 0.25 default
//!     SPTRSV_BENCH_SCALE=1.0 cargo bench --bench table1   # paper-sized
//!
//! Reduction percentages and cost ratios are scale-robust; the default
//! keeps the bench wall-clock friendly (see EXPERIMENTS.md for a recorded
//! full-scale run).

use sptrsv_gt::report::table1;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::SolvePlan;
use sptrsv_gt::util::timer::bench;

fn scale() -> f64 {
    std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25)
}

fn main() {
    let scale = scale();
    let opts = GenOptions::with_scale(scale);
    println!("== table1 bench (scale {scale}) ==\n");
    for (name, m, paper) in [
        ("lung2-like", generate::lung2_like(&opts), &table1::PAPER_LUNG2),
        ("torso2-like", generate::torso2_like(&opts), &table1::PAPER_TORSO2),
    ] {
        println!("-- {name}: {} rows, {} nnz --", m.nrows, m.nnz());
        // Time each strategy's transform separately.
        for strat in ["avgcost", "manual"] {
            let s = SolvePlan::parse(strat).unwrap();
            let mm = m.clone();
            bench(&format!("transform/{name}/{strat}"), move || {
                let t = s.apply(&mm);
                std::hint::black_box(t.stats.levels_after);
            });
        }
        // And print the actual table (with code sizes).
        let cells = table1::run_matrix(&m, true);
        print!("{}", table1::render(name, &cells, paper));
        println!();
    }
}
