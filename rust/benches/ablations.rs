//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. fixed vs updated avgLevelCost (the paper keeps it fixed — §III);
//! 2. the §III.A row constraints: indegree < α, critical-path-only,
//!    dependency span < β, rewriting-distance cap;
//! 3. manual distance sweep (the grouping granularity of [12]).

use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::avg_cost::{self, AvgCostOptions};
use sptrsv_gt::transform::manual::{self, ManualOptions};
use sptrsv_gt::transform::row_strategies::RowConstraints;
use sptrsv_gt::util::timer::Table;

fn row(
    t: &mut Table,
    name: &str,
    tr: &sptrsv_gt::transform::TransformResult,
    ms: f64,
) {
    t.row(&[
        name.to_string(),
        format!("{} -> {}", tr.stats.levels_before, tr.stats.levels_after),
        format!("{:.1}%", tr.stats.levels_reduction_pct()),
        format!("{:+.2}%", tr.stats.total_cost_change_pct()),
        format!("{} ({:.1}%)", tr.stats.rows_rewritten, tr.stats.rows_rewritten_pct()),
        format!("{}", tr.stats.substitutions_total),
        format!("{ms:.1}"),
    ]);
}

fn main() {
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let opts = GenOptions::with_scale(scale);

    for (name, m) in [
        ("lung2-like", generate::lung2_like(&opts)),
        ("torso2-like", generate::torso2_like(&opts)),
    ] {
        println!(
            "== ablations on {name} (scale {scale}): {} rows ==",
            m.nrows
        );
        let mut table = Table::new(&[
            "variant",
            "levels",
            "reduction",
            "total cost",
            "rows rewritten",
            "substitutions",
            "time (ms)",
        ]);

        let mut run_avg = |label: &str, o: AvgCostOptions| {
            let start = std::time::Instant::now();
            let t = avg_cost::apply(&m, &o);
            let ms = start.elapsed().as_secs_f64() * 1e3;
            t.validate(&m).unwrap();
            row(&mut table, label, &t, ms);
        };

        run_avg("avgcost (paper: fixed avg)", AvgCostOptions::default());
        run_avg(
            "avgcost + updated avg",
            AvgCostOptions {
                update_avg: true,
                ..Default::default()
            },
        );
        for alpha in [2usize, 4, 8] {
            run_avg(
                &format!("avgcost + indegree<{alpha}"),
                AvgCostOptions {
                    constraints: RowConstraints {
                        max_indegree: Some(alpha),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            );
        }
        run_avg(
            "avgcost + critical-path-only",
            AvgCostOptions {
                constraints: RowConstraints {
                    critical_path_only: true,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        for beta in [64u32, 1024] {
            run_avg(
                &format!("avgcost + dep-span<{beta}"),
                AvgCostOptions {
                    constraints: RowConstraints {
                        max_dep_span: Some(beta),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            );
        }
        for dmax in [5u32, 20] {
            run_avg(
                &format!("avgcost + distance<={dmax}"),
                AvgCostOptions {
                    constraints: RowConstraints {
                        max_distance: Some(dmax),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            );
        }
        for d in [5usize, 10, 20] {
            let start = std::time::Instant::now();
            let t = manual::apply(&m, &ManualOptions { distance: d });
            let ms = start.elapsed().as_secs_f64() * 1e3;
            t.validate(&m).unwrap();
            row(&mut table, &format!("manual distance={d}"), &t, ms);
        }
        print!("{}", table.render());
        println!();
    }
}
