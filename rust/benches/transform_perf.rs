//! Bench: transformation throughput — the preprocessing cost the paper
//! worries about ("the cost of the graph transformation process needs to
//! be taken into consideration"). Primary target of the §Perf pass.

use sptrsv_gt::graph::Levels;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::transform::SolvePlan;
use sptrsv_gt::util::timer::bench;

fn main() {
    println!("== transform perf ==\n");
    for scale in [0.05f64, 0.1, 0.25] {
        let opts = GenOptions::with_scale(scale);
        for (name, m) in [
            ("lung2-like", generate::lung2_like(&opts)),
            ("torso2-like", generate::torso2_like(&opts)),
        ] {
            {
                let mm = m.clone();
                bench(&format!("levels/{name}/s{scale}"), move || {
                    std::hint::black_box(Levels::build(&mm).num_levels());
                });
            }
            for strat in ["avgcost", "manual"] {
                let s = SolvePlan::parse(strat).unwrap();
                let mm = m.clone();
                let label = format!(
                    "transform/{name}/s{scale}/{strat} ({} rows)",
                    mm.nrows
                );
                let meas = bench(&label, move || {
                    std::hint::black_box(s.apply(&mm).stats.rows_rewritten);
                });
                // Substitution throughput for the record.
                let t = SolvePlan::parse(strat).unwrap().apply(&m);
                let per_sub = meas.median.as_secs_f64()
                    / t.stats.substitutions_total.max(1) as f64;
                println!(
                    "   -> {} substitutions, {:.1} ns/substitution",
                    t.stats.substitutions_total,
                    per_sub * 1e9
                );
            }
        }
    }
}
