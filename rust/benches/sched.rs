//! Bench gate: scheduled elastic execution against the level-set
//! executor on the generator families the scheduler targets — skewed
//! thin-level (lung2-like), banded, and the pure serial chain
//! (tridiagonal), plus torso2-like as a wide control.
//!
//!     cargo bench --bench sched
//!     SPTRSV_SCHED_SMOKE=1 cargo bench --bench sched   # CI: few iters, no gate
//!     SPTRSV_BENCH_SCALE=0.2 SPTRSV_BENCH_WORKERS=8 cargo bench --bench sched
//!
//! Full mode enforces the acceptance criterion: on the thin-level and
//! serial-chain matrices, scheduled execution must be **no worse than
//! level-set** (small multiplicative + absolute slack for timer noise).
//! Smoke mode runs the identical pipeline — schedule construction,
//! validation, elastic execution, correctness check — with a tiny budget
//! and reports timings without failing on them, so CI exercises the path
//! on every push without gating on shared-runner jitter.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sptrsv_gt::sched::{SchedOptions, ScheduledSolver};
use sptrsv_gt::solver::executor::TransformedSolver;
use sptrsv_gt::solver::pool::Pool;
use sptrsv_gt::sparse::generate::{self, GenOptions};
use sptrsv_gt::sparse::Csr;
use sptrsv_gt::transform::{Rewrite, SolvePlan};
use sptrsv_gt::util::prop::assert_allclose;
use sptrsv_gt::util::rng::Rng;
use sptrsv_gt::util::timer::Table;

/// Best-of-N microseconds of `solve_into` within a wall-clock budget.
fn measure_us(mut solve: impl FnMut(), budget: Duration) -> f64 {
    let mut best = f64::INFINITY;
    let start = Instant::now();
    let mut iters = 0u32;
    while start.elapsed() < budget || iters < 3 {
        let s0 = Instant::now();
        solve();
        best = best.min(s0.elapsed().as_secs_f64() * 1e6);
        iters += 1;
        if iters >= 10_000 {
            break;
        }
    }
    best
}

fn main() {
    let smoke = std::env::var("SPTRSV_SCHED_SMOKE").is_ok_and(|v| v != "0");
    let scale: f64 = std::env::var("SPTRSV_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 0.03 } else { 0.1 });
    let workers: usize = std::env::var("SPTRSV_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let budget = if smoke {
        Duration::from_millis(20)
    } else {
        Duration::from_millis(250)
    };
    let opts = GenOptions::with_scale(scale);
    let n_tri = ((4000.0 * scale).round() as usize).max(200);

    println!(
        "== sched bench (scale {scale}, {workers} workers{}) ==\n",
        if smoke { ", SMOKE" } else { "" }
    );
    // (name, matrix, gated): the gate covers the thin-level and
    // serial-chain families the acceptance criterion names.
    let cases: Vec<(&str, Csr, bool)> = vec![
        ("lung2-like (thin)", generate::lung2_like(&opts), true),
        ("tridiagonal (chain)", generate::tridiagonal(n_tri, &opts), true),
        (
            "banded",
            generate::banded(n_tri, 6, 0.5, &opts),
            false,
        ),
        ("torso2-like (wide)", generate::torso2_like(&opts), false),
    ];

    let mut failures = 0usize;
    let mut table = Table::new(&[
        "matrix", "rows", "levels", "blocks", "cut", "levelset (us)", "sched (us)", "ratio",
    ]);
    for (name, m, gated) in cases {
        let t_ls = Rewrite::None.apply(&m);
        let t_sc = SolvePlan::parse("scheduled").unwrap().apply(&m);
        let levels = t_ls.num_levels();
        let mc = Arc::new(m);
        let pool = Arc::new(Pool::new(workers));
        let mut rng = Rng::new(0x5CED);
        let b: Vec<f64> = (0..mc.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();

        let levelset =
            TransformedSolver::new(Arc::clone(&mc), Arc::new(t_ls), Arc::clone(&pool));
        let sched = ScheduledSolver::new(
            Arc::clone(&mc),
            Arc::new(t_sc),
            Arc::clone(&pool),
            &SchedOptions::default(),
        );
        sched
            .schedule
            .validate(&sched.m, &sched.t)
            .expect("schedule invariants");
        // Correctness first: both executors agree with the serial solver.
        let x_ref = sptrsv_gt::solver::serial::solve(&mc, &b);
        assert_allclose(&levelset.solve(&b), &x_ref, 1e-9, 1e-11).unwrap();
        assert_allclose(&sched.solve(&b), &x_ref, 1e-9, 1e-11).unwrap();

        let mut x = vec![0.0; mc.nrows];
        let ls_us = measure_us(|| levelset.solve_into(&b, &mut x), budget);
        let sc_us = measure_us(|| sched.solve_into(&b, &mut x), budget);
        let st = sched.stats();
        table.row(&[
            name.to_string(),
            mc.nrows.to_string(),
            levels.to_string(),
            st.num_blocks.to_string(),
            st.cut_edges.to_string(),
            format!("{ls_us:.1}"),
            format!("{sc_us:.1}"),
            format!("{:.2}x", sc_us / ls_us),
        ]);

        // Acceptance gate: no worse than level-set, within timer noise.
        let ok = sc_us <= ls_us * 1.05 + 2.0;
        if gated && !smoke && !ok {
            eprintln!("FAIL {name}: scheduled {sc_us:.1}us vs level-set {ls_us:.1}us");
            failures += 1;
        }
    }
    print!("{}", table.render());
    if failures > 0 {
        eprintln!("\n{failures} gated matrix family(ies) regressed vs level-set");
        std::process::exit(1);
    }
    println!(
        "\nsched bench OK{}",
        if smoke {
            " (smoke: timings informational)"
        } else {
            ": scheduled no worse than level-set on gated families"
        }
    );
}
