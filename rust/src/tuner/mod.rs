//! Plan-portfolio autotuner: pick the best solve plan per matrix,
//! automatically, over the full rewrite × exec cross product.
//!
//! The paper closes by noting its results "provide several hints on how
//! to craft a collection of strategies"; this subsystem operationalizes
//! that. Since the solve-plan split, the portfolio is the **cross
//! product** of the rewrite axis (`none | avgcost | manual | guarded`)
//! and the execution axis (`levelset | scheduled | syncfree | reorder`),
//! with each default-shape `scheduled` member expanded into a
//! neighborhood of the configured `sched_block_target` /
//! `sched_stale_window` point ([`expand_exec_knobs`]) — all pruned to a
//! `top_k` shortlist by the composed cost model so the race never runs
//! the full portfolio.
//!
//! Decision path of [`Tuner::choose`]:
//!
//! 1. [`fingerprint`] — hash the sparsity structure; a [`plan_cache`] hit
//!    returns the previously raced winner immediately (analysis cost is
//!    paid once per structure, amortized across re-registrations).
//! 2. [`features`]   — extract the structural feature vector (level
//!    widths, thin-level shares, indegrees, critical path).
//! 3. [`cost_model`] — per-plan cost prediction (rewrite-shape × exec
//!    synchronization model) shortlists the `top_k` candidates; measured
//!    timings continually recalibrate it, and the calibration table is
//!    persisted next to the plan cache ([`calibration`]).
//! 4. [`race`]       — the shortlist runs real transforms + a few warm-up
//!    solves on each plan's own backend; the measured winner becomes the
//!    plan and is cached.

pub mod calibration;
pub mod cost_model;
pub mod features;
pub mod fingerprint;
pub mod plan_cache;
pub mod race;

use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::Error;
use crate::sched::SchedOptions;
use crate::solver::dispatch::ExecSolver;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{Exec, SolvePlan, TransformResult};

pub use cost_model::{CostModel, PlanEstimate};
pub use features::MatrixFeatures;
pub use fingerprint::Fingerprint;
pub use plan_cache::{CachedPlan, PlanCache, PLAN_SCHEMA_VERSION};
pub use race::{RaceOptions, RaceOutcome};

/// Rewrite-axis members of the default portfolio.
pub const DEFAULT_REWRITES: [&str; 4] = ["none", "avgcost", "manual:10", "guarded:20"];

/// Exec-axis members of the default portfolio.
pub const DEFAULT_EXECS: [&str; 4] = ["levelset", "scheduled", "syncfree", "reorder"];

/// Iterative exec-axis members, raced only when an accuracy tolerance is
/// in scope (an inexact backend may not answer a request that never said
/// how wrong it is allowed to be).
pub const ITERATIVE_EXECS: [&str; 2] = ["jacobi:8", "jacobi-mixed:8"];

/// The default candidate portfolio: the full rewrite × exec cross
/// product, in canonical `rewrite+exec` names. The cost model prunes this
/// to `top_k` lanes before anything is raced.
pub fn default_candidates() -> Vec<String> {
    let mut out = Vec::with_capacity(DEFAULT_REWRITES.len() * DEFAULT_EXECS.len());
    for rw in DEFAULT_REWRITES {
        for ex in DEFAULT_EXECS {
            out.push(format!("{rw}+{ex}"));
        }
    }
    out
}

/// The accuracy-gated extension of the portfolio: every rewrite paired
/// with the iterative exec backends. Joined to the candidate set only
/// when the tuner runs under a tolerance ([`TunerOptions::tolerance`]) —
/// the race then disqualifies any lane whose achieved residual misses it.
pub fn iterative_candidates() -> Vec<String> {
    let mut out = Vec::with_capacity(DEFAULT_REWRITES.len() * ITERATIVE_EXECS.len());
    for rw in DEFAULT_REWRITES {
        for ex in ITERATIVE_EXECS {
            out.push(format!("{rw}+{ex}"));
        }
    }
    out
}

/// The schedule shapes the tuner explores for a default-shape `scheduled`
/// candidate: a neighborhood of the configured
/// `(sched_block_target, sched_stale_window)` point — the configured
/// shape itself, half and double the block target, and the flipped
/// elasticity (strict in-order when a window is configured, a small
/// window when it is zero). The knobs travel **inside** the plan name
/// (`scheduled:t:w`), so the cached winner is always served at exactly
/// the shape that won the race.
pub fn sched_shape_neighborhood(sched: &SchedOptions) -> Vec<(usize, usize)> {
    let t = sched.block_target();
    let w = sched.stale_window();
    let mut shapes = vec![
        (t, w),
        ((t / 2).max(1), w),
        (t.saturating_mul(2).max(2), w),
        (t, if w == 0 { 2 } else { 0 }),
    ];
    let mut seen = Vec::new();
    shapes.retain(|s| {
        if seen.contains(s) {
            false
        } else {
            seen.push(*s);
            true
        }
    });
    shapes
}

/// Expand every default-shape `scheduled` candidate (no explicit knobs)
/// into the [`sched_shape_neighborhood`] of the configured scheduling
/// point. Candidates that already carry explicit knobs, and every
/// non-scheduled candidate, pass through unchanged; duplicates are
/// dropped.
pub fn expand_exec_knobs(candidates: &[String], sched: &SchedOptions) -> Vec<String> {
    let shapes = sched_shape_neighborhood(sched);
    let mut out: Vec<String> = Vec::with_capacity(candidates.len() + shapes.len() * 4);
    for name in candidates {
        let expanded = match SolvePlan::parse(name) {
            Ok(plan) => match plan.exec {
                Exec::Scheduled(o) if o.block_target.is_none() && o.stale_window.is_none() => {
                    Some(plan.rewrite)
                }
                _ => None,
            },
            Err(_) => None,
        };
        match expanded {
            Some(rewrite) => {
                for &(t, w) in &shapes {
                    let composed = format!("{rewrite}+scheduled:{t}:{w}");
                    if !out.contains(&composed) {
                        out.push(composed);
                    }
                }
            }
            None => {
                if !out.contains(name) {
                    out.push(name.clone());
                }
            }
        }
    }
    out
}

#[derive(Debug, Clone)]
pub struct TunerOptions {
    /// plan names eligible for selection (`auto` is ignored)
    pub candidates: Vec<String>,
    /// how many cost-model favourites to race empirically (the pruning
    /// that keeps the 16-lane cross product affordable)
    pub top_k: usize,
    /// timed solves per raced candidate
    pub race_solves: usize,
    /// worker threads used by raced solves (and by the cost model's
    /// parallelism term)
    pub workers: usize,
    /// plan cache capacity (entries)
    pub cache_capacity: usize,
    /// JSON spill path; None keeps the cache (and calibration) in memory
    /// only
    pub cache_path: Option<PathBuf>,
    /// seconds before a spilled same-schema plan expires and is dropped
    /// on load (0 = plans never expire by age)
    pub cache_ttl_secs: u64,
    /// scheduling knobs raced `scheduled` candidates run with — the
    /// coordinator passes its config defaults so the race measures the
    /// exact schedule serving would build
    pub sched: crate::sched::SchedOptions,
    /// RHS seed for racing
    pub seed: u64,
    /// worker pool shared with the caller (the serving pipeline threads
    /// its own pool through here); None spawns a throwaway pool per race
    pub pool: Option<Arc<Pool>>,
    /// accuracy constraint for tuning decisions: when set, the iterative
    /// candidates ([`iterative_candidates`]) join the portfolio and every
    /// raced lane must achieve this relative residual or be disqualified;
    /// a cached iterative decision is only reused when its certified
    /// tolerance covers this one. None keeps the portfolio exact.
    pub tolerance: Option<f64>,
    /// right-hand sides per timed race iteration — the coordinator passes
    /// its `batch_size` so candidates are ranked under the RHS block the
    /// serving batcher actually presents
    pub batch: usize,
}

impl Default for TunerOptions {
    fn default() -> Self {
        TunerOptions {
            candidates: default_candidates(),
            top_k: 2,
            race_solves: 3,
            // Match the machine rather than a fixed guess: races measure
            // at the parallelism the solves will actually run with.
            // Callers with a known worker count should still set this.
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
            cache_capacity: 64,
            cache_path: None,
            cache_ttl_secs: 0,
            sched: Default::default(),
            seed: 0x7E57,
            pool: None,
            tolerance: None,
            batch: 1,
        }
    }
}

/// How a [`TunedPlan`] was decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// fingerprint found in the plan cache; no analysis ran
    CacheHit,
    /// cost model shortlisted, race measured
    Raced,
}

/// The tuner's decision for one matrix, ready to serve.
pub struct TunedPlan {
    pub fingerprint: Fingerprint,
    /// winning plan in `SolvePlan::parse` syntax
    pub plan_name: String,
    pub plan: SolvePlan,
    pub source: PlanSource,
    /// structural feature vector; None on a cache hit, where no feature
    /// analysis runs (applying the cached plan still builds its own
    /// level sets — that cost is inherent to producing a transform)
    pub features: Option<MatrixFeatures>,
    /// cost-model predictions, best first (empty on a cache hit)
    pub predictions: Vec<(String, f64)>,
    /// race report (None on a cache hit)
    pub race: Option<RaceOutcome>,
    /// the winning transform, ready for the executor (shared with the
    /// donated solver when one is present)
    pub transform: Arc<TransformResult>,
    /// the race's winning backend, donated instead of discarded: the
    /// analysis layer serves on this very solver, so a cache miss builds
    /// each schedule/permutation exactly once. None on a plan-cache hit
    /// (nothing was raced).
    pub solver: Option<ExecSolver>,
}

pub struct Tuner {
    pub opts: TunerOptions,
    pub model: CostModel,
    pub cache: PlanCache,
}

/// Lazily initialized process-wide tuner backing standalone `auto`
/// resolution (CLI `transform --plan auto`, library callers without a
/// serving pipeline). The old `Strategy::Auto.apply()` built a throwaway
/// tuner — cold cache, default pool — on **every** call, re-racing per
/// invocation; this keeps one warm tuner per process. The coordinator
/// pipeline still holds its own tuner (configured cache path, shared
/// worker pool).
static PROCESS_TUNER: OnceLock<Mutex<Tuner>> = OnceLock::new();

/// Decide a plan for `m` on the shared process-wide tuner (default
/// options, in-memory plan cache). Repeated calls on the same structure
/// hit the cache instead of re-racing.
pub fn process_choose(m: &Csr) -> Result<TunedPlan, Error> {
    PROCESS_TUNER
        .get_or_init(|| Mutex::new(Tuner::new(TunerOptions::default())))
        .lock()
        // A panic inside one tuning run must not brick every later
        // standalone `auto` in the process: the tuner holds no invariant
        // a mid-panic leaves broken (worst case a stale cache entry), so
        // recover the poisoned lock and keep serving.
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .choose(m)
}

impl Tuner {
    pub fn new(mut opts: TunerOptions) -> Tuner {
        // Exec knobs enter the cross product: default-shape `scheduled`
        // candidates expand into the configured scheduling point's
        // neighborhood, so the race explores block-target/window shapes
        // instead of only the config default (the cost model prunes the
        // wider portfolio back down to `top_k` lanes).
        opts.candidates = expand_exec_knobs(&opts.candidates, &opts.sched);
        let mut model = CostModel::new(opts.workers);
        let cache = match &opts.cache_path {
            Some(path) => {
                // Restore the persisted calibration next to the plan
                // cache: restarts keep the refined coefficients, not just
                // the decisions.
                for (plan, mult) in calibration::load(&calibration::path_for(path)) {
                    model.set_calibration(&plan, mult);
                }
                PlanCache::with_disk_ttl(opts.cache_capacity, path, opts.cache_ttl_secs)
            }
            None => PlanCache::new(opts.cache_capacity),
        };
        Tuner { opts, model, cache }
    }

    /// (hits, misses) observed by the plan cache so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Decide a plan for `m`: plan-cache lookup, else cost-model
    /// shortlist + race, then cache the winner.
    ///
    /// This entry point copies the matrix once on a cache miss (the race
    /// lanes share it by Arc); callers that already hold an `Arc<Csr>`
    /// should use [`Tuner::choose_arc`], which never copies.
    pub fn choose(&mut self, m: &Csr) -> Result<TunedPlan, Error> {
        let fingerprint = Fingerprint::of(m);
        if m.nrows == 0 {
            return Ok(self.empty_plan(fingerprint, m));
        }
        if let Some(plan) = self.try_cached(fingerprint, m) {
            return Ok(plan);
        }
        self.tune(&Arc::new(m.clone()), fingerprint)
    }

    /// [`Tuner::choose`] without the defensive copy: the cache-miss race
    /// shares `m` by reference count.
    pub fn choose_arc(&mut self, m: &Arc<Csr>) -> Result<TunedPlan, Error> {
        let fingerprint = Fingerprint::of(m);
        if m.nrows == 0 {
            return Ok(self.empty_plan(fingerprint, m));
        }
        if let Some(plan) = self.try_cached(fingerprint, m) {
            return Ok(plan);
        }
        self.tune(m, fingerprint)
    }

    /// A cached decision's plan name for a fingerprint, without applying
    /// the plan, bumping the LRU recency or counting a hit/miss. The
    /// serving pipeline peeks here so an analysis-cache probe can be
    /// keyed by `(fingerprint, plan)` before any transform work runs.
    pub fn peek_cached_plan(&self, fingerprint: Fingerprint) -> Option<String> {
        self.cache.peek(fingerprint).map(|c| c.plan.clone())
    }

    /// Degenerate (empty) matrix: nothing to tune.
    fn empty_plan(&self, fingerprint: Fingerprint, m: &Csr) -> TunedPlan {
        TunedPlan {
            fingerprint,
            plan_name: "none".to_string(),
            plan: SolvePlan::baseline(),
            source: PlanSource::Raced,
            features: None,
            predictions: Vec::new(),
            race: None,
            transform: Arc::new(TransformResult::identity(m)),
            solver: None,
        }
    }

    /// Plan-cache lookup. An unparseable cached plan (stale format,
    /// hand-edited file) must not brick its fingerprint: warn, return
    /// None so the caller re-tunes, and let the fresh put() overwrite it.
    /// An iterative decision additionally requires its certified
    /// tolerance to cover the current constraint — a `jacobi:8` winner
    /// certified at 1e-6 must not serve a 1e-9 request on cache trust.
    fn try_cached(&mut self, fingerprint: Fingerprint, m: &Csr) -> Option<TunedPlan> {
        let cached = self.cache.get(fingerprint)?;
        match SolvePlan::parse(&cached.plan) {
            Ok(plan) => {
                if plan.exec.is_iterative() {
                    let covered = self
                        .opts
                        .tolerance
                        .is_some_and(|tol| cached.tolerance > 0.0 && cached.tolerance <= tol);
                    if !covered {
                        return None; // re-tune; the fresh put overwrites
                    }
                }
                let transform = Arc::new(plan.apply(m));
                Some(TunedPlan {
                    fingerprint,
                    plan_name: cached.plan,
                    plan,
                    source: PlanSource::CacheHit,
                    features: None,
                    predictions: Vec::new(),
                    race: None,
                    transform,
                    solver: None,
                })
            }
            Err(e) => {
                eprintln!(
                    "warning: tuner plan cache entry for {fingerprint} unusable \
                     ({e}); re-tuning"
                );
                None
            }
        }
    }

    /// Cache-miss path: extract features, shortlist by predicted cost,
    /// race, record, cache. Shortlisting dedups on the
    /// **(exec axis, estimated rewrite shape)** key: two candidates with
    /// the same execution backend whose rewrites are predicted to produce
    /// the same system (e.g. `guarded` degenerating to `avgcost`, or
    /// `none` ≡ `avgcost` on a uniform chain) would race identical
    /// configurations, so only the better-ranked one runs — while the
    /// same rewrite under *different* backends always keeps both lanes.
    fn tune(&mut self, m: &Arc<Csr>, fingerprint: Fingerprint) -> Result<TunedPlan, Error> {
        let features = MatrixFeatures::of(m);
        // Under a tolerance the iterative backends join the portfolio;
        // without one they never race (nothing could certify them).
        let candidates = if self.opts.tolerance.is_some() {
            let mut c = self.opts.candidates.clone();
            for extra in iterative_candidates() {
                if !c.contains(&extra) {
                    c.push(extra);
                }
            }
            c
        } else {
            self.opts.candidates.clone()
        };
        let predictions = self.model.rank(&features, &candidates);
        if predictions.is_empty() {
            return Err(Error::Invalid(
                "tuner: no usable candidate plans".to_string(),
            ));
        }
        let top_k = self.opts.top_k.max(1);
        let mut shortlist: Vec<String> = Vec::with_capacity(top_k);
        let mut seen: Vec<(String, PlanEstimate)> = Vec::with_capacity(top_k);
        for (s, _) in &predictions {
            if shortlist.len() >= top_k {
                break;
            }
            let Some(est) = self.model.estimate(&features, s) else {
                continue;
            };
            // rank() already filtered unparseable names.
            let Ok(plan) = SolvePlan::parse(s) else { continue };
            // The dedup key carries the exec axis *with its knobs*: a
            // `scheduled:64` lane and a `scheduled:256` lane build
            // different schedules even over the same rewrite.
            let exec_key = exec_dedup_key(&plan.exec);
            if seen.iter().any(|(k, e)| *k == exec_key && *e == est) {
                continue;
            }
            seen.push((exec_key, est));
            shortlist.push(s.clone());
        }
        if shortlist.is_empty() {
            shortlist.push(predictions[0].0.clone());
        }
        let race_opts = RaceOptions {
            solves: self.opts.race_solves,
            workers: self.opts.workers,
            seed: self.opts.seed,
            sched: self.opts.sched,
            pool: self.opts.pool.clone(),
            tolerance: self.opts.tolerance,
            batch: self.opts.batch,
        };
        let mut outcome = race::race(m, &shortlist, &race_opts).map_err(Error::Runtime)?;

        // Feed measurements back into the model's calibration, against
        // the UNCALIBRATED prediction (see CostModel::record).
        for lane in &outcome.lanes {
            if let Some(raw) = self.model.predict_raw(&features, &lane.plan) {
                self.model.record(&lane.plan, raw, lane.solve_us);
            }
        }
        // Persist the refreshed calibration next to the plan cache.
        if let Some(cache_path) = &self.opts.cache_path {
            let path = calibration::path_for(cache_path);
            if let Err(e) = calibration::save(&path, self.model.calibration_table()) {
                eprintln!("warning: tuner calibration save failed: {e}");
            }
        }

        let winner = outcome.winner;
        let plan_name = outcome.lanes[winner].plan.clone();
        let plan = SolvePlan::parse(&plan_name).map_err(Error::Invalid)?;
        // Donate the winning lane's already-built artifacts: the
        // transform Arc it raced with and the backend it raced on.
        let transform = Arc::clone(&outcome.lanes[winner].transform);
        let solver = outcome.lanes[winner].solver.take();

        self.cache.put(
            fingerprint,
            CachedPlan {
                plan: plan_name.clone(),
                solve_us: outcome.lanes[winner].solve_us,
                timings: outcome
                    .lanes
                    .iter()
                    .map(|l| (l.plan.clone(), l.solve_us))
                    .collect(),
                nrows: m.nrows,
                created_unix: plan_cache::now_unix(),
                // An iterative winner is certified at the tolerance it
                // raced under; exact winners certify unconditionally.
                tolerance: if plan.exec.is_iterative() {
                    self.opts.tolerance.unwrap_or(0.0)
                } else {
                    0.0
                },
            },
        );

        Ok(TunedPlan {
            fingerprint,
            plan_name,
            plan,
            source: PlanSource::Raced,
            features: Some(features),
            predictions,
            race: Some(outcome),
            transform,
            solver,
        })
    }
}

/// Canonical dedup key for an exec axis, knobs included.
fn exec_dedup_key(exec: &Exec) -> String {
    exec.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn quick_opts() -> TunerOptions {
        TunerOptions {
            race_solves: 1,
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn default_portfolio_is_the_cross_product() {
        let c = default_candidates();
        assert_eq!(c.len(), 16);
        assert!(c.contains(&"avgcost+scheduled".to_string()));
        assert!(c.contains(&"guarded:20+syncfree".to_string()));
        assert!(c.contains(&"none+levelset".to_string()));
        for name in &c {
            SolvePlan::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn tuner_expands_sched_candidates_around_the_configured_shape() {
        let sched = SchedOptions {
            block_target: Some(128),
            stale_window: Some(4),
        };
        let shapes = sched_shape_neighborhood(&sched);
        assert!(shapes.contains(&(128, 4)), "{shapes:?}");
        assert!(shapes.contains(&(64, 4)) && shapes.contains(&(256, 4)), "{shapes:?}");
        assert!(shapes.contains(&(128, 0)), "elasticity flip missing: {shapes:?}");

        let tuner = Tuner::new(TunerOptions {
            sched,
            ..quick_opts()
        });
        let c = &tuner.opts.candidates;
        // Default-shape scheduled members became explicit-knob variants...
        assert!(!c.iter().any(|s| s.ends_with("+scheduled")), "{c:?}");
        assert!(c.contains(&"avgcost+scheduled:128:4".to_string()), "{c:?}");
        assert!(c.contains(&"none+scheduled:64:4".to_string()), "{c:?}");
        // ...every candidate still parses, and the non-scheduled members
        // of the cross product pass through untouched.
        for name in c {
            SolvePlan::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(c.contains(&"guarded:20+syncfree".to_string()));
        assert_eq!(c.len(), 12 + 4 * shapes.len());

        // A candidate that already pins its knobs is not expanded.
        let kept = expand_exec_knobs(&["avgcost+scheduled:32:1".to_string()], &sched);
        assert_eq!(kept, vec!["avgcost+scheduled:32:1".to_string()]);

        // Zero-window configs explore a small window instead.
        let strict = sched_shape_neighborhood(&SchedOptions {
            block_target: Some(64),
            stale_window: Some(0),
        });
        assert!(strict.contains(&(64, 2)), "{strict:?}");
    }

    #[test]
    fn raced_winner_donates_its_transform_and_backend() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let mut tuner = Tuner::new(quick_opts());
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.source, PlanSource::Raced);
        let solver = p.solver.as_ref().expect("winning backend donated");
        // The donated backend matches the winning plan's exec axis and
        // runs the winning transform.
        assert_eq!(solver.scheduled().is_some(), matches!(p.plan.exec, Exec::Scheduled(_)));
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&solver.solve(&b), &b) < 1e-9);
        // A cache hit donates no backend (nothing was raced).
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert!(p2.solver.is_none());
        // peek never disturbs the stats the real lookups accumulated.
        let stats = tuner.cache_stats();
        assert_eq!(tuner.peek_cached_plan(p.fingerprint), Some(p.plan_name.clone()));
        assert_eq!(tuner.cache_stats(), stats);
    }

    #[test]
    fn choose_then_cache_hit() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let mut tuner = Tuner::new(quick_opts());
        let p1 = tuner.choose(&m).unwrap();
        assert_eq!(p1.source, PlanSource::Raced);
        assert!(!p1.predictions.is_empty());
        p1.transform.validate(&m).unwrap();
        // guarded degenerates to avgcost under the estimate, so the
        // shortlist dedup must never race both under one backend.
        let lanes: Vec<&str> = p1
            .race
            .as_ref()
            .unwrap()
            .lanes
            .iter()
            .map(|l| l.plan.as_str())
            .collect();
        assert!(
            !(lanes.contains(&"avgcost+levelset") && lanes.contains(&"guarded:20+levelset")),
            "duplicate plan shapes raced: {lanes:?}"
        );
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert_eq!(p2.plan_name, p1.plan_name);
        assert_eq!(
            p2.transform.stats.levels_after,
            p1.transform.stats.levels_after
        );
        assert_eq!(tuner.cache_stats(), (1, 1));
    }

    #[test]
    fn tridiagonal_chooses_a_barrier_reducing_plan() {
        let m = generate::tridiagonal(300, &Default::default());
        let mut tuner = Tuner::new(quick_opts());
        let p = tuner.choose(&m).unwrap();
        // The model shortlists barrier-reducing plans (manual rewriting
        // or barrier-free execution); whatever wins the race must not be
        // worse than the baseline's 300 levels.
        assert!(p.transform.num_levels() <= 300);
        assert_eq!(p.features.as_ref().map(|f| f.num_levels), Some(300));
    }

    #[test]
    fn unusable_cache_entry_self_heals() {
        let m = generate::tridiagonal(80, &Default::default());
        let mut tuner = Tuner::new(quick_opts());
        tuner.cache.put(
            Fingerprint::of(&m),
            CachedPlan {
                plan: "not-a-plan".to_string(),
                solve_us: 1.0,
                timings: Vec::new(),
                nrows: 80,
                created_unix: plan_cache::now_unix(),
                tolerance: 0.0,
            },
        );
        // The poisoned entry must not brick `auto`: choose re-races and
        // overwrites it.
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.source, PlanSource::Raced);
        p.transform.validate(&m).unwrap();
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert_eq!(p2.plan_name, p.plan_name);
    }

    #[test]
    fn same_rewrite_different_backends_bypass_shape_dedup() {
        // On a tiny chain every rewrite is a no-op, so all candidates
        // share one estimated shape — but different exec axes execute on
        // different backends, so BOTH must reach the race.
        let m = generate::tridiagonal(20, &Default::default());
        // Pinned knobs keep the scheduled candidate out of the shape
        // expansion: this test is about the dedup, not the neighborhood.
        let mut tuner = Tuner::new(TunerOptions {
            candidates: vec!["none+scheduled:256:4".to_string(), "none+syncfree".to_string()],
            top_k: 2,
            race_solves: 1,
            workers: 2,
            ..Default::default()
        });
        let p = tuner.choose(&m).unwrap();
        let lanes: Vec<&str> = p
            .race
            .as_ref()
            .expect("raced")
            .lanes
            .iter()
            .map(|l| l.plan.as_str())
            .collect();
        assert_eq!(lanes.len(), 2, "dedup swallowed a backend: {lanes:?}");
    }

    #[test]
    fn same_backend_same_shape_dedups_across_rewrites() {
        // On a uniform chain avgcost is a predicted no-op: avgcost+X and
        // none+X estimate the same system under the same backend, so only
        // the better-ranked lane races.
        let m = generate::tridiagonal(40, &Default::default());
        let mut tuner = Tuner::new(TunerOptions {
            candidates: vec![
                "none+syncfree".to_string(),
                "avgcost+syncfree".to_string(),
            ],
            top_k: 2,
            race_solves: 1,
            workers: 2,
            ..Default::default()
        });
        let p = tuner.choose(&m).unwrap();
        assert_eq!(
            p.race.as_ref().unwrap().lanes.len(),
            1,
            "duplicate (rewrite shape, backend) lanes raced"
        );
    }

    #[test]
    fn calibration_persists_alongside_the_plan_cache() {
        let dir = std::env::temp_dir();
        let cache_path = dir.join(format!("sptrsv_tuner_calib_{}.json", std::process::id()));
        let calib_path = calibration::path_for(&cache_path);
        std::fs::remove_file(&cache_path).ok();
        std::fs::remove_file(&calib_path).ok();
        let m = generate::lung2_like(&GenOptions::with_scale(0.02));
        let expected = {
            let mut tuner = Tuner::new(TunerOptions {
                cache_path: Some(cache_path.clone()),
                ..quick_opts()
            });
            let p = tuner.choose(&m).unwrap();
            assert_eq!(p.source, PlanSource::Raced);
            assert!(calib_path.exists(), "calibration not spilled");
            tuner.model.calibration_table()
        };
        assert!(!expected.is_empty(), "race recorded no calibration");
        // The split keys are per axis, not per plan.
        assert!(
            expected.keys().all(|k| k.starts_with("rewrite:") || k.starts_with("exec:")),
            "unexpected calibration keys: {:?}",
            expected.keys().collect::<Vec<_>>()
        );
        // A fresh tuner (fresh process, same spill dir) starts with the
        // refined coefficients, not the closed-form seeds.
        let tuner2 = Tuner::new(TunerOptions {
            cache_path: Some(cache_path.clone()),
            ..quick_opts()
        });
        assert_eq!(
            tuner2.model.calibration_table(),
            expected,
            "calibration table not restored"
        );
        std::fs::remove_file(&cache_path).ok();
        std::fs::remove_file(&calib_path).ok();
    }

    #[test]
    fn tolerance_admits_iterative_candidates_and_gates_cache_reuse() {
        let m = generate::tridiagonal(200, &Default::default());
        // Without a tolerance the iterative backends never enter the
        // portfolio: no raced lane may be a jacobi plan.
        let mut exact = Tuner::new(quick_opts());
        let p = exact.choose(&m).unwrap();
        for lane in &p.race.as_ref().unwrap().lanes {
            let plan = SolvePlan::parse(&lane.plan).unwrap();
            assert!(!plan.exec.is_iterative(), "{} raced without tolerance", lane.plan);
        }

        // Under a tolerance they join, and whatever wins is cached with
        // its certified tolerance.
        let mut tuner = Tuner::new(TunerOptions {
            tolerance: Some(1e-8),
            top_k: 3,
            ..quick_opts()
        });
        let p1 = tuner.choose(&m).unwrap();
        assert_eq!(p1.source, PlanSource::Raced);
        // Every qualified lane certified the tolerance; the winner is
        // qualified (exact lanes guarantee at least one qualifies).
        let out = p1.race.as_ref().unwrap();
        assert!(out.winner_lane().qualified);
        let cached = tuner.cache.peek(p1.fingerprint).unwrap();
        if p1.plan.exec.is_iterative() {
            assert_eq!(cached.tolerance, 1e-8);
        } else {
            assert_eq!(cached.tolerance, 0.0);
        }

        // Same tolerance: the cached decision is reusable.
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);

        // Force an iterative cached decision and tighten the constraint:
        // the cache must NOT serve it — the tuner re-races.
        tuner.cache.put(
            p1.fingerprint,
            CachedPlan {
                plan: "none+jacobi:8".to_string(),
                solve_us: 1.0,
                timings: Vec::new(),
                nrows: m.nrows,
                created_unix: plan_cache::now_unix(),
                tolerance: 1e-6,
            },
        );
        tuner.opts.tolerance = Some(1e-12);
        let p3 = tuner.choose(&m).unwrap();
        assert_eq!(
            p3.source,
            PlanSource::Raced,
            "a 1e-6-certified jacobi plan served a 1e-12 constraint"
        );
        // And with no tolerance at all, an iterative cached plan is
        // likewise refused.
        tuner.cache.put(
            p1.fingerprint,
            CachedPlan {
                plan: "none+jacobi:8".to_string(),
                solve_us: 1.0,
                timings: Vec::new(),
                nrows: m.nrows,
                created_unix: plan_cache::now_unix(),
                tolerance: 1e-6,
            },
        );
        tuner.opts.tolerance = None;
        let p4 = tuner.choose(&m).unwrap();
        assert_eq!(p4.source, PlanSource::Raced);
        assert!(!p4.plan.exec.is_iterative());
    }

    #[test]
    fn process_tuner_is_shared_and_caches() {
        let m = generate::tridiagonal(64, &Default::default());
        let p1 = process_choose(&m).unwrap();
        p1.transform.validate(&m).unwrap();
        // The second standalone call answers from the shared cache
        // instead of re-racing (the old Strategy::Auto::apply re-raced
        // every time).
        let p2 = process_choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert_eq!(p2.plan_name, p1.plan_name);
    }

    #[test]
    fn empty_matrix_is_served_without_racing() {
        let m = crate::sparse::Csr::new(0, 0, vec![0], vec![], vec![]).unwrap();
        let mut tuner = Tuner::new(quick_opts());
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.plan_name, "none");
        assert_eq!(p.transform.num_levels(), 0);
    }
}
