//! Strategy-portfolio autotuner: pick the best transformation strategy
//! per matrix, automatically.
//!
//! The paper closes by noting its results "provide several hints on how
//! to craft a collection of strategies"; this subsystem operationalizes
//! that: the fixed `Strategy` portfolio (`none | avgcost | manual |
//! guarded`) becomes a self-tuning choice made per sparsity structure.
//!
//! Decision path of [`Tuner::choose`]:
//!
//! 1. [`fingerprint`] — hash the sparsity structure; a [`plan_cache`] hit
//!    returns the previously raced winner immediately (analysis cost is
//!    paid once per structure, amortized across re-registrations).
//! 2. [`features`]   — extract the structural feature vector (level
//!    widths, thin-level shares, indegrees, critical path).
//! 3. [`cost_model`] — closed-form per-strategy cost prediction shortlists
//!    the `top_k` candidates; measured timings continually recalibrate it.
//! 4. [`race`]       — the shortlist runs real transforms + a few warm-up
//!    solves; the measured winner becomes the plan and is cached.

pub mod cost_model;
pub mod features;
pub mod fingerprint;
pub mod plan_cache;
pub mod race;

use std::path::PathBuf;
use std::sync::Arc;

use crate::error::Error;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{Strategy, TransformResult};

pub use cost_model::{CostModel, PlanEstimate};
pub use features::MatrixFeatures;
pub use fingerprint::Fingerprint;
pub use plan_cache::{CachedPlan, PlanCache, PLAN_SCHEMA_VERSION};
pub use race::{RaceOptions, RaceOutcome};

/// The default strategy portfolio: the paper's three columns, the
/// guarded variant of §III.A, and the execution strategies — the
/// coarsened static schedule, the sync-free solver and the level-sorted
/// reordering (ROADMAP "widen the portfolio").
pub const DEFAULT_CANDIDATES: [&str; 7] = [
    "none",
    "avgcost",
    "manual:10",
    "guarded:20",
    "scheduled",
    "syncfree",
    "reorder",
];

#[derive(Debug, Clone)]
pub struct TunerOptions {
    /// strategy names eligible for selection (`auto` is ignored)
    pub candidates: Vec<String>,
    /// how many cost-model favourites to race empirically
    pub top_k: usize,
    /// timed solves per raced candidate
    pub race_solves: usize,
    /// worker threads used by raced solves (and by the cost model's
    /// parallelism term)
    pub workers: usize,
    /// plan cache capacity (entries)
    pub cache_capacity: usize,
    /// JSON spill path; None keeps the cache in memory only
    pub cache_path: Option<PathBuf>,
    /// seconds before a spilled same-schema plan expires and is dropped
    /// on load (0 = plans never expire by age)
    pub cache_ttl_secs: u64,
    /// scheduling knobs raced `scheduled` candidates run with — the
    /// coordinator passes its config defaults so the race measures the
    /// exact schedule serving would build
    pub sched: crate::sched::SchedOptions,
    /// RHS seed for racing
    pub seed: u64,
    /// worker pool shared with the caller (the serving pipeline threads
    /// its own pool through here); None spawns a throwaway pool per race
    pub pool: Option<Arc<Pool>>,
}

impl Default for TunerOptions {
    fn default() -> Self {
        TunerOptions {
            candidates: DEFAULT_CANDIDATES.iter().map(|s| s.to_string()).collect(),
            top_k: 2,
            race_solves: 3,
            // Match the machine rather than a fixed guess: races measure
            // at the parallelism the solves will actually run with.
            // Callers with a known worker count should still set this.
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(8),
            cache_capacity: 64,
            cache_path: None,
            cache_ttl_secs: 0,
            sched: Default::default(),
            seed: 0x7E57,
            pool: None,
        }
    }
}

/// How a [`TunedPlan`] was decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// fingerprint found in the plan cache; no analysis ran
    CacheHit,
    /// cost model shortlisted, race measured
    Raced,
}

/// The tuner's decision for one matrix, ready to serve.
pub struct TunedPlan {
    pub fingerprint: Fingerprint,
    /// winning strategy in `Strategy::parse` syntax
    pub strategy_name: String,
    pub strategy: Strategy,
    pub source: PlanSource,
    /// structural feature vector; None on a cache hit, where no feature
    /// analysis runs (applying the cached strategy still builds its own
    /// level sets — that cost is inherent to producing a transform)
    pub features: Option<MatrixFeatures>,
    /// cost-model predictions, best first (empty on a cache hit)
    pub predictions: Vec<(String, f64)>,
    /// race report (None on a cache hit)
    pub race: Option<RaceOutcome>,
    /// the winning transform, ready for the executor
    pub transform: TransformResult,
}

pub struct Tuner {
    pub opts: TunerOptions,
    pub model: CostModel,
    pub cache: PlanCache,
}

impl Tuner {
    pub fn new(opts: TunerOptions) -> Tuner {
        let model = CostModel::new(opts.workers);
        let cache = match &opts.cache_path {
            Some(path) => {
                PlanCache::with_disk_ttl(opts.cache_capacity, path, opts.cache_ttl_secs)
            }
            None => PlanCache::new(opts.cache_capacity),
        };
        Tuner { opts, model, cache }
    }

    /// (hits, misses) observed by the plan cache so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Decide a strategy for `m`: plan-cache lookup, else cost-model
    /// shortlist + race, then cache the winner.
    ///
    /// This entry point copies the matrix once on a cache miss (the race
    /// lanes share it by Arc); callers that already hold an `Arc<Csr>`
    /// should use [`Tuner::choose_arc`], which never copies.
    pub fn choose(&mut self, m: &Csr) -> Result<TunedPlan, Error> {
        let fingerprint = Fingerprint::of(m);
        if m.nrows == 0 {
            return Ok(self.empty_plan(fingerprint, m));
        }
        if let Some(plan) = self.try_cached(fingerprint, m) {
            return Ok(plan);
        }
        self.tune(&Arc::new(m.clone()), fingerprint)
    }

    /// [`Tuner::choose`] without the defensive copy: the cache-miss race
    /// shares `m` by reference count.
    pub fn choose_arc(&mut self, m: &Arc<Csr>) -> Result<TunedPlan, Error> {
        let fingerprint = Fingerprint::of(m);
        if m.nrows == 0 {
            return Ok(self.empty_plan(fingerprint, m));
        }
        if let Some(plan) = self.try_cached(fingerprint, m) {
            return Ok(plan);
        }
        self.tune(m, fingerprint)
    }

    /// Degenerate (empty) matrix: nothing to tune.
    fn empty_plan(&self, fingerprint: Fingerprint, m: &Csr) -> TunedPlan {
        TunedPlan {
            fingerprint,
            strategy_name: "none".to_string(),
            strategy: Strategy::None,
            source: PlanSource::Raced,
            features: None,
            predictions: Vec::new(),
            race: None,
            transform: TransformResult::identity(m),
        }
    }

    /// Plan-cache lookup. An unparseable cached strategy (stale format,
    /// hand-edited file) must not brick its fingerprint: warn, return
    /// None so the caller re-tunes, and let the fresh put() overwrite it.
    fn try_cached(&mut self, fingerprint: Fingerprint, m: &Csr) -> Option<TunedPlan> {
        let cached = self.cache.get(fingerprint)?;
        match Strategy::parse(&cached.strategy) {
            Ok(strategy) => {
                let transform = strategy.apply(m);
                Some(TunedPlan {
                    fingerprint,
                    strategy_name: cached.strategy,
                    strategy,
                    source: PlanSource::CacheHit,
                    features: None,
                    predictions: Vec::new(),
                    race: None,
                    transform,
                })
            }
            Err(e) => {
                eprintln!(
                    "warning: tuner plan cache entry for {fingerprint} unusable \
                     ({e}); re-tuning"
                );
                None
            }
        }
    }

    /// Cache-miss path: extract features, shortlist by predicted cost
    /// (skipping candidates whose estimated plan shape duplicates one
    /// already shortlisted — e.g. `guarded` degenerates to `avgcost`),
    /// race, record, cache.
    fn tune(&mut self, m: &Arc<Csr>, fingerprint: Fingerprint) -> Result<TunedPlan, Error> {
        let features = MatrixFeatures::of(m);
        let predictions = self.model.rank(&features, &self.opts.candidates);
        if predictions.is_empty() {
            return Err(Error::Invalid(
                "tuner: no usable candidate strategies".to_string(),
            ));
        }
        let top_k = self.opts.top_k.max(1);
        let mut shortlist: Vec<String> = Vec::with_capacity(top_k);
        let mut seen: Vec<PlanEstimate> = Vec::with_capacity(top_k);
        for (s, _) in &predictions {
            if shortlist.len() >= top_k {
                break;
            }
            let Some(est) = self.model.estimate(&features, s) else {
                continue;
            };
            // "Same predicted plan shape => racing adds nothing" only
            // holds between candidates that execute on the level-set
            // executor. Execution strategies (scheduled/syncfree/reorder)
            // run on their own backends, so an estimate that happens to
            // equal another candidate's does NOT make their race
            // redundant — they bypass the dedup entirely.
            let dedupable = !matches!(
                Strategy::parse(s),
                Ok(Strategy::Scheduled(_) | Strategy::Syncfree | Strategy::Reorder)
            );
            if dedupable {
                if seen.contains(&est) {
                    continue;
                }
                seen.push(est);
            }
            shortlist.push(s.clone());
        }
        if shortlist.is_empty() {
            shortlist.push(predictions[0].0.clone());
        }
        let race_opts = RaceOptions {
            solves: self.opts.race_solves,
            workers: self.opts.workers,
            seed: self.opts.seed,
            sched: self.opts.sched,
            pool: self.opts.pool.clone(),
        };
        let mut outcome = race::race(m, &shortlist, &race_opts).map_err(Error::Runtime)?;

        // Feed measurements back into the model's calibration, against
        // the UNCALIBRATED prediction (see CostModel::record).
        for lane in &outcome.lanes {
            if let Some(raw) = self.model.predict_raw(&features, &lane.strategy) {
                self.model.record(&lane.strategy, raw, lane.solve_us);
            }
        }

        let winner = outcome.winner;
        let strategy_name = outcome.lanes[winner].strategy.clone();
        let strategy = Strategy::parse(&strategy_name).map_err(Error::Invalid)?;
        let transform = match outcome.lanes[winner].transform.take() {
            Some(t) => t,
            // The race could not reclaim its Arc (never expected, but
            // cheap to recover from): apply the winner again.
            None => strategy.apply(m),
        };

        self.cache.put(
            fingerprint,
            CachedPlan {
                strategy: strategy_name.clone(),
                solve_us: outcome.lanes[winner].solve_us,
                timings: outcome
                    .lanes
                    .iter()
                    .map(|l| (l.strategy.clone(), l.solve_us))
                    .collect(),
                nrows: m.nrows,
                created_unix: plan_cache::now_unix(),
            },
        );

        Ok(TunedPlan {
            fingerprint,
            strategy_name,
            strategy,
            source: PlanSource::Raced,
            features: Some(features),
            predictions,
            race: Some(outcome),
            transform,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn quick_opts() -> TunerOptions {
        TunerOptions {
            race_solves: 1,
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn choose_then_cache_hit() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let mut tuner = Tuner::new(quick_opts());
        let p1 = tuner.choose(&m).unwrap();
        assert_eq!(p1.source, PlanSource::Raced);
        assert!(!p1.predictions.is_empty());
        p1.transform.validate(&m).unwrap();
        // guarded degenerates to avgcost under the estimate, so the
        // shortlist dedup must never race both.
        let lanes: Vec<&str> = p1
            .race
            .as_ref()
            .unwrap()
            .lanes
            .iter()
            .map(|l| l.strategy.as_str())
            .collect();
        assert!(
            !(lanes.contains(&"avgcost") && lanes.contains(&"guarded:20")),
            "duplicate plan shapes raced: {lanes:?}"
        );
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert_eq!(p2.strategy_name, p1.strategy_name);
        assert_eq!(
            p2.transform.stats.levels_after,
            p1.transform.stats.levels_after
        );
        assert_eq!(tuner.cache_stats(), (1, 1));
    }

    #[test]
    fn tridiagonal_chooses_a_barrier_reducing_plan() {
        let m = generate::tridiagonal(300, &Default::default());
        let mut tuner = Tuner::new(quick_opts());
        let p = tuner.choose(&m).unwrap();
        // The model shortlists manual (the only strategy that helps a
        // uniform chain); whatever wins the race must not be worse than
        // the baseline's 300 levels.
        assert!(p.transform.num_levels() <= 300);
        assert_eq!(p.features.as_ref().map(|f| f.num_levels), Some(300));
    }

    #[test]
    fn unusable_cache_entry_self_heals() {
        let m = generate::tridiagonal(80, &Default::default());
        let mut tuner = Tuner::new(quick_opts());
        tuner.cache.put(
            Fingerprint::of(&m),
            CachedPlan {
                strategy: "not-a-strategy".to_string(),
                solve_us: 1.0,
                timings: Vec::new(),
                nrows: 80,
                created_unix: plan_cache::now_unix(),
            },
        );
        // The poisoned entry must not brick `auto`: choose re-races and
        // overwrites it.
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.source, PlanSource::Raced);
        p.transform.validate(&m).unwrap();
        let p2 = tuner.choose(&m).unwrap();
        assert_eq!(p2.source, PlanSource::CacheHit);
        assert_eq!(p2.strategy_name, p.strategy_name);
    }

    #[test]
    fn execution_strategies_bypass_shape_dedup() {
        // On a tiny chain, `scheduled` and `syncfree` estimate the same
        // plan shape ({1 block/level, same work}) — but they execute on
        // different backends, so BOTH must reach the race.
        let m = generate::tridiagonal(20, &Default::default());
        let mut tuner = Tuner::new(TunerOptions {
            candidates: vec!["scheduled".to_string(), "syncfree".to_string()],
            top_k: 2,
            race_solves: 1,
            workers: 2,
            ..Default::default()
        });
        let p = tuner.choose(&m).unwrap();
        let lanes: Vec<&str> = p
            .race
            .as_ref()
            .expect("raced")
            .lanes
            .iter()
            .map(|l| l.strategy.as_str())
            .collect();
        assert_eq!(lanes.len(), 2, "dedup swallowed a backend: {lanes:?}");
    }

    #[test]
    fn empty_matrix_is_served_without_racing() {
        let m = crate::sparse::Csr::new(0, 0, vec![0], vec![], vec![]).unwrap();
        let mut tuner = Tuner::new(quick_opts());
        let p = tuner.choose(&m).unwrap();
        assert_eq!(p.strategy_name, "none");
        assert_eq!(p.transform.num_levels(), 0);
    }
}
