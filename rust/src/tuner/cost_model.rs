//! Per-strategy solve-cost prediction from structural features.
//!
//! The model is deliberately closed-form: a level-set solve costs one
//! synchronization per level plus the level work divided by the usable
//! parallelism ([`plan_cost`]). Each strategy's effect is estimated from
//! the features alone ([`CostModel::estimate`]) — how many thin levels it
//! merges and how much it inflates total work — seeded from the paper's
//! Table I observations (avgcost preserves work; the blind manual
//! strategy inflates rewritten rows roughly by the mean indegree).
//!
//! Predictions are only used to *shortlist* candidates for the empirical
//! race; they are refined over time by [`CostModel::record`], which keeps
//! a per-strategy EWMA multiplier of measured/predicted so systematic
//! model error cancels out of the ranking.

use std::collections::BTreeMap;

use crate::sched::SchedOptions;
use crate::transform::Strategy;
use crate::tuner::features::MatrixFeatures;

/// Modelled cost of one level-set synchronization, in the same abstract
/// work units as the paper's row cost (2*nnz-1 flops-equivalents).
pub const SYNC_COST: f64 = 60.0;

/// Modelled cost of one elastic point-to-point wait (a cross-worker block
/// edge in a schedule): far cheaper than a full barrier.
pub const WAIT_COST: f64 = 8.0;

/// Modelled per-block dispatch overhead of scheduled execution (ready
/// check + done-flag publish).
pub const BLOCK_COST: f64 = 2.0;

/// Modelled per-edge cost of the sync-free solver's atomic counter
/// traffic.
pub const ATOMIC_COST: f64 = 2.0;

/// Modelled per-row cost of permuting b in / x out for the reordering
/// strategy.
pub const PERM_COST: f64 = 0.5;

/// Work multiplier the level-sorted reordering is credited with (the
/// locality gain of contiguous levels).
pub const REORDER_LOCALITY: f64 = 0.97;

/// Estimated shape of a transformed system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanEstimate {
    pub levels: usize,
    pub work: f64,
}

/// Cost of executing a level partition: `levels` synchronizations plus the
/// total work spread over the usable parallelism (capped by the average
/// level width — a 1-wide chain cannot use more than one worker).
pub fn plan_cost(levels: usize, work: f64, nrows: usize, workers: usize) -> f64 {
    let levels = levels.max(1);
    let width = (nrows as f64 / levels as f64).max(1.0);
    let p = (workers.max(1) as f64).min(width);
    levels as f64 * SYNC_COST + work / p
}

pub struct CostModel {
    pub workers: usize,
    /// per-strategy EWMA of measured/predicted (1.0 = model exact)
    calibration: BTreeMap<String, f64>,
}

impl CostModel {
    pub fn new(workers: usize) -> CostModel {
        CostModel {
            workers: workers.max(1),
            calibration: BTreeMap::new(),
        }
    }

    /// Estimate the post-transform (levels, work) for a named strategy.
    /// Returns None for names the model cannot interpret (including
    /// `auto`, which would be self-referential).
    pub fn estimate(&self, f: &MatrixFeatures, strategy: &str) -> Option<PlanEstimate> {
        let base = PlanEstimate {
            levels: f.num_levels,
            work: f.total_cost as f64,
        };
        match Strategy::parse(strategy).ok()? {
            Strategy::None => Some(base),
            Strategy::Auto => None,
            // Scheduled execution removes levels from the cost picture:
            // the "plan shape" is its estimated block count at unchanged
            // total work (see `sched_shape`).
            Strategy::Scheduled(o) => {
                let (blocks, _, _) = self.sched_shape(f, &o);
                Some(PlanEstimate {
                    levels: blocks as usize,
                    work: f.total_cost as f64,
                })
            }
            // Sync-free execution has no level structure at all.
            Strategy::Syncfree => Some(PlanEstimate {
                levels: 1,
                work: f.total_cost as f64,
            }),
            // Reordering keeps the levels, trims the work by the modelled
            // locality gain.
            Strategy::Reorder => Some(PlanEstimate {
                levels: f.num_levels,
                work: f.total_cost as f64 * REORDER_LOCALITY,
            }),
            Strategy::AvgLevelCost(_) => {
                // avgcost merges cost-thin levels into targets until each
                // target reaches avgLevelCost; with fewer than 2 thin
                // levels it is a no-op (the uniform-chain limitation).
                if f.thin_cost_levels < 2 {
                    return Some(base);
                }
                let group = (f.avg_level_cost / f.mean_thin_level_cost.max(1.0))
                    .clamp(1.0, f.thin_cost_levels as f64);
                let merged = (f.thin_cost_levels as f64 / group).ceil() as usize;
                Some(PlanEstimate {
                    levels: f.num_levels - f.thin_cost_levels + merged,
                    // Cost-guided rewriting approximately preserves work
                    // (Table I: -1.1% on lung2, +0.2% on torso2).
                    work: f.total_cost as f64,
                })
            }
            Strategy::Manual(o) => {
                // Every `distance` width-thin levels collapse into one.
                if f.thin_width_levels < 2 {
                    return Some(base);
                }
                let d = o.distance.max(2);
                let merged = f.thin_width_levels.div_ceil(d);
                // Blind substitution multiplies a rewritten row's
                // dependency count by roughly the mean indegree of the
                // rows substituted into it (torso2: +40% total with
                // indegree ~4; chains with indegree 1 stay flat).
                let moved = f.thin_width_cost as f64 * (d as f64 - 1.0) / d as f64;
                let inflation = (f.avg_indegree - 1.0).max(0.0);
                Some(PlanEstimate {
                    levels: f.num_levels - f.thin_width_levels + merged,
                    work: f.total_cost as f64 + moved * inflation,
                })
            }
        }
    }

    /// Estimated schedule shape for the scheduled strategy:
    /// `(blocks, usable parallelism, cross-worker edge cut)`. Blocks come
    /// from the coarsening target; the usable parallelism is capped by
    /// the mean level width (a serial chain collapses onto one worker);
    /// the cut scales with how many block edges must cross workers at
    /// that parallelism.
    fn sched_shape(&self, f: &MatrixFeatures, o: &SchedOptions) -> (f64, f64, f64) {
        let target = o.block_target() as f64;
        let blocks = (f.total_cost as f64 / target)
            .ceil()
            .clamp(1.0, f.nrows.max(1) as f64);
        let p = (self.workers as f64)
            .min(f.mean_level_width.max(1.0))
            .max(1.0);
        let cut = blocks * f.avg_indegree.min(4.0) * (p - 1.0) / p;
        (blocks, p, cut)
    }

    /// Closed-form prediction without the calibration multiplier. This is
    /// what measured timings must be recorded against — recording against
    /// the calibrated value would make the feedback loop converge to the
    /// square root of the model error instead of cancelling it.
    pub fn predict_raw(&self, f: &MatrixFeatures, strategy: &str) -> Option<f64> {
        // Execution strategies replace the barrier-per-level cost shape
        // of `plan_cost` with their own synchronization model.
        match Strategy::parse(strategy).ok()? {
            Strategy::Scheduled(o) => {
                let (blocks, p, cut) = self.sched_shape(f, &o);
                return Some(f.total_cost as f64 / p + blocks * BLOCK_COST + cut * WAIT_COST);
            }
            Strategy::Syncfree => {
                let p = (self.workers as f64)
                    .min(f.mean_level_width.max(1.0))
                    .max(1.0);
                let edges = f.nnz.saturating_sub(f.nrows) as f64;
                return Some(f.total_cost as f64 / p + edges * ATOMIC_COST);
            }
            Strategy::Reorder => {
                let est = self.estimate(f, strategy)?;
                return Some(
                    plan_cost(est.levels, est.work, f.nrows, self.workers)
                        + f.nrows as f64 * PERM_COST,
                );
            }
            _ => {}
        }
        let est = self.estimate(f, strategy)?;
        Some(plan_cost(est.levels, est.work, f.nrows, self.workers))
    }

    /// Predicted solve cost (abstract units; lower is better), including
    /// the empirical calibration multiplier.
    pub fn predict(&self, f: &MatrixFeatures, strategy: &str) -> Option<f64> {
        Some(self.predict_raw(f, strategy)? * self.calibration(strategy))
    }

    /// All candidates with predictions, best first. Unknown names are
    /// dropped. Ties keep the input order (stable sort), so earlier
    /// candidates win equal predictions.
    pub fn rank(&self, f: &MatrixFeatures, candidates: &[String]) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = candidates
            .iter()
            .filter_map(|s| self.predict(f, s).map(|c| (s.clone(), c)))
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Fold a measured timing back into the per-strategy calibration.
    /// `predicted` must be the UNCALIBRATED prediction ([`Self::predict_raw`]);
    /// `measured` may be in any fixed unit (the race reports µs) — only
    /// the measured/predicted ratio matters and it cancels across
    /// strategies.
    pub fn record(&mut self, strategy: &str, predicted: f64, measured: f64) {
        if predicted <= 0.0 || measured <= 0.0 || !predicted.is_finite() || !measured.is_finite() {
            return;
        }
        let ratio = (measured / predicted).clamp(1e-6, 1e6);
        let m = self
            .calibration
            .entry(strategy.to_string())
            .or_insert(ratio);
        *m = 0.7 * *m + 0.3 * ratio;
    }

    pub fn calibration(&self, strategy: &str) -> f64 {
        self.calibration.get(strategy).copied().unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn feats(m: &crate::sparse::Csr) -> MatrixFeatures {
        MatrixFeatures::of(m)
    }

    #[test]
    fn tridiagonal_prefers_manual() {
        let f = feats(&generate::tridiagonal(400, &Default::default()));
        let cm = CostModel::new(4);
        let none = cm.predict(&f, "none").unwrap();
        let avg = cm.predict(&f, "avgcost").unwrap();
        let man = cm.predict(&f, "manual:10").unwrap();
        // avgcost is a no-op on the uniform chain; manual cuts barriers 10x.
        assert_eq!(none, avg);
        assert!(man < none / 3.0, "manual {man} vs none {none}");
    }

    #[test]
    fn lung2_prefers_avgcost() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let cm = CostModel::new(4);
        let none = cm.predict(&f, "none").unwrap();
        let avg = cm.predict(&f, "avgcost").unwrap();
        assert!(avg < none, "avgcost {avg} vs none {none}");
        // Estimated level count collapses like Table I.
        let est = cm.estimate(&f, "avgcost").unwrap();
        assert!(est.levels < f.num_levels / 2, "{} levels", est.levels);
    }

    #[test]
    fn manual_inflates_work_with_indegree() {
        let f = feats(&generate::torso2_like(&GenOptions::with_scale(0.03)));
        let cm = CostModel::new(4);
        let man = cm.estimate(&f, "manual:10").unwrap();
        assert!(man.work > f.total_cost as f64, "no inflation modelled");
        let avg = cm.estimate(&f, "avgcost").unwrap();
        assert_eq!(avg.work, f.total_cost as f64);
    }

    #[test]
    fn rank_is_stable_and_filters_unknown() {
        let f = feats(&generate::tridiagonal(100, &Default::default()));
        let cm = CostModel::new(2);
        let cands = vec![
            "none".to_string(),
            "bogus-strategy".to_string(),
            "avgcost".to_string(),
            "manual:10".to_string(),
            "auto".to_string(),
        ];
        let ranked = cm.rank(&f, &cands);
        assert_eq!(ranked.len(), 3); // bogus + auto dropped
        assert_eq!(ranked[0].0, "manual:10");
        // none and avgcost tie on a uniform chain; input order breaks it.
        assert_eq!(ranked[1].0, "none");
        assert_eq!(ranked[2].0, "avgcost");
    }

    #[test]
    fn calibration_shifts_predictions() {
        let f = feats(&generate::tridiagonal(50, &Default::default()));
        let mut cm = CostModel::new(2);
        let before = cm.predict(&f, "none").unwrap();
        // Model says `before`; reality says 10x more.
        cm.record("none", cm.predict_raw(&f, "none").unwrap(), before * 10.0);
        let after = cm.predict(&f, "none").unwrap();
        assert!(after > before * 3.0, "calibration not applied: {after}");
        // Bad samples are ignored.
        cm.record("none", 0.0, 1.0);
        cm.record("none", 1.0, -5.0);
    }

    #[test]
    fn calibration_converges_when_fed_raw_predictions() {
        // Recording measured against predict_raw (NOT the calibrated
        // value) must converge the multiplier to the true ratio, not its
        // square root.
        let f = feats(&generate::tridiagonal(50, &Default::default()));
        let mut cm = CostModel::new(2);
        let raw = cm.predict_raw(&f, "none").unwrap();
        for _ in 0..20 {
            let base = cm.predict_raw(&f, "none").unwrap();
            assert_eq!(base, raw); // raw prediction ignores calibration
            cm.record("none", base, raw * 10.0);
        }
        let cal = cm.calibration("none");
        assert!((cal - 10.0).abs() < 0.5, "calibration {cal}, want ~10");
    }

    #[test]
    fn scheduled_wins_the_serial_chain() {
        // A uniform chain is the scheduled strategy's home game: chains
        // collapse into blocks with no barriers and (at parallelism 1) no
        // cross-worker waits, so the model must rank it ahead of every
        // barrier-paying strategy.
        let f = feats(&generate::tridiagonal(400, &Default::default()));
        let cm = CostModel::new(4);
        let sched = cm.predict(&f, "scheduled").unwrap();
        for other in ["none", "avgcost", "manual:10", "syncfree"] {
            let c = cm.predict(&f, other).unwrap();
            assert!(sched < c, "scheduled {sched} not < {other} {c}");
        }
    }

    #[test]
    fn execution_strategies_have_estimates_and_predictions() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let cm = CostModel::new(4);
        for s in ["scheduled", "scheduled:64:2", "syncfree", "reorder"] {
            let est = cm.estimate(&f, s).expect(s);
            assert!(est.levels >= 1, "{s}");
            assert!(est.work > 0.0, "{s}");
            assert!(cm.predict(&f, s).unwrap().is_finite(), "{s}");
        }
        // The three execution strategies estimate distinct plan shapes,
        // so the shortlist dedup never collapses them together.
        let sched = cm.estimate(&f, "scheduled").unwrap();
        let syncfree = cm.estimate(&f, "syncfree").unwrap();
        let reorder = cm.estimate(&f, "reorder").unwrap();
        assert_ne!(sched, syncfree);
        assert_ne!(sched, reorder);
        assert_ne!(syncfree, reorder);
        // Reorder keeps the level structure: it differs from `none` only
        // by the modelled locality gain minus the per-solve permutation
        // cost, so the two predictions stay within one permutation pass
        // of each other (the race, not the seed model, settles the call).
        let none = cm.predict(&f, "none").unwrap();
        let re = cm.predict(&f, "reorder").unwrap();
        assert!(
            (re - none).abs() <= f.nrows as f64 * PERM_COST + 1.0,
            "reorder {re} vs none {none}"
        );
    }

    #[test]
    fn plan_cost_shape() {
        // More levels cost more at equal work; parallelism caps at width.
        assert!(plan_cost(100, 1000.0, 100, 4) > plan_cost(10, 1000.0, 100, 4));
        // 1-wide chain: workers do not help.
        assert_eq!(plan_cost(100, 1000.0, 100, 1), plan_cost(100, 1000.0, 100, 8));
    }
}
