//! Per-plan solve-cost prediction from structural features, composed
//! along the two plan axes.
//!
//! A [`crate::transform::SolvePlan`] is a rewrite × exec pair, and the
//! model prices it the same way: the **rewrite axis** predicts the shape
//! of the transformed system — how many thin levels it merges and how
//! much it inflates total work ([`CostModel::estimate`], seeded from the
//! paper's Table I observations: avgcost preserves work; the blind manual
//! strategy inflates rewritten rows roughly by the mean indegree) — and
//! the **exec axis** prices consuming that estimated shape: level-set
//! barriers ([`plan_cost`]), a coarsened schedule's block dispatch +
//! cross-worker waits, the sync-free solver's atomic counter traffic, or
//! the reordering's locality gain minus its permutation pass.
//!
//! Predictions are only used to *shortlist* candidates for the empirical
//! race; they are refined over time by [`CostModel::record`], which keeps
//! a per-plan EWMA multiplier of measured/predicted so systematic model
//! error cancels out of the ranking. The calibration table is persisted
//! alongside the plan cache (see [`crate::tuner::calibration`]) so a
//! restart keeps the refined coefficients, not just the decisions.

use std::collections::BTreeMap;

use crate::sched::SchedOptions;
use crate::transform::{Exec, Rewrite, SolvePlan};
use crate::tuner::features::MatrixFeatures;

/// Modelled cost of one level-set synchronization, in the same abstract
/// work units as the paper's row cost (2*nnz-1 flops-equivalents).
pub const SYNC_COST: f64 = 60.0;

/// Modelled cost of one elastic point-to-point wait (a cross-worker block
/// edge in a schedule): far cheaper than a full barrier.
pub const WAIT_COST: f64 = 8.0;

/// Modelled per-block dispatch overhead of scheduled execution (ready
/// check + done-flag publish).
pub const BLOCK_COST: f64 = 2.0;

/// Modelled per-edge cost of the sync-free solver's atomic counter
/// traffic.
pub const ATOMIC_COST: f64 = 2.0;

/// Modelled per-row cost of permuting b in / x out for the reordering
/// execution.
pub const PERM_COST: f64 = 0.5;

/// Work multiplier the level-sorted reordering is credited with (the
/// locality gain of contiguous levels).
pub const REORDER_LOCALITY: f64 = 0.97;

/// Per-sweep work discount of the mixed-precision Jacobi backend: f32
/// sweeps halve the value bandwidth but the index structure stays full
/// width, so the saving is less than half.
pub const MIXED_SWEEP_DISCOUNT: f64 = 0.6;

/// Estimated shape of a transformed system (the rewrite axis's output).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanEstimate {
    pub levels: usize,
    pub work: f64,
}

/// Cost of executing a level partition: `levels` synchronizations plus the
/// total work spread over the usable parallelism (capped by the average
/// level width — a 1-wide chain cannot use more than one worker).
pub fn plan_cost(levels: usize, work: f64, nrows: usize, workers: usize) -> f64 {
    let levels = levels.max(1);
    let width = (nrows as f64 / levels as f64).max(1.0);
    let p = (workers.max(1) as f64).min(width);
    levels as f64 * SYNC_COST + work / p
}

pub struct CostModel {
    pub workers: usize,
    /// per-rewrite-axis EWMA error term: how far the rewrite *shape*
    /// estimate is off, shared by every plan using that rewrite (keyed by
    /// the rewrite's canonical name, e.g. `avgcost`, `manual:10`)
    rewrite_calibration: BTreeMap<String, f64>,
    /// per-exec-axis EWMA error term: how far the execution *cost* model
    /// is off, shared by every plan on that backend (keyed by the exec
    /// category name, e.g. `scheduled`, `jacobi` — knob-free so a
    /// `scheduled:64` race also refines `scheduled:256` predictions)
    exec_calibration: BTreeMap<String, f64>,
    /// effective per-wait cost of scheduled execution; starts at
    /// [`WAIT_COST`] and tracks observed elastic stall rates
    /// ([`Self::calibrate_sched`])
    wait_cost: f64,
    /// effective per-block dispatch cost of scheduled execution; starts
    /// at [`BLOCK_COST`]
    block_cost: f64,
}

/// Prefix of persisted rewrite-axis calibration keys.
const REWRITE_KEY: &str = "rewrite:";
/// Prefix of persisted exec-axis calibration keys.
const EXEC_KEY: &str = "exec:";

/// The two axis keys a plan's measured error folds into.
fn axis_keys(plan: &str) -> Option<(String, String)> {
    let p = SolvePlan::parse(plan).ok()?;
    Some((p.rewrite.to_string(), p.exec.name().to_string()))
}

impl CostModel {
    pub fn new(workers: usize) -> CostModel {
        CostModel {
            workers: workers.max(1),
            rewrite_calibration: BTreeMap::new(),
            exec_calibration: BTreeMap::new(),
            wait_cost: WAIT_COST,
            block_cost: BLOCK_COST,
        }
    }

    /// Estimate the post-rewrite (levels, work) shape of a plan — the
    /// **rewrite axis** only; the exec axis does not change the
    /// transformed system, only how it is consumed. Returns None for
    /// names the model cannot interpret (including `auto`, which would be
    /// self-referential).
    pub fn estimate(&self, f: &MatrixFeatures, plan: &str) -> Option<PlanEstimate> {
        let p = SolvePlan::parse(plan).ok()?;
        Some(self.rewrite_estimate(f, &p.rewrite))
    }

    fn rewrite_estimate(&self, f: &MatrixFeatures, rewrite: &Rewrite) -> PlanEstimate {
        let base = PlanEstimate {
            levels: f.num_levels,
            work: f.total_cost as f64,
        };
        match rewrite {
            Rewrite::None => base,
            Rewrite::AvgLevelCost(_) => {
                // avgcost merges cost-thin levels into targets until each
                // target reaches avgLevelCost; with fewer than 2 thin
                // levels it is a no-op (the uniform-chain limitation).
                if f.thin_cost_levels < 2 {
                    return base;
                }
                let group = (f.avg_level_cost / f.mean_thin_level_cost.max(1.0))
                    .clamp(1.0, f.thin_cost_levels as f64);
                let merged = (f.thin_cost_levels as f64 / group).ceil() as usize;
                PlanEstimate {
                    levels: f.num_levels - f.thin_cost_levels + merged,
                    // Cost-guided rewriting approximately preserves work
                    // (Table I: -1.1% on lung2, +0.2% on torso2).
                    work: f.total_cost as f64,
                }
            }
            Rewrite::Manual(o) => {
                // Every `distance` width-thin levels collapse into one.
                if f.thin_width_levels < 2 {
                    return base;
                }
                let d = o.distance.max(2);
                let merged = f.thin_width_levels.div_ceil(d);
                // Blind substitution multiplies a rewritten row's
                // dependency count by roughly the mean indegree of the
                // rows substituted into it (torso2: +40% total with
                // indegree ~4; chains with indegree 1 stay flat).
                let moved = f.thin_width_cost as f64 * (d as f64 - 1.0) / d as f64;
                let inflation = (f.avg_indegree - 1.0).max(0.0);
                PlanEstimate {
                    levels: f.num_levels - f.thin_width_levels + merged,
                    work: f.total_cost as f64 + moved * inflation,
                }
            }
        }
    }

    /// Mean level width of the estimated post-rewrite partition. Kept
    /// equal to the measured feature when the rewrite is a no-op so
    /// legacy predictions are bit-identical.
    fn mean_width(&self, f: &MatrixFeatures, est: &PlanEstimate) -> f64 {
        if est.levels == f.num_levels {
            f.mean_level_width.max(1.0)
        } else {
            (f.nrows as f64 / est.levels.max(1) as f64).max(1.0)
        }
    }

    /// Estimated schedule shape over an estimated rewrite:
    /// `(blocks, usable parallelism, cross-worker edge cut)`. Blocks come
    /// from the coarsening target applied to the post-rewrite work; the
    /// usable parallelism is capped by the post-rewrite mean level width
    /// (a serial chain collapses onto one worker); the cut scales with
    /// how many block edges must cross workers at that parallelism.
    fn sched_shape(
        &self,
        f: &MatrixFeatures,
        est: &PlanEstimate,
        o: &SchedOptions,
    ) -> (f64, f64, f64) {
        let target = o.block_target() as f64;
        let blocks = (est.work / target).ceil().clamp(1.0, f.nrows.max(1) as f64);
        let p = (self.workers as f64).min(self.mean_width(f, est)).max(1.0);
        let cut = blocks * f.avg_indegree.min(4.0) * (p - 1.0) / p;
        (blocks, p, cut)
    }

    /// Closed-form prediction without the calibration multiplier: the
    /// rewrite axis's estimated shape priced by the exec axis's
    /// synchronization model. This is what measured timings must be
    /// recorded against — recording against the calibrated value would
    /// make the feedback loop converge to the square root of the model
    /// error instead of cancelling it.
    pub fn predict_raw(&self, f: &MatrixFeatures, plan: &str) -> Option<f64> {
        let p = SolvePlan::parse(plan).ok()?;
        let est = self.rewrite_estimate(f, &p.rewrite);
        Some(match &p.exec {
            Exec::Levelset => plan_cost(est.levels, est.work, f.nrows, self.workers),
            Exec::Scheduled(o) => {
                let (blocks, par, cut) = self.sched_shape(f, &est, o);
                est.work / par + blocks * self.block_cost + cut * self.wait_cost
            }
            Exec::Syncfree => {
                let par = (self.workers as f64).min(self.mean_width(f, &est)).max(1.0);
                // Counter traffic scales with the transformed edge count,
                // approximated by the raw edge count times the rewrite's
                // work inflation.
                let inflation = if f.total_cost > 0 {
                    est.work / f.total_cost as f64
                } else {
                    1.0
                };
                let edges = f.nnz.saturating_sub(f.nrows) as f64 * inflation;
                est.work / par + edges * ATOMIC_COST
            }
            Exec::Reorder => {
                plan_cost(
                    est.levels,
                    est.work * REORDER_LOCALITY,
                    f.nrows,
                    self.workers,
                ) + f.nrows as f64 * PERM_COST
            }
            // Sweep-count × nnz pricing: every Jacobi sweep streams the
            // whole transformed system, but rows are independent within a
            // sweep, so the parallelism is NOT capped by level width —
            // that is the iterative backends' whole appeal on
            // barrier-bound systems. One pool rendezvous per sweep plays
            // the role the level barrier plays for level-set execution.
            Exec::Jacobi { sweeps } => {
                let s = (*sweeps).max(1) as f64;
                s * est.work / self.workers as f64 + s * SYNC_COST
            }
            Exec::JacobiMixed { sweeps } => {
                let s = (*sweeps).max(1) as f64;
                // all but the final (f64 correction) sweep run in f32
                let effective = (s - 1.0) * MIXED_SWEEP_DISCOUNT + 1.0;
                effective * est.work / self.workers as f64 + s * SYNC_COST
            }
        })
    }

    /// Predicted solve cost (abstract units; lower is better), including
    /// the empirical calibration multiplier.
    pub fn predict(&self, f: &MatrixFeatures, plan: &str) -> Option<f64> {
        Some(self.predict_raw(f, plan)? * self.calibration(plan))
    }

    /// All candidates with predictions, best first. Unknown names are
    /// dropped. Ties keep the input order (stable sort), so earlier
    /// candidates win equal predictions.
    pub fn rank(&self, f: &MatrixFeatures, candidates: &[String]) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = candidates
            .iter()
            .filter_map(|s| self.predict(f, s).map(|c| (s.clone(), c)))
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Fold a measured timing back into the per-axis calibration.
    /// `predicted` must be the UNCALIBRATED prediction ([`Self::predict_raw`]);
    /// `measured` may be in any fixed unit (the race reports µs) — only
    /// the measured/predicted ratio matters and it cancels across plans.
    ///
    /// The error splits evenly (in log space) between the plan's rewrite
    /// and exec axis terms: each EWMA tracks √(measured/predicted), and
    /// [`Self::calibration`] multiplies the two back together. A constant
    /// model error converges to the true ratio exactly as the old
    /// per-plan table did, but the axis terms are *shared* — racing
    /// `avgcost+scheduled` also refines `avgcost+syncfree` (same rewrite
    /// shape) and `none+scheduled` (same exec cost model), so a fresh
    /// pairing of known axes starts calibrated instead of cold.
    pub fn record(&mut self, plan: &str, predicted: f64, measured: f64) {
        if predicted <= 0.0 || measured <= 0.0 || !predicted.is_finite() || !measured.is_finite() {
            return;
        }
        let Some((rw, ex)) = axis_keys(plan) else {
            return;
        };
        let half = (measured / predicted).clamp(1e-6, 1e6).sqrt();
        for (map, key) in [
            (&mut self.rewrite_calibration, rw),
            (&mut self.exec_calibration, ex),
        ] {
            let m = map.entry(key).or_insert(half);
            *m = 0.7 * *m + 0.3 * half;
        }
    }

    /// Combined calibration multiplier for a plan: the product of its
    /// rewrite-axis and exec-axis error terms (1.0 for unknown axes or
    /// unparseable names).
    pub fn calibration(&self, plan: &str) -> f64 {
        let Some((rw, ex)) = axis_keys(plan) else {
            return 1.0;
        };
        self.rewrite_calibration.get(&rw).copied().unwrap_or(1.0)
            * self.exec_calibration.get(&ex).copied().unwrap_or(1.0)
    }

    /// The full calibration table for persistence alongside the plan
    /// cache: axis terms under namespaced keys (`rewrite:avgcost`,
    /// `exec:scheduled`).
    pub fn calibration_table(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, v) in &self.rewrite_calibration {
            out.insert(format!("{REWRITE_KEY}{k}"), *v);
        }
        for (k, v) in &self.exec_calibration {
            out.insert(format!("{EXEC_KEY}{k}"), *v);
        }
        out
    }

    /// Seed one calibration multiplier (restoring a persisted table).
    /// Keys use the [`Self::calibration_table`] namespacing; entries with
    /// an unknown prefix (including pre-split whole-plan keys) are
    /// ignored, as are non-finite or non-positive multipliers.
    pub fn set_calibration(&mut self, key: &str, multiplier: f64) {
        if !multiplier.is_finite() || multiplier <= 0.0 {
            return;
        }
        if let Some(k) = key.strip_prefix(REWRITE_KEY) {
            self.rewrite_calibration.insert(k.to_string(), multiplier);
        } else if let Some(k) = key.strip_prefix(EXEC_KEY) {
            self.exec_calibration.insert(k.to_string(), multiplier);
        }
    }

    /// Current effective `(wait_cost, block_cost)` of the scheduled-exec
    /// arm (the seeds are [`WAIT_COST`] / [`BLOCK_COST`]).
    pub fn sched_costs(&self) -> (f64, f64) {
        (self.wait_cost, self.block_cost)
    }

    /// Fold **measured** elastic execution counters back into the
    /// scheduled-exec cost terms (the coordinator calls this with the
    /// metrics it aggregates at snapshot time, closing the loop the
    /// static seeds could only guess at).
    ///
    /// `waits` is the cumulative count of blocked frontier ready-scans,
    /// `ooo` the lookahead fills, over schedules totalling `blocks`
    /// coarsened blocks. The seed `WAIT_COST` assumes roughly one stall
    /// per block; the observed waits-per-block rate scales the term
    /// toward reality, clamped to one decade each way so a single
    /// pathological run cannot zero it out or blow it up. Lookahead fills
    /// convert would-be stalls into extra dispatches, so the fill ratio
    /// surcharges `block_cost` instead. Both move by the same 0.7/0.3
    /// EWMA as the per-plan calibration; counters are cumulative, so
    /// repeated feeding converges rather than compounds.
    pub fn calibrate_sched(&mut self, waits: u64, ooo: u64, blocks: u64) {
        if blocks == 0 {
            return;
        }
        let waits_per_block = waits as f64 / blocks as f64;
        let target_wait = (WAIT_COST * waits_per_block).clamp(WAIT_COST / 10.0, WAIT_COST * 10.0);
        let fills = (ooo as f64 / (waits + ooo).max(1) as f64).clamp(0.0, 1.0);
        let target_block = BLOCK_COST * (1.0 + fills);
        self.wait_cost = 0.7 * self.wait_cost + 0.3 * target_wait;
        self.block_cost = 0.7 * self.block_cost + 0.3 * target_block;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn feats(m: &crate::sparse::Csr) -> MatrixFeatures {
        MatrixFeatures::of(m)
    }

    #[test]
    fn tridiagonal_prefers_manual() {
        let f = feats(&generate::tridiagonal(400, &Default::default()));
        let cm = CostModel::new(4);
        let none = cm.predict(&f, "none").unwrap();
        let avg = cm.predict(&f, "avgcost").unwrap();
        let man = cm.predict(&f, "manual:10").unwrap();
        // avgcost is a no-op on the uniform chain; manual cuts barriers 10x.
        assert_eq!(none, avg);
        assert!(man < none / 3.0, "manual {man} vs none {none}");
    }

    #[test]
    fn lung2_prefers_avgcost() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let cm = CostModel::new(4);
        let none = cm.predict(&f, "none").unwrap();
        let avg = cm.predict(&f, "avgcost").unwrap();
        assert!(avg < none, "avgcost {avg} vs none {none}");
        // Estimated level count collapses like Table I.
        let est = cm.estimate(&f, "avgcost").unwrap();
        assert!(est.levels < f.num_levels / 2, "{} levels", est.levels);
    }

    #[test]
    fn manual_inflates_work_with_indegree() {
        let f = feats(&generate::torso2_like(&GenOptions::with_scale(0.03)));
        let cm = CostModel::new(4);
        let man = cm.estimate(&f, "manual:10").unwrap();
        assert!(man.work > f.total_cost as f64, "no inflation modelled");
        let avg = cm.estimate(&f, "avgcost").unwrap();
        assert_eq!(avg.work, f.total_cost as f64);
    }

    #[test]
    fn rank_is_stable_and_filters_unknown() {
        let f = feats(&generate::tridiagonal(100, &Default::default()));
        let cm = CostModel::new(2);
        let cands = vec![
            "none".to_string(),
            "bogus-strategy".to_string(),
            "avgcost".to_string(),
            "manual:10".to_string(),
            "auto".to_string(),
        ];
        let ranked = cm.rank(&f, &cands);
        assert_eq!(ranked.len(), 3); // bogus + auto dropped
        assert_eq!(ranked[0].0, "manual:10");
        // none and avgcost tie on a uniform chain; input order breaks it.
        assert_eq!(ranked[1].0, "none");
        assert_eq!(ranked[2].0, "avgcost");
    }

    #[test]
    fn calibration_shifts_predictions() {
        let f = feats(&generate::tridiagonal(50, &Default::default()));
        let mut cm = CostModel::new(2);
        let before = cm.predict(&f, "none").unwrap();
        // Model says `before`; reality says 10x more.
        cm.record("none", cm.predict_raw(&f, "none").unwrap(), before * 10.0);
        let after = cm.predict(&f, "none").unwrap();
        assert!(after > before * 3.0, "calibration not applied: {after}");
        // Bad samples are ignored.
        cm.record("none", 0.0, 1.0);
        cm.record("none", 1.0, -5.0);
        // The table round-trips through set_calibration (persistence).
        let table = cm.calibration_table();
        let mut cm2 = CostModel::new(2);
        for (plan, mult) in &table {
            cm2.set_calibration(plan, *mult);
        }
        assert_eq!(cm2.predict(&f, "none").unwrap(), after);
        cm2.set_calibration("rewrite:none", f64::NAN); // ignored
        assert_eq!(cm2.predict(&f, "none").unwrap(), after);
        // Pre-split whole-plan keys from old spill files are ignored too.
        cm2.set_calibration("avgcost+scheduled", 5.0);
        assert_eq!(cm2.calibration("avgcost+scheduled"), 1.0);
    }

    #[test]
    fn calibration_error_is_shared_per_axis() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let mut cm = CostModel::new(4);
        let raw = cm.predict_raw(&f, "avgcost+syncfree").unwrap();
        for _ in 0..30 {
            cm.record("avgcost+syncfree", raw, raw * 9.0);
        }
        // The raced plan itself converges to the full ratio...
        let own = cm.calibration("avgcost+syncfree");
        assert!((own - 9.0).abs() < 0.5, "own calibration {own}, want ~9");
        // ...while plans sharing exactly ONE axis inherit its √ term.
        let rw_shared = cm.calibration("avgcost+levelset");
        let ex_shared = cm.calibration("none+syncfree");
        assert!((rw_shared - 3.0).abs() < 0.3, "rewrite share {rw_shared}");
        assert!((ex_shared - 3.0).abs() < 0.3, "exec share {ex_shared}");
        // Plans sharing neither axis stay at the closed-form seed.
        assert_eq!(cm.calibration("none+levelset"), 1.0);
        // Exec knobs calibrate per category: racing one scheduled shape
        // refines every scheduled shape.
        cm.record("none+scheduled:64:2", 100.0, 400.0);
        assert_eq!(
            cm.calibration("none+scheduled:64:2"),
            cm.calibration("none+scheduled:256:4")
        );
    }

    #[test]
    fn jacobi_pricing_scales_with_sweeps() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let cm = CostModel::new(4);
        let j4 = cm.predict(&f, "none+jacobi:4").unwrap();
        let j8 = cm.predict(&f, "none+jacobi:8").unwrap();
        assert!(j8 > j4, "sweeps must price in: {j4} vs {j8}");
        // Mixed precision discounts the f32 sweeps at equal sweep count.
        let m8 = cm.predict(&f, "none+jacobi-mixed:8").unwrap();
        assert!(m8 < j8, "mixed {m8} not below full {j8}");
        // Every iterative composition is priceable and finite.
        for plan in [
            "avgcost+jacobi:8",
            "manual:10+jacobi-mixed:4",
            "guarded:20+jacobi:2",
        ] {
            assert!(cm.predict(&f, plan).unwrap().is_finite(), "{plan}");
        }
        // A rewrite that merges levels lowers the iterative price too
        // (fewer sweeps needed is priced by the caller; here the work
        // term stays comparable while the estimate shape shifts).
        let est = cm.estimate(&f, "avgcost+jacobi:8").unwrap();
        assert!(est.levels < f.num_levels);
    }

    #[test]
    fn calibration_converges_when_fed_raw_predictions() {
        // Recording measured against predict_raw (NOT the calibrated
        // value) must converge the multiplier to the true ratio, not its
        // square root.
        let f = feats(&generate::tridiagonal(50, &Default::default()));
        let mut cm = CostModel::new(2);
        let raw = cm.predict_raw(&f, "none").unwrap();
        for _ in 0..20 {
            let base = cm.predict_raw(&f, "none").unwrap();
            assert_eq!(base, raw); // raw prediction ignores calibration
            cm.record("none", base, raw * 10.0);
        }
        let cal = cm.calibration("none");
        assert!((cal - 10.0).abs() < 0.5, "calibration {cal}, want ~10");
    }

    #[test]
    fn scheduled_wins_the_serial_chain() {
        // A uniform chain is the scheduled exec's home game: chains
        // collapse into blocks with no barriers and (at parallelism 1) no
        // cross-worker waits, so the model must rank it ahead of every
        // barrier-paying plan.
        let f = feats(&generate::tridiagonal(400, &Default::default()));
        let cm = CostModel::new(4);
        let sched = cm.predict(&f, "scheduled").unwrap();
        for other in ["none", "avgcost", "manual:10", "syncfree"] {
            let c = cm.predict(&f, other).unwrap();
            assert!(sched < c, "scheduled {sched} not < {other} {c}");
        }
    }

    /// Composition: the prediction for a composed plan combines the
    /// rewrite's estimated shape with the exec's synchronization model.
    #[test]
    fn composed_plans_price_both_axes() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let cm = CostModel::new(4);
        // avgcost merges levels, so avgcost+levelset pays fewer barriers
        // than none+levelset...
        let base = cm.predict(&f, "none+levelset").unwrap();
        let avg_ls = cm.predict(&f, "avgcost+levelset").unwrap();
        assert!(avg_ls < base);
        // ...and avgcost+reorder inherits the merged-level shape too: it
        // must beat none+reorder by the same barrier savings.
        let re = cm.predict(&f, "none+reorder").unwrap();
        let avg_re = cm.predict(&f, "avgcost+reorder").unwrap();
        assert!(avg_re < re, "avgcost+reorder {avg_re} vs none+reorder {re}");
        // The legacy single names predict identically to their pairings.
        assert_eq!(cm.predict(&f, "avgcost"), cm.predict(&f, "avgcost+levelset"));
        assert_eq!(cm.predict(&f, "scheduled"), cm.predict(&f, "none+scheduled"));
        assert_eq!(cm.predict(&f, "syncfree"), cm.predict(&f, "none+syncfree"));
        assert_eq!(cm.predict(&f, "reorder"), cm.predict(&f, "none+reorder"));
        // Every cross-product member is priceable and finite.
        for rw in ["none", "avgcost", "manual:10", "guarded:20"] {
            for ex in ["levelset", "scheduled", "syncfree", "reorder"] {
                let plan = format!("{rw}+{ex}");
                assert!(
                    cm.predict(&f, &plan).unwrap().is_finite(),
                    "{plan} not priceable"
                );
            }
        }
        // Reorder keeps the level structure: it differs from levelset only
        // by the modelled locality gain minus the per-solve permutation
        // cost, so the two predictions stay within one permutation pass
        // of each other (the race, not the seed model, settles the call).
        assert!(
            (re - base).abs() <= f.nrows as f64 * PERM_COST + 1.0,
            "reorder {re} vs none {base}"
        );
    }

    #[test]
    fn calibrate_sched_tracks_observed_stall_rates() {
        let f = feats(&generate::lung2_like(&GenOptions::with_scale(0.05)));
        let mut cm = CostModel::new(4);
        assert_eq!(cm.sched_costs(), (WAIT_COST, BLOCK_COST));
        let before = cm.predict(&f, "avgcost+scheduled").unwrap();
        // Observed: 5 stalls per block, half of them absorbed by the
        // lookahead. The wait term must rise toward 5x its seed and the
        // prediction with it; repeated cumulative feeds converge.
        for _ in 0..30 {
            cm.calibrate_sched(500, 500, 100);
        }
        let (w, b) = cm.sched_costs();
        assert!((w - WAIT_COST * 5.0).abs() < WAIT_COST * 0.1, "wait_cost {w}");
        assert!((b - BLOCK_COST * 1.5).abs() < BLOCK_COST * 0.1, "block_cost {b}");
        let after = cm.predict(&f, "avgcost+scheduled").unwrap();
        assert!(after > before, "stall-heavy feedback must raise the price");
        // Only the scheduled arm reprices: the barrier model is untouched.
        assert_eq!(cm.predict(&f, "none+levelset"), CostModel::new(4).predict(&f, "none+levelset"));
        // A stall-free observation walks the terms back down.
        for _ in 0..60 {
            cm.calibrate_sched(0, 0, 100);
        }
        let (w2, _) = cm.sched_costs();
        assert!(w2 < w / 2.0, "stall-free feedback must relax wait_cost: {w2}");
        // Degenerate input (no blocks) is a no-op.
        let costs = cm.sched_costs();
        cm.calibrate_sched(10, 10, 0);
        assert_eq!(cm.sched_costs(), costs);
    }

    #[test]
    fn plan_cost_shape() {
        // More levels cost more at equal work; parallelism caps at width.
        assert!(plan_cost(100, 1000.0, 100, 4) > plan_cost(10, 1000.0, 100, 4));
        // 1-wide chain: workers do not help.
        assert_eq!(plan_cost(100, 1000.0, 100, 1), plan_cost(100, 1000.0, 100, 8));
    }
}
