//! Fingerprint-keyed plan cache: in-memory LRU with optional JSON
//! spill-to-disk.
//!
//! Re-registering a known sparsity structure (same factor, refreshed
//! values; a service restart; another replica warming from a shared
//! volume) skips the cost-model + racing analysis entirely and goes
//! straight to the recorded winning plan. The disk format is the
//! crate's own minimal JSON (`util::json`), so the cache file is
//! greppable and survives toolchain changes (the fingerprint is
//! platform-stable FNV, not `DefaultHasher`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::Error;
use crate::tuner::fingerprint::Fingerprint;
use crate::util::json::Json;

/// Schema/solver version stamped on every spilled plan entry. Entries
/// written under a different version are dropped on load: a raced
/// decision is only as good as the executor that timed it, so bump this
/// whenever the solver, executor or plan semantics change in a way that
/// invalidates previously cached winners. v3: decisions are two-axis
/// solve plans (`rewrite+exec` grammar); v2-era single-strategy entries
/// are dropped. v4: entries carry the certified tolerance of iterative
/// (Jacobi) winners and the calibration table is keyed per axis —
/// v3-era entries and calibrations are dropped.
pub const PLAN_SCHEMA_VERSION: u64 = 4;

/// A tuning decision worth remembering.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedPlan {
    /// winning plan, in `SolvePlan::parse` syntax
    pub plan: String,
    /// winner's best per-solve time when raced, microseconds
    pub solve_us: f64,
    /// every raced candidate's (plan, best solve µs)
    pub timings: Vec<(String, f64)>,
    /// rows of the fingerprinted matrix (sanity check / observability)
    pub nrows: usize,
    /// wall-clock seconds (unix) when the plan was raced; drives the
    /// `tuner_cache_ttl` age expiry on load
    pub created_unix: u64,
    /// relative-residual tolerance the race certified an iterative
    /// winner under (0.0 for exact plans, which certify unconditionally).
    /// A cached iterative decision may only serve requests whose
    /// tolerance is at least this loose.
    pub tolerance: f64,
}

/// Current wall-clock as unix seconds (0 if the clock is before the
/// epoch, which only breaks age expiry, never correctness).
pub fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

pub struct PlanCache {
    capacity: usize,
    path: Option<PathBuf>,
    /// age limit for loaded entries, seconds; 0 = no age expiry
    ttl_secs: u64,
    /// fingerprint -> (LRU stamp, plan); higher stamp = more recent
    entries: BTreeMap<u64, (u64, CachedPlan)>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl PlanCache {
    /// In-memory-only cache holding at most `capacity` plans.
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            path: None,
            ttl_secs: 0,
            entries: BTreeMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Cache backed by a JSON file: loads existing entries (a corrupt or
    /// missing file starts empty with a warning) and saves after every
    /// insertion. Spilled plans never expire by age.
    pub fn with_disk(capacity: usize, path: &Path) -> PlanCache {
        Self::with_disk_ttl(capacity, path, 0)
    }

    /// [`PlanCache::with_disk`] with age expiry: same-schema entries older
    /// than `ttl_secs` are dropped on load (a raced decision goes stale as
    /// the machine, load mix and calibration drift — `tuner_cache_ttl`
    /// bounds how long a win is trusted). `ttl_secs == 0` disables expiry.
    pub fn with_disk_ttl(capacity: usize, path: &Path, ttl_secs: u64) -> PlanCache {
        let mut cache = PlanCache::new(capacity);
        cache.path = Some(path.to_path_buf());
        cache.ttl_secs = ttl_secs;
        if path.exists() {
            match load_entries(path) {
                Ok(mut entries) => {
                    if ttl_secs > 0 {
                        let now = now_unix();
                        entries.retain(|_, (_, plan)| {
                            now.saturating_sub(plan.created_unix) <= ttl_secs
                        });
                    }
                    cache.clock = entries.values().map(|&(s, _)| s).max().unwrap_or(0);
                    cache.entries = entries;
                    cache.trim();
                }
                Err(e) => {
                    eprintln!("warning: ignoring tuner plan cache {}: {e}", path.display());
                }
            }
        }
        cache
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Non-mutating probe: the entry for a fingerprint without bumping
    /// LRU recency or the hit/miss stats (used by the pipeline's
    /// analysis-cache key lookup, which must not skew the accounting of
    /// the real `get` that may follow).
    pub fn peek(&self, fp: Fingerprint) -> Option<&CachedPlan> {
        self.entries.get(&fp.0).map(|(_, plan)| plan)
    }

    /// Look up a fingerprint, refreshing its recency on a hit.
    pub fn get(&mut self, fp: Fingerprint) -> Option<CachedPlan> {
        self.clock += 1;
        let now = self.clock;
        match self.entries.get_mut(&fp.0) {
            Some(entry) => {
                entry.0 = now;
                self.hits += 1;
                Some(entry.1.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) a plan; evicts least-recently-used entries past
    /// capacity and spills to disk when a path is configured.
    pub fn put(&mut self, fp: Fingerprint, plan: CachedPlan) {
        self.clock += 1;
        let now = self.clock;
        self.entries.insert(fp.0, (now, plan));
        self.trim();
        if let Err(e) = self.save() {
            eprintln!("warning: tuner plan cache save failed: {e}");
        }
    }

    fn trim(&mut self) {
        while self.entries.len() > self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    self.entries.remove(&k);
                }
                None => break,
            }
        }
    }

    /// Write the cache to its configured path (no-op without one).
    ///
    /// The spill file is a *union*: entries already on disk that this
    /// process does not know (another replica writing the same shared
    /// volume, or entries this process LRU-evicted from memory) are
    /// preserved rather than clobbered. Same-fingerprint conflicts are
    /// last-writer-wins; there is deliberately no cross-process locking.
    pub fn save(&self) -> Result<(), Error> {
        let Some(path) = &self.path else { return Ok(()) };
        let mut merged: BTreeMap<u64, (u64, CachedPlan)> = if path.exists() {
            load_entries(path).unwrap_or_default()
        } else {
            BTreeMap::new()
        };
        for (fp, entry) in &self.entries {
            merged.insert(*fp, entry.clone());
        }
        let mut items = Vec::with_capacity(merged.len());
        for (fp, (stamp, plan)) in &merged {
            let timings = plan
                .timings
                .iter()
                .map(|(s, us)| Json::Arr(vec![Json::Str(s.clone()), Json::Num(*us)]))
                .collect();
            items.push(Json::obj(vec![
                ("fingerprint", Json::Str(format!("{fp:016x}"))),
                ("plan", Json::Str(plan.plan.clone())),
                ("solve_us", Json::Num(plan.solve_us)),
                ("nrows", Json::Num(plan.nrows as f64)),
                ("stamp", Json::Num(*stamp as f64)),
                ("schema", Json::Num(PLAN_SCHEMA_VERSION as f64)),
                ("created", Json::Num(plan.created_unix as f64)),
                ("tolerance", Json::Num(plan.tolerance)),
                ("timings", Json::Arr(timings)),
            ]));
        }
        let root = Json::obj(vec![
            ("version", Json::Num(PLAN_SCHEMA_VERSION as f64)),
            ("entries", Json::Arr(items)),
        ]);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| Error::Io(format!("create {}: {e}", dir.display())))?;
            }
        }
        // Write-then-rename: a reader (another replica warming from a
        // shared volume, or this process crashing mid-save) must never
        // observe a truncated file.
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".tmp.{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, root.to_string())
            .map_err(|e| Error::Io(format!("write {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            std::fs::remove_file(&tmp).ok();
            Error::Io(format!("rename {} -> {}: {e}", tmp.display(), path.display()))
        })
    }
}

fn load_entries(path: &Path) -> Result<BTreeMap<u64, (u64, CachedPlan)>, Error> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
    let root = Json::parse(&text).map_err(|e| Error::Invalid(e.to_string()))?;
    let items = root
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Invalid("plan cache: missing 'entries' array".into()))?;
    let mut entries = BTreeMap::new();
    for item in items {
        // Drop entries stamped by a different solver/schema version: a
        // decision raced on an old executor may no longer be the winner.
        // (Entries from before versioning carry no stamp and read as 0.)
        let schema = item.get("schema").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        if schema != PLAN_SCHEMA_VERSION {
            continue;
        }
        // Skip malformed rows rather than discarding the whole cache.
        let Some(fp) = item
            .get("fingerprint")
            .and_then(Json::as_str)
            .and_then(Fingerprint::from_hex)
        else {
            continue;
        };
        let Some(plan) = item.get("plan").and_then(Json::as_str) else {
            continue;
        };
        let solve_us = item.get("solve_us").and_then(Json::as_f64).unwrap_or(0.0);
        let nrows = item.get("nrows").and_then(Json::as_usize).unwrap_or(0);
        let stamp = item.get("stamp").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let created_unix = item.get("created").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let tolerance = item.get("tolerance").and_then(Json::as_f64).unwrap_or(0.0);
        let mut timings = Vec::new();
        if let Some(arr) = item.get("timings").and_then(Json::as_arr) {
            for pair in arr {
                if let Some(p) = pair.as_arr() {
                    if let (Some(s), Some(us)) =
                        (p.first().and_then(Json::as_str), p.get(1).and_then(Json::as_f64))
                    {
                        timings.push((s.to_string(), us));
                    }
                }
            }
        }
        entries.insert(
            fp.0,
            (
                stamp,
                CachedPlan {
                    plan: plan.to_string(),
                    solve_us,
                    timings,
                    nrows,
                    created_unix,
                    tolerance,
                },
            ),
        );
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(winner: &str, us: f64) -> CachedPlan {
        CachedPlan {
            plan: winner.to_string(),
            solve_us: us,
            timings: vec![("none+levelset".into(), us * 2.0), (winner.to_string(), us)],
            nrows: 100,
            created_unix: now_unix(),
            tolerance: 0.0,
        }
    }

    fn fp(v: u64) -> Fingerprint {
        Fingerprint(v)
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = PlanCache::new(4);
        assert!(c.get(fp(1)).is_none());
        c.put(fp(1), plan("avgcost+levelset", 10.0));
        let got = c.get(fp(1)).unwrap();
        assert_eq!(got.plan, "avgcost+levelset");
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(c.len(), 1);
        assert!(!c.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = PlanCache::new(2);
        c.put(fp(1), plan("a", 1.0));
        c.put(fp(2), plan("b", 1.0));
        assert!(c.get(fp(1)).is_some()); // 1 is now more recent than 2
        c.put(fp(3), plan("c", 1.0)); // evicts 2
        assert!(c.get(fp(2)).is_none());
        assert!(c.get(fp(1)).is_some());
        assert!(c.get(fp(3)).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn disk_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_plan_cache_{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        {
            let mut c = PlanCache::with_disk(8, &path);
            c.put(fp(0xDEAD), plan("manual:10+scheduled", 42.5));
            let mut inexact = plan("avgcost+jacobi:8", 7.25);
            inexact.tolerance = 1e-6;
            c.put(fp(0xBEEF), inexact);
        }
        let mut c2 = PlanCache::with_disk(8, &path);
        assert_eq!(c2.len(), 2);
        let got = c2.get(fp(0xDEAD)).unwrap();
        assert_eq!(got.plan, "manual:10+scheduled");
        assert_eq!(got.solve_us, 42.5);
        assert_eq!(got.timings.len(), 2);
        assert_eq!(got.nrows, 100);
        assert_eq!(got.tolerance, 0.0, "exact plans certify unconditionally");
        // The certified tolerance of an iterative decision survives disk.
        let inexact = c2.get(fp(0xBEEF)).unwrap();
        assert_eq!(inexact.plan, "avgcost+jacobi:8");
        assert_eq!(inexact.tolerance, 1e-6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_merges_with_other_writers() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_plan_cache_merge_{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        // Two replicas sharing one spill file, each tuning a different
        // structure: neither save may clobber the other's entry.
        let mut a = PlanCache::with_disk(8, &path);
        let mut b = PlanCache::with_disk(8, &path);
        a.put(fp(1), plan("avgcost+levelset", 1.0));
        b.put(fp(2), plan("manual:10+syncfree", 2.0));
        let mut fresh = PlanCache::with_disk(8, &path);
        assert_eq!(fresh.len(), 2);
        assert_eq!(fresh.get(fp(1)).unwrap().plan, "avgcost+levelset");
        assert_eq!(fresh.get(fp(2)).unwrap().plan, "manual:10+syncfree");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stale_schema_entries_dropped_on_load() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_plan_cache_schema_{}.json",
            std::process::id()
        ));
        // One entry from the current solver version, one from a stale one
        // (and one pre-versioning entry with no stamp at all).
        let text = format!(
            r#"{{"version": {v}, "entries": [
  {{"fingerprint": "00000000000000aa", "plan": "avgcost+scheduled", "solve_us": 1.5,
    "nrows": 10, "stamp": 1, "schema": {v}, "timings": []}},
  {{"fingerprint": "00000000000000bb", "plan": "manual:10", "solve_us": 2.5,
    "nrows": 10, "stamp": 2, "schema": 2, "timings": []}},
  {{"fingerprint": "00000000000000cc", "plan": "none", "solve_us": 3.5,
    "nrows": 10, "stamp": 3, "timings": []}}
]}}"#,
            v = PLAN_SCHEMA_VERSION
        );
        std::fs::write(&path, text).unwrap();
        let mut c = PlanCache::with_disk(8, &path);
        assert_eq!(c.len(), 1, "only the current-version entry survives");
        assert_eq!(c.get(fp(0xAA)).unwrap().plan, "avgcost+scheduled");
        assert!(c.get(fp(0xBB)).is_none());
        assert!(c.get(fp(0xCC)).is_none());
        // Re-saving persists only current-version entries: the stale ones
        // are gone from the file too.
        c.put(fp(0xDD), plan("guarded:20+levelset", 4.0));
        let reread = PlanCache::with_disk(8, &path);
        assert_eq!(reread.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ttl_expires_old_entries_on_load() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_plan_cache_ttl_{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        {
            let mut c = PlanCache::with_disk(8, &path);
            let mut old = plan("manual:10+levelset", 5.0);
            old.created_unix = now_unix().saturating_sub(10_000);
            c.put(fp(1), old);
            c.put(fp(2), plan("avgcost+levelset", 3.0)); // fresh
        }
        // Without a TTL both entries survive a reload.
        let c = PlanCache::with_disk(8, &path);
        assert_eq!(c.len(), 2);
        // With a 1-hour TTL only the fresh entry survives; the stale one
        // is dropped on load.
        let mut c = PlanCache::with_disk_ttl(8, &path, 3600);
        assert_eq!(c.len(), 1);
        assert!(c.get(fp(1)).is_none());
        assert_eq!(c.get(fp(2)).unwrap().plan, "avgcost+levelset");
        // A TTL far wider than the age keeps everything.
        let c = PlanCache::with_disk_ttl(8, &path, 100_000);
        assert_eq!(c.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_file_starts_empty() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_plan_cache_bad_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, "{ not json").unwrap();
        let c = PlanCache::with_disk(4, &path);
        assert!(c.is_empty());
        std::fs::remove_file(&path).ok();
    }
}
