//! Stable sparsity-structure fingerprints.
//!
//! The plan cache is keyed by *structure*, not values: SpTRSV strategy
//! choice depends only on the dependency graph, and serving workloads
//! re-register the same factor with refreshed numerical values (new
//! factorization, scaled systems). The fingerprint therefore hashes
//! dimensions, row lengths and column indices — never `data` — so a
//! value-perturbed re-registration hits the cached plan.
//!
//! FNV-1a 64-bit: tiny, dependency-free, and fully deterministic across
//! platforms (unlike `DefaultHasher`, whose output is unspecified and
//! would invalidate the on-disk cache between toolchains).

use std::fmt;

use crate::sparse::Csr;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// A 64-bit structural fingerprint of a sparse matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

impl Fingerprint {
    /// Fingerprint of a CSR matrix's sparsity structure.
    pub fn of(m: &Csr) -> Fingerprint {
        let mut h = FNV_OFFSET;
        h = fnv_u64(h, m.nrows as u64);
        h = fnv_u64(h, m.ncols as u64);
        h = fnv_u64(h, m.nnz() as u64);
        for w in m.indptr.windows(2) {
            h = fnv_u64(h, (w[1] - w[0]) as u64);
        }
        for &c in &m.indices {
            h = fnv_u64(h, c as u64);
        }
        Fingerprint(h)
    }

    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    pub fn from_hex(s: &str) -> Option<Fingerprint> {
        u64::from_str_radix(s.trim(), 16).ok().map(Fingerprint)
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Fold one u64 (little-endian bytes) into an FNV-1a state.
#[inline]
fn fnv_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    #[test]
    fn stable_across_value_perturbation() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v = *v * 1.0001 + 0.5;
        }
        assert_ne!(m.data, m2.data);
        assert_eq!(Fingerprint::of(&m), Fingerprint::of(&m2));
    }

    #[test]
    fn sensitive_to_structure() {
        let a = generate::tridiagonal(50, &Default::default());
        let b = generate::tridiagonal(51, &Default::default());
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&b));
        let c = generate::banded(50, 3, 0.5, &Default::default());
        assert_ne!(Fingerprint::of(&a), Fingerprint::of(&c));
    }

    #[test]
    fn hex_roundtrip() {
        let m = generate::tridiagonal(10, &Default::default());
        let fp = Fingerprint::of(&m);
        assert_eq!(Fingerprint::from_hex(&fp.to_hex()), Some(fp));
        assert_eq!(fp.to_hex().len(), 16);
        assert!(Fingerprint::from_hex("not-hex").is_none());
    }

    #[test]
    fn deterministic_across_instances() {
        let o = GenOptions::with_scale(0.02);
        let a = Fingerprint::of(&generate::torso2_like(&o));
        let b = Fingerprint::of(&generate::torso2_like(&o));
        assert_eq!(a, b);
    }
}
