//! Structural feature extraction: the compact description of a matrix the
//! cost model predicts from.
//!
//! Everything here is O(nnz) and derived purely from the sparsity
//! structure and the level partition — no values — so features are stable
//! under value perturbation, matching the fingerprint's invariance.

use crate::graph::analyze::LevelStats;
use crate::graph::Levels;
use crate::sparse::Csr;
use crate::util::json::Json;

/// Feature vector of one matrix under its level-set partition.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixFeatures {
    pub nrows: usize,
    pub nnz: usize,
    /// number of levels in DAG_L (== critical-path length in rows)
    pub num_levels: usize,
    pub critical_path_len: usize,
    pub mean_level_width: f64,
    pub p95_level_width: usize,
    pub max_level_width: usize,
    /// mean off-diagonal dependencies per row
    pub avg_indegree: f64,
    /// paper cost model: total level cost = 2*nnz - n
    pub total_cost: u64,
    pub avg_level_cost: f64,
    /// levels with cost < avgLevelCost (the avgcost strategy's criterion)
    pub thin_cost_levels: usize,
    /// mean cost of those thin levels (0 when there are none)
    pub mean_thin_level_cost: f64,
    /// levels with width <= avg width (the manual strategy's criterion)
    pub thin_width_levels: usize,
    /// summed cost of the width-thin levels
    pub thin_width_cost: u64,
}

impl MatrixFeatures {
    /// Extract features from a matrix and its (already built) level sets.
    pub fn extract(m: &Csr, lv: &Levels) -> MatrixFeatures {
        let st = LevelStats::from_csr(m, lv);
        let nrows = m.nrows;
        let nnz = m.nnz();
        let num_levels = st.num_levels;

        let mut widths = st.level_widths.clone();
        widths.sort_unstable();
        let p95_level_width = if widths.is_empty() {
            0
        } else {
            let idx = ((widths.len() as f64 * 0.95).ceil() as usize)
                .clamp(1, widths.len())
                - 1;
            widths[idx]
        };
        let max_level_width = widths.last().copied().unwrap_or(0);

        let thin_cost: Vec<usize> = st.thin_levels();
        let thin_cost_sum: u64 = thin_cost.iter().map(|&l| st.level_costs[l]).sum();
        let mean_thin_level_cost = if thin_cost.is_empty() {
            0.0
        } else {
            thin_cost_sum as f64 / thin_cost.len() as f64
        };

        let avg_width = st.avg_width();
        let mut thin_width_levels = 0usize;
        let mut thin_width_cost = 0u64;
        for (l, &w) in st.level_widths.iter().enumerate() {
            if w as f64 <= avg_width {
                thin_width_levels += 1;
                thin_width_cost += st.level_costs[l];
            }
        }

        MatrixFeatures {
            nrows,
            nnz,
            num_levels,
            critical_path_len: num_levels,
            mean_level_width: avg_width,
            p95_level_width,
            max_level_width,
            // saturating: a structurally invalid matrix (empty rows) must
            // not underflow here — downstream validation rejects it.
            avg_indegree: if nrows == 0 {
                0.0
            } else {
                nnz.saturating_sub(nrows) as f64 / nrows as f64
            },
            total_cost: st.total_cost,
            avg_level_cost: st.avg_level_cost,
            thin_cost_levels: thin_cost.len(),
            mean_thin_level_cost,
            thin_width_levels,
            thin_width_cost,
        }
    }

    /// Convenience: build the level sets and extract in one step.
    pub fn of(m: &Csr) -> MatrixFeatures {
        let lv = Levels::build(m);
        Self::extract(m, &lv)
    }

    /// Fraction of levels below the average cost (the paper's thin-level
    /// share: ~94% for lung2).
    pub fn thin_cost_fraction(&self) -> f64 {
        if self.num_levels == 0 {
            0.0
        } else {
            self.thin_cost_levels as f64 / self.num_levels as f64
        }
    }

    /// JSON rendering for the `tune` CLI and persisted reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nrows", Json::Num(self.nrows as f64)),
            ("nnz", Json::Num(self.nnz as f64)),
            ("num_levels", Json::Num(self.num_levels as f64)),
            ("mean_level_width", Json::Num(self.mean_level_width)),
            ("p95_level_width", Json::Num(self.p95_level_width as f64)),
            ("max_level_width", Json::Num(self.max_level_width as f64)),
            ("avg_indegree", Json::Num(self.avg_indegree)),
            ("total_cost", Json::Num(self.total_cost as f64)),
            ("avg_level_cost", Json::Num(self.avg_level_cost)),
            ("thin_cost_levels", Json::Num(self.thin_cost_levels as f64)),
            ("thin_width_levels", Json::Num(self.thin_width_levels as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    #[test]
    fn tridiagonal_features() {
        let m = generate::tridiagonal(100, &Default::default());
        let f = MatrixFeatures::of(&m);
        assert_eq!(f.nrows, 100);
        assert_eq!(f.num_levels, 100);
        assert_eq!(f.critical_path_len, 100);
        assert_eq!(f.max_level_width, 1);
        assert_eq!(f.p95_level_width, 1);
        // Uniform chain: no level is strictly below the average cost
        // (levels 1..n cost 3, level 0 costs 1 — only level 0 is thin).
        assert!(f.thin_cost_levels <= 1);
        // Every level has width == avg width, so all are width-thin.
        assert_eq!(f.thin_width_levels, 100);
        assert!((f.avg_indegree - 0.99).abs() < 0.011);
        assert_eq!(f.total_cost, (2 * m.nnz() - m.nrows) as u64);
    }

    #[test]
    fn lung2_like_is_mostly_thin() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.05));
        let f = MatrixFeatures::of(&m);
        assert!(f.thin_cost_fraction() > 0.85, "{}", f.thin_cost_fraction());
        assert!(f.mean_thin_level_cost < f.avg_level_cost);
        assert!(f.max_level_width > 100 * 2);
        assert!(f.avg_indegree <= 2.0);
    }

    #[test]
    fn features_stable_under_value_perturbation() {
        let m = generate::torso2_like(&GenOptions::with_scale(0.02));
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 1.5;
        }
        assert_eq!(MatrixFeatures::of(&m), MatrixFeatures::of(&m2));
    }

    #[test]
    fn empty_matrix_features() {
        let m = Csr::new(0, 0, vec![0], vec![], vec![]).unwrap();
        let f = MatrixFeatures::of(&m);
        assert_eq!(f.num_levels, 0);
        assert_eq!(f.thin_cost_fraction(), 0.0);
        assert_eq!(f.avg_indegree, 0.0);
    }

    #[test]
    fn json_rendering_contains_keys() {
        let m = generate::tridiagonal(10, &Default::default());
        let s = MatrixFeatures::of(&m).to_json().to_string();
        assert!(s.contains("\"num_levels\":10"));
        assert!(s.contains("\"nrows\":10"));
    }
}
