//! Persistence for the cost model's EWMA calibration, alongside the plan
//! cache.
//!
//! The plan cache remembers *decisions*; the calibration table remembers
//! how far the closed-form model was off per plan. Spilling only the
//! former meant every restart re-learned the multipliers from scratch —
//! the ROADMAP's "persist cost-model calibration" follow-up. The table is
//! written next to the plan-cache file (`plans.json` →
//! `plans.calib.json`) in the crate's minimal JSON, stamped with
//! [`PLAN_SCHEMA_VERSION`]: multipliers learned against an older solver
//! or plan grammar are dropped on load rather than trusted stale.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::Error;
use crate::tuner::plan_cache::PLAN_SCHEMA_VERSION;
use crate::util::json::Json;

/// Sibling path for the calibration table of a plan-cache spill file:
/// the full cache filename plus `.calib.json`. Appending (rather than
/// replacing the extension) keeps the mapping injective — `plans.v1` and
/// `plans.v2` must not share one calibration file.
pub fn path_for(cache_path: &Path) -> PathBuf {
    let mut os = cache_path.as_os_str().to_owned();
    os.push(".calib.json");
    PathBuf::from(os)
}

/// Load a persisted calibration table. Returns an empty table when the
/// file is absent, unparseable (with a warning) or stamped by a different
/// schema version.
pub fn load(path: &Path) -> BTreeMap<String, f64> {
    if !path.exists() {
        return BTreeMap::new();
    }
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("warning: ignoring tuner calibration {}: {e}", path.display());
            return BTreeMap::new();
        }
    };
    let root = match Json::parse(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("warning: ignoring tuner calibration {}: {e}", path.display());
            return BTreeMap::new();
        }
    };
    let version = root.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    if version != PLAN_SCHEMA_VERSION {
        return BTreeMap::new();
    }
    let mut table = BTreeMap::new();
    if let Some(entries) = root.get("entries").and_then(Json::as_arr) {
        for pair in entries {
            if let Some(p) = pair.as_arr() {
                if let (Some(plan), Some(mult)) = (
                    p.first().and_then(Json::as_str),
                    p.get(1).and_then(Json::as_f64),
                ) {
                    if mult.is_finite() && mult > 0.0 {
                        table.insert(plan.to_string(), mult);
                    }
                }
            }
        }
    }
    table
}

/// Atomically write the calibration table (write-then-rename, like the
/// plan cache: a concurrent reader never observes a truncated file).
pub fn save(path: &Path, table: &BTreeMap<String, f64>) -> Result<(), Error> {
    let entries: Vec<Json> = table
        .iter()
        .map(|(plan, mult)| Json::Arr(vec![Json::Str(plan.clone()), Json::Num(*mult)]))
        .collect();
    let root = Json::obj(vec![
        ("version", Json::Num(PLAN_SCHEMA_VERSION as f64)),
        ("entries", Json::Arr(entries)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::Io(format!("create {}: {e}", dir.display())))?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, root.to_string())
        .map_err(|e| Error::Io(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, path).map_err(|e| {
        std::fs::remove_file(&tmp).ok();
        Error::Io(format!("rename {} -> {}: {e}", tmp.display(), path.display()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sibling_path() {
        assert_eq!(
            path_for(Path::new("/var/cache/plans.json")),
            PathBuf::from("/var/cache/plans.json.calib.json")
        );
        assert_eq!(
            path_for(Path::new("plans")),
            PathBuf::from("plans.calib.json")
        );
        // Injective: caches differing only in extension get distinct
        // calibration files.
        assert_ne!(
            path_for(Path::new("plans.v1")),
            path_for(Path::new("plans.v2"))
        );
    }

    #[test]
    fn roundtrip_and_schema_guard() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_calib_{}.calib.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        assert!(load(&path).is_empty(), "absent file loads empty");
        let mut table = BTreeMap::new();
        table.insert("avgcost+scheduled".to_string(), 2.5);
        table.insert("none+levelset".to_string(), 0.8);
        save(&path, &table).unwrap();
        assert_eq!(load(&path), table);
        // A stale schema version is dropped wholesale.
        let stale = format!(
            r#"{{"version": {}, "entries": [["none+levelset", 3.0]]}}"#,
            PLAN_SCHEMA_VERSION - 1
        );
        std::fs::write(&path, stale).unwrap();
        assert!(load(&path).is_empty());
        // Corrupt files warn and load empty instead of failing the tuner.
        std::fs::write(&path, "{ not json").unwrap();
        assert!(load(&path).is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_multipliers_filtered_on_load() {
        let path = std::env::temp_dir().join(format!(
            "sptrsv_calib_bad_{}.calib.json",
            std::process::id()
        ));
        let text = format!(
            r#"{{"version": {PLAN_SCHEMA_VERSION}, "entries": [
  ["good+levelset", 1.5], ["zero+levelset", 0.0], ["neg+levelset", -2.0]
]}}"#
        );
        std::fs::write(&path, text).unwrap();
        let table = load(&path);
        assert_eq!(table.len(), 1);
        assert_eq!(table.get("good+levelset"), Some(&1.5));
        std::fs::remove_file(&path).ok();
    }
}
