//! Empirical plan racing: apply each shortlisted plan's rewrite for
//! real, build its execution backend, warm it up, and time a few solves.
//!
//! The cost model shortlists; the race decides. This mirrors how analysis
//! cost is amortized in serving (Li 2017): the transform + a handful of
//! warm-up solves are paid once per new sparsity structure, then the
//! winning plan is cached by fingerprint and reused for every later
//! registration of that structure.

use std::sync::Arc;
use std::time::Instant;

use crate::sched::SchedOptions;
use crate::solver::dispatch::ExecSolver;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{SolvePlan, TransformResult};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct RaceOptions {
    /// timed solves per candidate (after one warm-up solve)
    pub solves: usize,
    /// worker threads when `pool` is None (a throwaway pool is spawned)
    pub workers: usize,
    /// seed for the right-hand side used by every lane
    pub seed: u64,
    /// scheduling knobs for `scheduled` lanes (filled where a candidate
    /// leaves them unset), so the race measures the exact schedule the
    /// caller would serve with
    pub sched: SchedOptions,
    /// run raced solves on this shared pool (the serving pipeline's) so a
    /// plan-cache miss pays no thread spawn/teardown cost
    pub pool: Option<Arc<Pool>>,
    /// accuracy constraint: a lane whose achieved relative residual
    /// exceeds this tolerance is disqualified from winning, however fast
    /// it raced (None = speed alone decides — exact backends only)
    pub tolerance: Option<f64>,
    /// right-hand sides per timed iteration: each lane solves a
    /// `batch`-wide RHS block, so candidates are ranked under the load
    /// the serving batcher actually presents (a plan that wins on one
    /// RHS can lose once per-solve setup amortizes over a batch)
    pub batch: usize,
}

impl Default for RaceOptions {
    fn default() -> Self {
        RaceOptions {
            solves: 3,
            workers: 4,
            seed: 0x7E57,
            sched: SchedOptions::default(),
            pool: None,
            tolerance: None,
            batch: 1,
        }
    }
}

/// One raced candidate.
pub struct Lane {
    /// the candidate's plan name, verbatim
    pub plan: String,
    /// wall-clock of the rewrite + backend build (the analysis cost)
    pub transform_ms: f64,
    /// best-of-N per-solve time, microseconds
    pub solve_us: f64,
    /// achieved relative residual of the lane's last raced solve against
    /// the ORIGINAL system (what a request tolerance is stated in)
    pub residual: f64,
    /// false when a tolerance was in force and this lane missed it: the
    /// lane still reports its timing but can no longer win
    pub qualified: bool,
    pub levels_after: usize,
    pub total_cost_after: u64,
    /// the applied transform, shared with the lane's solver
    pub transform: Arc<TransformResult>,
    /// the lane's built execution backend. Kept only for the winning
    /// lane — the analysis layer adopts it instead of rebuilding the
    /// same transform + schedule it just raced; losers are dropped when
    /// the race settles.
    pub solver: Option<ExecSolver>,
}

pub struct RaceOutcome {
    pub lanes: Vec<Lane>,
    /// index into `lanes` of the fastest candidate
    pub winner: usize,
}

impl RaceOutcome {
    pub fn winner_lane(&self) -> &Lane {
        &self.lanes[self.winner]
    }
}

/// Race `candidates` (plan names) on `m`. Unparseable names — including
/// `auto`, which is a request to run this very machinery — are skipped;
/// errors only if no candidate survives. Takes the matrix by Arc so large
/// factors are never deep-copied onto the tuning path.
pub fn race(m: &Arc<Csr>, candidates: &[String], opts: &RaceOptions) -> Result<RaceOutcome, String> {
    let solves = opts.solves.max(1);
    // One pool shared by every lane: thread spawn cost must not skew the
    // comparison toward whichever lane runs first. Callers that already
    // run a pool (the serving pipeline) lend it via `opts.pool` so the
    // race measures at the exact parallel substrate serving will use.
    let pool = match &opts.pool {
        Some(p) => Arc::clone(p),
        None => Arc::new(Pool::new(opts.workers)),
    };
    let batch = opts.batch.max(1);
    let mut rng = Rng::new(opts.seed);
    // The RHS block every lane solves per timed iteration — one column
    // per batched right-hand side the serving batcher would present.
    let bs: Vec<Vec<f64>> = (0..batch)
        .map(|_| (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();

    let mut lanes: Vec<Lane> = Vec::with_capacity(candidates.len());
    for name in candidates {
        let Ok(plan) = SolvePlan::parse(name) else {
            continue; // unknown names and `auto` never race
        };
        let t0 = Instant::now();
        let t_arc = Arc::new(plan.apply(m));
        let levels_after = t_arc.stats.levels_after;
        let total_cost_after = t_arc.stats.total_level_cost_after;

        // Each lane runs on the backend its exec axis calls for
        // (level-set executor, coarsened schedule, sync-free, reordered),
        // over the system its rewrite axis produced — racing everything
        // on the level-set executor would misprice the composition.
        // Schedule/permutation construction is part of the lane's
        // analysis cost, so the transform clock covers the build too.
        let solver = match ExecSolver::build(
            Arc::clone(m),
            Arc::clone(&t_arc),
            &plan.exec,
            Arc::clone(&pool),
            opts.sched,
        ) {
            Ok(s) => s,
            Err(_) => continue, // unraceable here (e.g. permutation failed)
        };
        let transform_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut x = vec![0.0; m.nrows];
        solver.solve_into(&bs[0], &mut x); // warm-up: page in the plan
        let mut best = f64::INFINITY;
        for _ in 0..solves {
            let s0 = Instant::now();
            for b in &bs {
                solver.solve_into(b, &mut x);
            }
            // Normalize to per-solve so `solve_us` compares across batch
            // settings (and the report stays in familiar units).
            best = best.min(s0.elapsed().as_secs_f64() * 1e6 / batch as f64);
        }
        // The accuracy gate: measured against the original system, which
        // is what a request tolerance promises about. Exact lanes sit at
        // rounding error and sail through; an iterative lane whose sweep
        // budget undershoots is disqualified no matter how fast it was.
        // (`x` holds the block's last column after the timing loop.)
        let residual = crate::iterative::relative_residual(m, &x, &bs[batch - 1]);
        let qualified = opts.tolerance.is_none_or(|tol| residual <= tol);
        lanes.push(Lane {
            plan: name.clone(),
            transform_ms,
            solve_us: best,
            residual,
            qualified,
            levels_after,
            total_cost_after,
            transform: t_arc,
            solver: Some(solver),
        });
    }
    if lanes.is_empty() {
        return Err("no raceable candidate plans".to_string());
    }
    // Fastest qualified lane wins; if the tolerance disqualified every
    // lane, the most accurate one wins as a best effort (the serving
    // layer's fallback ladder owns the hard accuracy guarantee).
    let candidates_ord = |a: &Lane, b: &Lane| {
        a.solve_us
            .partial_cmp(&b.solve_us)
            .unwrap_or(std::cmp::Ordering::Equal)
    };
    let winner = lanes
        .iter()
        .enumerate()
        .filter(|(_, l)| l.qualified)
        .min_by(|a, b| candidates_ord(a.1, b.1))
        .or_else(|| {
            lanes.iter().enumerate().min_by(|a, b| {
                a.1.residual
                    .partial_cmp(&b.1.residual)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    // Only the winner's backend is worth keeping (the analysis layer
    // adopts it); the losing lanes' solvers free their memory now.
    for (i, lane) in lanes.iter_mut().enumerate() {
        if i != winner {
            lane.solver = None;
        }
    }
    Ok(RaceOutcome { lanes, winner })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn race_produces_a_winner_with_valid_plans() {
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let opts = RaceOptions {
            solves: 2,
            workers: 2,
            ..Default::default()
        };
        let out = race(&m, &names(&["none", "avgcost"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 2);
        for (i, lane) in out.lanes.iter().enumerate() {
            assert!(lane.solve_us.is_finite() && lane.solve_us >= 0.0);
            lane.transform.validate(&m).unwrap();
            // Only the winner keeps its built backend for donation.
            assert_eq!(lane.solver.is_some(), i == out.winner, "{}", lane.plan);
        }
        let w = out.winner_lane();
        assert!(w.plan == "none" || w.plan == "avgcost");
    }

    #[test]
    fn race_runs_on_a_shared_pool() {
        let m = Arc::new(generate::tridiagonal(80, &Default::default()));
        let pool = Arc::new(Pool::new(2));
        let opts = RaceOptions {
            solves: 1,
            pool: Some(Arc::clone(&pool)),
            ..Default::default()
        };
        let out = race(&m, &names(&["none", "manual:5"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 2);
        // The lender keeps sole ownership once the race outcome (whose
        // winning lane's donated backend also runs on the shared pool) is
        // dropped: no worker threads were spawned or leaked by the race.
        drop(opts);
        drop(out);
        assert_eq!(Arc::strong_count(&pool), 1);
    }

    #[test]
    fn composed_plans_race_on_their_own_backends() {
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let opts = RaceOptions {
            solves: 1,
            workers: 2,
            ..Default::default()
        };
        let out = race(
            &m,
            &names(&["avgcost+scheduled:64:2", "avgcost+syncfree", "guarded:5+reorder"]),
            &opts,
        )
        .unwrap();
        assert_eq!(out.lanes.len(), 3);
        for lane in &out.lanes {
            assert!(lane.solve_us.is_finite() && lane.solve_us >= 0.0);
            // Composed lanes really ran their rewrite axis: the lane's
            // transform is the rewritten system, not the identity.
            assert!(lane.transform.stats.rows_rewritten > 0, "{}", lane.plan);
            lane.transform.validate(&m).unwrap();
        }
    }

    #[test]
    fn execution_only_plans_keep_the_identity_transform() {
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let opts = RaceOptions {
            solves: 1,
            workers: 2,
            ..Default::default()
        };
        let out = race(&m, &names(&["scheduled:64:2", "syncfree", "reorder"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 3);
        for lane in &out.lanes {
            assert_eq!(lane.transform.stats.rows_rewritten, 0);
            lane.transform.validate(&m).unwrap();
        }
    }

    #[test]
    fn tolerance_disqualifies_inaccurate_lanes() {
        // One Jacobi sweep on a long chain is x = D⁻¹b — fast and very
        // wrong. Under a tolerance it must lose to the exact lane even
        // when its clock is better; without one it may win on speed.
        let m = Arc::new(generate::tridiagonal(3000, &Default::default()));
        let opts = RaceOptions {
            solves: 1,
            workers: 2,
            tolerance: Some(1e-10),
            ..Default::default()
        };
        let out = race(&m, &names(&["none+jacobi:1", "none+levelset"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 2);
        let jac = out.lanes.iter().find(|l| l.plan == "none+jacobi:1").unwrap();
        let exact = out.lanes.iter().find(|l| l.plan == "none+levelset").unwrap();
        assert!(!jac.qualified, "1 sweep cannot certify 1e-10: {}", jac.residual);
        assert!(jac.residual > 1e-10);
        assert!(exact.qualified, "exact lane at {}", exact.residual);
        assert_eq!(out.winner_lane().plan, "none+levelset");
        // Enough sweeps for nilpotency-index exactness qualifies: on a
        // rewritten chain the level count (and so the needed sweep
        // budget) drops with the rewrite.
        let opts_ok = RaceOptions {
            solves: 1,
            workers: 2,
            tolerance: Some(1e-10),
            ..Default::default()
        };
        let m2 = Arc::new(generate::tridiagonal(40, &Default::default()));
        let out2 = race(&m2, &names(&["manual:5+jacobi:16", "none+levelset"]), &opts_ok).unwrap();
        for lane in &out2.lanes {
            assert!(lane.qualified, "{}: residual {}", lane.plan, lane.residual);
        }
        // Without a tolerance nothing is disqualified.
        let free = race(
            &m2,
            &names(&["none+jacobi:1", "none+levelset"]),
            &RaceOptions {
                solves: 1,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(free.lanes.iter().all(|l| l.qualified));
    }

    #[test]
    fn batched_race_times_an_rhs_block_per_iteration() {
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let opts = RaceOptions {
            solves: 2,
            workers: 2,
            batch: 4,
            tolerance: Some(1e-8),
            ..Default::default()
        };
        let out = race(&m, &names(&["none", "avgcost"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 2);
        for lane in &out.lanes {
            // Per-solve normalization keeps batched timings in the same
            // units as batch=1 runs.
            assert!(lane.solve_us.is_finite() && lane.solve_us >= 0.0);
            // Exact lanes certify the tolerance on the block's last
            // column — the residual gate still operates under batching.
            assert!(lane.qualified, "{}: residual {}", lane.plan, lane.residual);
            assert!(lane.residual < 1e-8);
        }
    }

    #[test]
    fn unparseable_and_auto_candidates_are_skipped() {
        let m = Arc::new(generate::tridiagonal(60, &Default::default()));
        let opts = RaceOptions {
            solves: 1,
            workers: 1,
            ..Default::default()
        };
        let out = race(&m, &names(&["auto", "nonsense", "manual:5"]), &opts).unwrap();
        assert_eq!(out.lanes.len(), 1);
        assert_eq!(out.lanes[0].plan, "manual:5");
        assert_eq!(out.lanes[0].levels_after, 12);
        assert!(race(&m, &names(&["auto", "nope"]), &opts).is_err());
    }
}
