//! Compressed Sparse Row storage and the lower-triangular invariants the
//! solver stack relies on.

use crate::error::Error;

/// CSR matrix. For SpTRSV use the matrix must satisfy
/// [`Csr::validate_lower_triangular`]: square, every row's column indices
/// strictly ascending, all indices `<= row`, and the diagonal present (and
/// therefore last) in every row with a nonzero value.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub data: Vec<f64>,
}

impl Csr {
    pub fn new(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Result<Self, Error> {
        if indptr.len() != nrows + 1 {
            return Err(Error::Invalid(format!(
                "indptr length {} != nrows+1 {}",
                indptr.len(),
                nrows + 1
            )));
        }
        if indices.len() != data.len() {
            return Err(Error::Invalid("indices/data length mismatch".into()));
        }
        if *indptr.last().unwrap_or(&0) != indices.len() {
            return Err(Error::Invalid("indptr tail != nnz".into()));
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(Error::Invalid("indptr not monotone".into()));
        }
        if indices.iter().any(|&c| c as usize >= ncols) {
            return Err(Error::Invalid("column index out of range".into()));
        }
        Ok(Csr {
            nrows,
            ncols,
            indptr,
            indices,
            data,
        })
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row i (including the diagonal if stored).
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i]..self.indptr[i + 1]]
    }

    #[inline]
    pub fn row_vals(&self, i: usize) -> &[f64] {
        &self.data[self.indptr[i]..self.indptr[i + 1]]
    }

    /// Off-diagonal dependencies of row i (all stored columns except the
    /// last). Valid only on a validated lower-triangular matrix.
    #[inline]
    pub fn row_deps(&self, i: usize) -> &[u32] {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        &self.indices[lo..hi - 1]
    }

    #[inline]
    pub fn row_dep_vals(&self, i: usize) -> &[f64] {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        &self.data[lo..hi - 1]
    }

    /// Diagonal value of row i (last stored entry).
    #[inline]
    pub fn diag(&self, i: usize) -> f64 {
        self.data[self.indptr[i + 1] - 1]
    }

    /// Number of off-diagonal dependencies (indegree in DAG_L) of row i.
    #[inline]
    pub fn indegree(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i] - 1
    }

    /// Paper cost model: FLOPs to compute row i = 2*nnz(row) - 1
    /// (a multiply+add per dependency, plus subtract-free diagonal divide).
    #[inline]
    pub fn row_cost(&self, i: usize) -> usize {
        2 * (self.indptr[i + 1] - self.indptr[i]) - 1
    }

    /// Check every lower-triangular SpTRSV invariant; cheap enough to call
    /// at system boundaries (file load, generator output).
    pub fn validate_lower_triangular(&self) -> Result<(), Error> {
        if self.nrows != self.ncols {
            return Err(Error::Invalid(format!(
                "not square: {}x{}",
                self.nrows, self.ncols
            )));
        }
        for i in 0..self.nrows {
            let cols = self.row_cols(i);
            if cols.is_empty() {
                return Err(Error::Invalid(format!("row {i}: empty (no diagonal)")));
            }
            if cols.windows(2).any(|w| w[0] >= w[1]) {
                return Err(Error::Invalid(format!("row {i}: columns not ascending")));
            }
            if *cols.last().unwrap() as usize != i {
                return Err(Error::Invalid(format!(
                    "row {i}: diagonal missing or above-diagonal entry present"
                )));
            }
            let d = self.diag(i);
            if d == 0.0 || !d.is_finite() {
                return Err(Error::Invalid(format!("row {i}: bad diagonal {d}")));
            }
        }
        Ok(())
    }

    /// y = L * x (for residual checks).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for i in 0..self.nrows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.data[k] * x[self.indices[k] as usize];
            }
            y[i] = acc;
        }
        y
    }

    /// ||Lx - b||_inf.
    pub fn residual_inf(&self, x: &[f64], b: &[f64]) -> f64 {
        self.matvec(x)
            .iter()
            .zip(b)
            .map(|(yi, bi)| (yi - bi).abs())
            .fold(0.0, f64::max)
    }

    /// Extract the lower-triangular part (incl. diagonal) of a general
    /// square CSR; rows missing a diagonal get a unit diagonal (the usual
    /// convention when treating an L factor stored without it).
    pub fn lower_triangular_part(&self) -> Result<Csr, Error> {
        if self.nrows != self.ncols {
            return Err(Error::Invalid("lower part of a non-square matrix".into()));
        }
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for i in 0..self.nrows {
            let mut entries: Vec<(u32, f64)> = self
                .row_cols(i)
                .iter()
                .zip(self.row_vals(i))
                .filter(|(&c, _)| (c as usize) < i)
                .map(|(&c, &v)| (c, v))
                .collect();
            entries.sort_unstable_by_key(|&(c, _)| c);
            let diag = self
                .row_cols(i)
                .iter()
                .zip(self.row_vals(i))
                .find(|(&c, _)| c as usize == i)
                .map(|(_, &v)| v)
                .unwrap_or(1.0);
            for (c, v) in entries {
                indices.push(c);
                data.push(v);
            }
            indices.push(i as u32);
            data.push(diag);
            indptr.push(indices.len());
        }
        Csr::new(self.nrows, self.ncols, indptr, indices, data)
    }
}

/// Convenience builder used by generators and tests: rows given as
/// `(deps, dep_vals, diag)` with deps strictly ascending.
pub struct LowerBuilder {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    data: Vec<f64>,
}

impl LowerBuilder {
    pub fn new() -> Self {
        LowerBuilder {
            indptr: vec![0],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    pub fn with_capacity(nrows: usize, nnz: usize) -> Self {
        let mut b = LowerBuilder::new();
        b.indptr.reserve(nrows);
        b.indices.reserve(nnz);
        b.data.reserve(nnz);
        b
    }

    /// Append the next row. `deps` must be strictly ascending and < row id.
    pub fn row(&mut self, deps: &[(u32, f64)], diag: f64) -> &mut Self {
        let i = self.indptr.len() - 1;
        debug_assert!(deps.windows(2).all(|w| w[0].0 < w[1].0));
        debug_assert!(deps.iter().all(|&(c, _)| (c as usize) < i));
        for &(c, v) in deps {
            self.indices.push(c);
            self.data.push(v);
        }
        self.indices.push(i as u32);
        self.data.push(diag);
        self.indptr.push(self.indices.len());
        self
    }

    pub fn finish(self) -> Csr {
        let n = self.indptr.len() - 1;
        let m = Csr {
            nrows: n,
            ncols: n,
            indptr: self.indptr,
            indices: self.indices,
            data: self.data,
        };
        debug_assert!(m.validate_lower_triangular().is_ok());
        m
    }
}

impl Default for LowerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // L = [[2,0,0],[1,3,0],[0,4,5]]
        let mut b = LowerBuilder::new();
        b.row(&[], 2.0);
        b.row(&[(0, 1.0)], 3.0);
        b.row(&[(1, 4.0)], 5.0);
        b.finish()
    }

    #[test]
    fn builder_and_accessors() {
        let m = small();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.diag(0), 2.0);
        assert_eq!(m.diag(2), 5.0);
        assert_eq!(m.row_deps(2), &[1]);
        assert_eq!(m.row_dep_vals(1), &[1.0]);
        assert_eq!(m.indegree(0), 0);
        assert_eq!(m.indegree(2), 1);
    }

    #[test]
    fn row_cost_matches_paper_model() {
        let m = small();
        assert_eq!(m.row_cost(0), 1); // 2*1-1
        assert_eq!(m.row_cost(1), 3); // 2*2-1
    }

    #[test]
    fn validate_accepts_good_matrix() {
        small().validate_lower_triangular().unwrap();
    }

    #[test]
    fn validate_rejects_zero_diag() {
        let mut m = small();
        let last = m.indptr[1] - 1;
        m.data[last] = 0.0;
        assert!(m.validate_lower_triangular().is_err());
    }

    #[test]
    fn validate_rejects_upper_entry() {
        let m = Csr::new(2, 2, vec![0, 2, 3], vec![0, 1, 1], vec![1.0, 9.0, 1.0]).unwrap();
        assert!(m.validate_lower_triangular().is_err());
    }

    #[test]
    fn validate_rejects_unsorted() {
        let m = Csr::new(
            3,
            3,
            vec![0, 1, 2, 5],
            vec![0, 1, 1, 0, 2],
            vec![1.0; 5],
        )
        .unwrap();
        assert!(m.validate_lower_triangular().is_err());
    }

    #[test]
    fn new_rejects_inconsistent() {
        assert!(Csr::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // indptr len
        assert!(Csr::new(1, 1, vec![0, 2], vec![0], vec![1.0]).is_err()); // tail
        assert!(Csr::new(1, 1, vec![0, 1], vec![5], vec![1.0]).is_err()); // col range
    }

    #[test]
    fn matvec_and_residual() {
        let m = small();
        let x = vec![1.0, 2.0, 3.0];
        let y = m.matvec(&x);
        assert_eq!(y, vec![2.0, 7.0, 23.0]);
        assert_eq!(m.residual_inf(&x, &y), 0.0);
        assert!(m.residual_inf(&x, &[0.0, 0.0, 0.0]) == 23.0);
    }

    #[test]
    fn lower_part_extraction() {
        // General matrix with an upper entry and a missing diagonal on row 0.
        let g = Csr::new(
            2,
            2,
            vec![0, 1, 3],
            vec![1, 0, 1],
            vec![7.0, 4.0, 3.0],
        )
        .unwrap();
        let l = g.lower_triangular_part().unwrap();
        l.validate_lower_triangular().unwrap();
        assert_eq!(l.diag(0), 1.0); // filled-in unit diagonal
        assert_eq!(l.diag(1), 3.0);
        assert_eq!(l.row_deps(1), &[0]);
    }
}
