//! Synthetic workload generators.
//!
//! SuiteSparse is not reachable from this environment, so the two matrices
//! of the paper's evaluation (lung2, torso2) are replaced by structural
//! analogs (see DESIGN.md §3). The rewriting strategies operate purely on
//! the dependency/level structure and the nnz counts, so generators that
//! reproduce the published level profiles exercise identical code paths:
//!
//! * **lung2-like** — n=109,460; 479 levels, 453 of which ("94%") hold
//!   exactly 2 rows (the near-serial thin chain); 26 fat levels in three
//!   bump clusters; indegree ≤ 2 on chain rows; total level cost ≈ 437,834.
//! * **torso2-like** — n=115,967; 513 levels with a triangular (linearly
//!   decreasing) width profile; indegrees 2–6 (mean ≈ 4); total level cost
//!   ≈ 1,035,484.
//!
//! All generators are deterministic in the seed and emit matrices ordered
//! level-by-level (rows of level l precede rows of level l+1), which keeps
//! them lower-triangular by construction.

use crate::sparse::csr::{Csr, LowerBuilder};
use crate::util::rng::Rng;

/// Generator options shared by the structured generators.
#[derive(Debug, Clone)]
pub struct GenOptions {
    pub seed: u64,
    /// Scale factor on the matrix size (rows and level widths); 1.0 is the
    /// paper-sized instance, smaller values give fast test instances with
    /// the same shape.
    pub scale: f64,
    /// Well-conditioned values (default) vs. ill-scaled values spanning
    /// ~1e-8..1e2 on the diagonal, mimicking lung2's raw scaling; used by
    /// the numerical-stability experiment (paper §IV, Fig 3 middle).
    pub ill_scaled: bool,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            seed: 0x5EED,
            scale: 1.0,
            ill_scaled: false,
        }
    }
}

impl GenOptions {
    pub fn with_scale(scale: f64) -> Self {
        GenOptions {
            scale,
            ..Default::default()
        }
    }
}

/// A level plan: the width of each level; the generator materializes rows
/// so that the level-set construction of the result reproduces the plan
/// exactly (each row in level l > 0 has at least one dependency in level
/// l-1; level-0 rows have none).
#[derive(Debug, Clone)]
pub struct LevelPlan {
    pub widths: Vec<usize>,
}

impl LevelPlan {
    pub fn total_rows(&self) -> usize {
        self.widths.iter().sum()
    }
}

fn gen_values(rng: &mut Rng, ndeps: usize, ill_scaled: bool) -> (Vec<f64>, f64) {
    let dep_vals: Vec<f64> = (0..ndeps)
        .map(|_| {
            let v = rng.uniform(-1.0, 1.0);
            if ill_scaled {
                v * 10f64.powf(rng.uniform(-4.0, 4.0))
            } else {
                v
            }
        })
        .collect();
    let diag = if ill_scaled {
        let mag = 10f64.powf(rng.uniform(-8.0, 2.0));
        if rng.chance(0.5) {
            mag
        } else {
            -mag
        }
    } else {
        // Diagonally dominant: keeps forward substitution well-conditioned.
        rng.uniform(1.0, 2.0) * (1.0 + ndeps as f64)
    };
    (dep_vals, diag)
}

/// Materialize a level plan into a lower-triangular CSR.
///
/// `deps_for` decides, per row, how many dependencies it gets *in addition
/// to* the mandatory one in the previous level (which pins its level);
/// extra dependencies are drawn from earlier levels with geometric
/// lookback (`lookback_p`), biased toward nearby levels — mimicking the
/// banded locality of discretization matrices.
pub fn from_level_plan(
    plan: &LevelPlan,
    opts: &GenOptions,
    mut extra_deps_for: impl FnMut(&mut Rng, usize, usize) -> usize,
    lookback_p: f64,
) -> Csr {
    let mut rng = Rng::new(opts.seed);
    let nlevels = plan.widths.len();
    // Row-id ranges per level.
    let mut level_start = Vec::with_capacity(nlevels + 1);
    let mut acc = 0usize;
    for &w in &plan.widths {
        level_start.push(acc);
        acc += w;
    }
    level_start.push(acc);
    let n = acc;

    let mut b = LowerBuilder::with_capacity(n, n * 3);
    let mut deps_buf: Vec<u32> = Vec::new();
    // Localized dependency sampling: like the discretization matrices the
    // paper evaluates, a row's dependencies cluster around its own
    // relative position in earlier levels. This spatial locality is what
    // keeps dependency unions overlapping under rewriting (torso2's total
    // cost grows 40%, not unboundedly, under the blind manual strategy).
    let local_pick = |rng: &mut Rng, lvl: usize, rel: f64, lo: usize, hi: usize| {
        let w = hi - lo;
        let _ = lvl;
        let center = lo + ((rel * w as f64) as usize).min(w - 1);
        let window = (w / 64).max(2);
        let a = center.saturating_sub(window).max(lo);
        let z = (center + window + 1).min(hi);
        rng.range(a, z)
    };
    for lvl in 0..nlevels {
        let width = plan.widths[lvl];
        for r in 0..width {
            let row = level_start[lvl] + r;
            let rel = r as f64 / width as f64;
            deps_buf.clear();
            if lvl > 0 {
                // Mandatory dependency in the previous level pins the level.
                let prev_lo = level_start[lvl - 1];
                let prev_hi = level_start[lvl];
                deps_buf.push(local_pick(&mut rng, lvl, rel, prev_lo, prev_hi) as u32);
                // Extra dependencies with geometric level lookback.
                let extras = extra_deps_for(&mut rng, lvl, row);
                for _ in 0..extras {
                    let mut back = 1usize;
                    while back < lvl && rng.chance(lookback_p) {
                        back += 1;
                    }
                    let src = lvl - back;
                    let dep =
                        local_pick(&mut rng, src, rel, level_start[src], level_start[src + 1])
                            as u32;
                    if !deps_buf.contains(&dep) {
                        deps_buf.push(dep);
                    }
                }
                deps_buf.sort_unstable();
            }
            let (vals, diag) = gen_values(&mut rng, deps_buf.len(), opts.ill_scaled);
            let entries: Vec<(u32, f64)> = deps_buf
                .iter()
                .copied()
                .zip(vals.iter().copied())
                .collect();
            b.row(&entries, diag);
        }
    }
    let m = b.finish();
    debug_assert_eq!(m.nrows, n);
    m
}

/// lung2 structural analog. `scale=1.0` reproduces the published profile:
/// 479 levels, 453 thin levels of 2 rows, 26 fat levels (~4175 rows each)
/// in three bump clusters, chain indegree <= 2.
pub fn lung2_like(opts: &GenOptions) -> Csr {
    let plan = lung2_plan(opts.scale);
    // Thin-chain rows: exactly 1 extra dep (both rows of the previous thin
    // level when possible) => indegree 2, and crucially the union of the
    // previous level's dependencies stays of size <= 2, so rewriting does
    // not grow indegrees — the paper's key observation for lung2.
    let widths = plan.widths.clone();
    from_level_plan(
        &plan,
        opts,
        move |rng, lvl, _| {
            if widths[lvl] <= 2 {
                1 // thin chain: mandatory + 1 = 2 deps
            } else if rng.chance(0.5) {
                1 // fat rows: 1-2 deps, averaging 1.5
            } else {
                0
            }
        },
        0.0, // no lookback: deps live in the previous level only
    )
}

/// The lung2 level-width plan (three fat bumps inside a long thin chain).
pub fn lung2_plan(scale: f64) -> LevelPlan {
    let nlevels = ((479.0 * scale.max(0.02)).round() as usize).max(12);
    let nthin = (nlevels as f64 * 453.0 / 479.0).round() as usize;
    let nfat = nlevels - nthin;
    let fat_rows_total = (108_554.0 * scale).round() as usize;
    let fat_w = (fat_rows_total / nfat.max(1)).max(3);
    // Bump positions: ~24%, ~52%, ~84% through the level sequence.
    let bump_starts = [
        nlevels * 24 / 100,
        nlevels * 52 / 100,
        nlevels * 84 / 100,
    ];
    let per_bump = [nfat / 3, nfat / 3, nfat - 2 * (nfat / 3)];
    let mut widths = vec![2usize; nlevels];
    for (b, &start) in bump_starts.iter().enumerate() {
        for i in 0..per_bump[b] {
            let idx = (start + i).min(nlevels - 1);
            widths[idx] = fat_w;
        }
    }
    LevelPlan { widths }
}

/// torso2 structural analog: triangular level-width profile (wide head,
/// thin tail), indegree mean ~4 overall but declining toward the thin
/// tail — the FD-discretization locality that keeps the paper's manual
/// rewriting at +40% total cost rather than exploding.
pub fn torso2_like(opts: &GenOptions) -> Csr {
    let plan = torso2_plan(opts.scale);
    let widths = plan.widths.clone();
    let avg_w = plan.total_rows() / plan.widths.len().max(1);
    from_level_plan(
        &plan,
        opts,
        move |rng, lvl, _| {
            if widths[lvl] < avg_w {
                rng.range(0, 3) // thin tail: 1..=3 deps total
            } else {
                rng.range(2, 6) // wide head: 3..=7 deps total
            }
        },
        0.2,
    )
}

/// The torso2 level-width plan: width decreases linearly from ~450 to 2
/// over ~513 levels (sums to ~115,967 rows at scale 1).
pub fn torso2_plan(scale: f64) -> LevelPlan {
    let nlevels = ((513.0 * scale.max(0.02)).round() as usize).max(10);
    let n_target = (115_967.0 * scale).round() as usize;
    // width(l) = w0 * (1 - l/nlevels) + 2, with w0 solving the sum.
    let w0 = (2.0 * (n_target as f64 - 2.0 * nlevels as f64) / nlevels as f64).max(2.0);
    let mut widths = Vec::with_capacity(nlevels);
    for l in 0..nlevels {
        let frac = 1.0 - l as f64 / nlevels as f64;
        widths.push(((w0 * frac).round() as usize + 2).max(2));
    }
    LevelPlan { widths }
}

/// Tridiagonal lower factor: the fully serial worst case — every level has
/// exactly one row, n levels in total.
pub fn tridiagonal(n: usize, opts: &GenOptions) -> Csr {
    let mut rng = Rng::new(opts.seed);
    let mut b = LowerBuilder::with_capacity(n, 2 * n);
    for i in 0..n {
        let (vals, diag) = gen_values(&mut rng, usize::from(i > 0), opts.ill_scaled);
        if i == 0 {
            b.row(&[], diag);
        } else {
            b.row(&[((i - 1) as u32, vals[0])], diag);
        }
    }
    b.finish()
}

/// Banded lower factor: each row depends on up to `bandwidth` previous rows
/// with fill probability `fill`.
pub fn banded(n: usize, bandwidth: usize, fill: f64, opts: &GenOptions) -> Csr {
    let mut rng = Rng::new(opts.seed);
    let mut b = LowerBuilder::with_capacity(n, n * (1 + (bandwidth as f64 * fill) as usize));
    let mut deps: Vec<(u32, f64)> = Vec::new();
    for i in 0..n {
        deps.clear();
        let lo = i.saturating_sub(bandwidth);
        for j in lo..i {
            if rng.chance(fill) {
                deps.push((j as u32, 0.0));
            }
        }
        let (vals, diag) = gen_values(&mut rng, deps.len(), opts.ill_scaled);
        for (d, v) in deps.iter_mut().zip(vals) {
            d.1 = v;
        }
        b.row(&deps, diag);
    }
    b.finish()
}

/// Uniformly random lower factor: each row has 0..=max_deps dependencies
/// drawn anywhere below it. Used heavily by the property tests.
pub fn random_lower(n: usize, max_deps: usize, density: f64, opts: &GenOptions) -> Csr {
    let mut rng = Rng::new(opts.seed);
    let mut b = LowerBuilder::with_capacity(n, n * (1 + max_deps));
    for i in 0..n {
        let ndeps = if i == 0 || !rng.chance(density) {
            0
        } else {
            rng.range(1, max_deps.min(i) + 1)
        };
        let cols = rng.sample_distinct(i, ndeps);
        let (vals, diag) = gen_values(&mut rng, ndeps, opts.ill_scaled);
        let entries: Vec<(u32, f64)> = cols
            .into_iter()
            .map(|c| c as u32)
            .zip(vals)
            .collect();
        b.row(&entries, diag);
    }
    b.finish()
}

/// Lower triangular factor of an ILU(0)-style factorization of the
/// 5-point Poisson stencil on an nx x ny grid: cell (i,j) depends on
/// (i-1,j) and (i,j-1). The level sets are the grid anti-diagonals —
/// a real discretization workload with a triangular-then-shrinking level
/// profile (the classic SpTRSV benchmark structure, cf. paper refs
/// [14-18]).
pub fn poisson2d_ilu(nx: usize, ny: usize, opts: &GenOptions) -> Csr {
    let mut rng = Rng::new(opts.seed);
    let idx = |i: usize, j: usize| (i * ny + j) as u32;
    let mut b = LowerBuilder::with_capacity(nx * ny, 3 * nx * ny);
    let mut deps: Vec<(u32, f64)> = Vec::new();
    for i in 0..nx {
        for j in 0..ny {
            deps.clear();
            if i > 0 {
                deps.push((idx(i - 1, j), 0.0));
            }
            if j > 0 {
                deps.push((idx(i, j - 1), 0.0));
            }
            deps.sort_unstable_by_key(|&(c, _)| c);
            let (vals, diag) = gen_values(&mut rng, deps.len(), opts.ill_scaled);
            for (d, v) in deps.iter_mut().zip(vals) {
                d.1 = v;
            }
            b.row(&deps, diag);
        }
    }
    b.finish()
}

/// The 8-row example matrix of the paper's Fig. 1 (dependency pattern
/// only; values are synthesized well-conditioned). Used in unit tests to
/// pin level-set behaviour to the paper's worked example.
pub fn fig1_example() -> Csr {
    let mut b = LowerBuilder::new();
    // Levels from Fig 1: L0 = {0,1,2}, L1 = {3,4}, L2 = {5,6}, L3 = {7}.
    b.row(&[], 2.0); // 0
    b.row(&[], 3.0); // 1
    b.row(&[], 4.0); // 2
    b.row(&[(0, 1.0)], 2.5); // 3 <- 0
    b.row(&[(1, 1.0), (2, -1.0)], 3.5); // 4 <- 1,2
    b.row(&[(3, 0.5)], 2.0); // 5 <- 3
    b.row(&[(4, 1.5)], 4.0); // 6 <- 4
    b.row(&[(0, 1.0), (3, -0.5), (6, 2.0)], 5.0); // 7 <- 0,3,6
    b.finish()
}

/// The 4-row chain of the paper's Fig. 2 (x3 -> x1 -> x0 rewriting example).
pub fn fig2_example() -> Csr {
    let mut b = LowerBuilder::new();
    b.row(&[], 2.0); // 0            level 0
    b.row(&[(0, 1.0)], 3.0); // 1 <- 0      level 1
    b.row(&[(0, -1.0)], 2.0); // 2 <- 0     level 1
    b.row(&[(1, 2.0)], 4.0); // 3 <- 1      level 2
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tridiagonal_structure() {
        let m = tridiagonal(10, &GenOptions::default());
        m.validate_lower_triangular().unwrap();
        assert_eq!(m.indegree(0), 0);
        for i in 1..10 {
            assert_eq!(m.row_deps(i), &[(i - 1) as u32]);
        }
    }

    #[test]
    fn random_lower_valid_and_deterministic() {
        let o = GenOptions::default();
        let a = random_lower(200, 4, 0.8, &o);
        let b = random_lower(200, 4, 0.8, &o);
        a.validate_lower_triangular().unwrap();
        assert_eq!(a, b);
        let c = random_lower(200, 4, 0.8, &GenOptions { seed: 1, ..o });
        assert_ne!(a, c);
    }

    #[test]
    fn banded_respects_bandwidth() {
        let m = banded(100, 5, 0.6, &GenOptions::default());
        m.validate_lower_triangular().unwrap();
        for i in 0..100 {
            for &d in m.row_deps(i) {
                assert!(i - (d as usize) <= 5);
            }
        }
    }

    #[test]
    fn lung2_like_small_profile() {
        let o = GenOptions::with_scale(0.05);
        let m = lung2_like(&o);
        m.validate_lower_triangular().unwrap();
        let plan = lung2_plan(0.05);
        assert_eq!(m.nrows, plan.total_rows());
        // Chain rows have indegree <= 2.
        for i in 0..m.nrows {
            assert!(m.indegree(i) <= 2);
        }
    }

    #[test]
    fn lung2_full_scale_counts() {
        let plan = lung2_plan(1.0);
        assert_eq!(plan.widths.len(), 479);
        let thin = plan.widths.iter().filter(|&&w| w == 2).count();
        assert_eq!(thin, 453);
        // Published n = 109,460; we match within ~1%.
        let n = plan.total_rows();
        assert!(
            (n as f64 - 109_460.0).abs() / 109_460.0 < 0.01,
            "n = {n}"
        );
    }

    #[test]
    fn torso2_full_scale_counts() {
        let plan = torso2_plan(1.0);
        assert_eq!(plan.widths.len(), 513);
        let n = plan.total_rows();
        assert!(
            (n as f64 - 115_967.0).abs() / 115_967.0 < 0.02,
            "n = {n}"
        );
        // Triangular: first width much larger than last.
        assert!(plan.widths[0] > 100 * plan.widths[plan.widths.len() - 1] / 2);
    }

    #[test]
    fn torso2_like_small_valid() {
        let m = torso2_like(&GenOptions::with_scale(0.03));
        m.validate_lower_triangular().unwrap();
        // Mean indegree should be near 4 (2..6 uniform-ish).
        let total_deps: usize = (0..m.nrows).map(|i| m.indegree(i)).sum();
        let mean = total_deps as f64 / m.nrows as f64;
        assert!(mean > 1.5 && mean < 5.0, "mean indegree {mean}");
    }

    #[test]
    fn ill_scaled_values_span_magnitudes() {
        let m = tridiagonal(
            500,
            &GenOptions {
                ill_scaled: true,
                ..Default::default()
            },
        );
        let mags: Vec<f64> = (0..500).map(|i| m.diag(i).abs().log10()).collect();
        let min = mags.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = mags.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(max - min > 5.0, "magnitude span {min}..{max}");
    }

    #[test]
    fn fig_examples_valid() {
        fig1_example().validate_lower_triangular().unwrap();
        fig2_example().validate_lower_triangular().unwrap();
    }

    #[test]
    fn poisson2d_levels_are_antidiagonals() {
        let m = poisson2d_ilu(7, 5, &GenOptions::default());
        m.validate_lower_triangular().unwrap();
        assert_eq!(m.nrows, 35);
        let lv = crate::graph::Levels::build(&m);
        // Level of cell (i, j) is i + j; count of levels = nx + ny - 1.
        assert_eq!(lv.num_levels(), 7 + 5 - 1);
        for i in 0..7 {
            for j in 0..5 {
                assert_eq!(lv.level_of[i * 5 + j] as usize, i + j);
            }
        }
        // Widths rise to min(nx, ny) then fall — the diamond profile.
        assert_eq!(lv.max_width(), 5);
        assert_eq!(lv.width(0), 1);
        assert_eq!(lv.width(10), 1);
    }
}
