//! Sparse-matrix substrate: storage formats, I/O, and synthetic workload
//! generators.
//!
//! Everything downstream (level sets, the rewriting engine, the solvers)
//! operates on [`csr::Csr`] lower-triangular matrices with a full diagonal
//! stored as the last entry of each row — the same convention as the
//! paper's Algorithm 1.

pub mod coo;
pub mod csr;
pub mod generate;
pub mod matrix_market;
pub mod reorder;

pub use coo::Coo;
pub use csr::Csr;
