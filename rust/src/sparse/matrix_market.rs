//! Matrix Market (.mtx) reader/writer — the SuiteSparse interchange format.
//!
//! Supports `matrix coordinate real|integer|pattern general|symmetric`,
//! which covers the collection's triangular-solve matrices (lung2, torso2
//! are `coordinate real general`/`symmetric`). Pattern matrices get value
//! 1.0. Symmetric files are expanded to both triangles.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::Error;
use crate::sparse::{Coo, Csr};

#[derive(Debug, Clone, Copy, PartialEq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

pub fn read_path(path: &Path) -> Result<Csr, Error> {
    let f = std::fs::File::open(path)
        .map_err(|e| Error::Io(format!("open {}: {e}", path.display())))?;
    read(std::io::BufReader::new(f))
}

/// Parse error carrying the 1-based line number of the offending content.
fn perr(line: usize, msg: String) -> Error {
    Error::MatrixMarket { line, msg }
}

pub fn read<R: BufRead>(mut r: R) -> Result<Csr, Error> {
    let mut line = String::new();
    let mut lineno = 0usize;
    // Reads one line; returns false at EOF.
    let mut next_line = |line: &mut String, lineno: &mut usize| -> Result<bool, Error> {
        line.clear();
        let n = r.read_line(line).map_err(|e| Error::Io(e.to_string()))?;
        if n == 0 {
            return Ok(false);
        }
        *lineno += 1;
        Ok(true)
    };

    if !next_line(&mut line, &mut lineno)? {
        return Err(perr(1, "empty file (missing %%MatrixMarket header)".into()));
    }
    let header: Vec<String> = line
        .trim()
        .to_ascii_lowercase()
        .split_whitespace()
        .map(str::to_string)
        .collect();
    if header.len() < 5 || header[0] != "%%matrixmarket" || header[1] != "matrix" {
        return Err(perr(
            lineno,
            "not a MatrixMarket matrix file (expected \
             '%%MatrixMarket matrix coordinate <field> <symmetry>')"
                .into(),
        ));
    }
    if header[2] != "coordinate" {
        return Err(perr(
            lineno,
            format!("unsupported format '{}' (only coordinate)", header[2]),
        ));
    }
    let field = match header[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        f => return Err(perr(lineno, format!("unsupported field '{f}'"))),
    };
    let symmetry = match header[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        s => return Err(perr(lineno, format!("unsupported symmetry '{s}'"))),
    };

    // Skip comment/blank lines, read the size line.
    let dims = loop {
        if !next_line(&mut line, &mut lineno)? {
            return Err(perr(lineno + 1, "missing size line".into()));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        break t
            .split_whitespace()
            .map(|w| w.parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| perr(lineno, format!("bad size line: {e}")))?;
    };
    if dims.len() != 3 {
        return Err(perr(lineno, "size line needs 'rows cols nnz'".into()));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    let mut coo = Coo::new(nrows, ncols);
    coo.entries.reserve(nnz);

    let mut seen = 0usize;
    while seen < nnz {
        if !next_line(&mut line, &mut lineno)? {
            return Err(perr(
                lineno + 1,
                format!("file ended after {seen}/{nnz} entries"),
            ));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue; // comments/blanks are tolerated between entries
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .ok_or_else(|| perr(lineno, "short entry line".into()))?
            .parse()
            .map_err(|e| perr(lineno, format!("bad row index: {e}")))?;
        let j: usize = it
            .next()
            .ok_or_else(|| perr(lineno, "short entry line (missing column index)".into()))?
            .parse()
            .map_err(|e| perr(lineno, format!("bad col index: {e}")))?;
        let v = match field {
            Field::Pattern => 1.0,
            _ => it
                .next()
                .ok_or_else(|| perr(lineno, "missing value".into()))?
                .parse::<f64>()
                .map_err(|e| perr(lineno, format!("bad value: {e}")))?,
        };
        if it.next().is_some() {
            return Err(perr(
                lineno,
                format!("trailing tokens after entry ({i},{j})"),
            ));
        }
        if i == 0 || j == 0 {
            return Err(perr(
                lineno,
                format!("entry ({i},{j}): Matrix Market indices are 1-based, 0 is invalid"),
            ));
        }
        if i > nrows || j > ncols {
            return Err(perr(
                lineno,
                format!("entry ({i},{j}) out of range for a {nrows}x{ncols} matrix"),
            ));
        }
        let (i, j) = (i - 1, j - 1); // 1-based on disk
        coo.push(i, j, v);
        match symmetry {
            Symmetry::General => {}
            Symmetry::Symmetric if i != j => coo.push(j, i, v),
            Symmetry::SkewSymmetric if i != j => coo.push(j, i, -v),
            _ => {}
        }
        seen += 1;
    }
    coo.to_csr()
}

/// Write a CSR matrix as `coordinate real general`.
pub fn write_path(m: &Csr, path: &Path) -> Result<(), Error> {
    let f = std::fs::File::create(path)
        .map_err(|e| Error::Io(format!("create {}: {e}", path.display())))?;
    let mut w = BufWriter::new(f);
    (|| -> std::io::Result<()> {
        writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
        writeln!(w, "% written by sptrsv-gt")?;
        writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
        for i in 0..m.nrows {
            for (c, v) in m.row_cols(i).iter().zip(m.row_vals(i)) {
                writeln!(w, "{} {} {:.17e}", i + 1, *c as usize + 1, v)?;
            }
        }
        w.flush()
    })()
    .map_err(|e| Error::Io(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::LowerBuilder;
    use std::io::Cursor;

    #[test]
    fn reads_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   3 3 4\n\
                   1 1 2.0\n2 1 1.0\n2 2 3.0\n3 3 5.0\n";
        let m = read(Cursor::new(src)).unwrap();
        assert_eq!(m.nrows, 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.diag(1), 3.0);
    }

    #[test]
    fn reads_symmetric_expands() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   2 2 2\n\
                   1 1 1.0\n2 1 4.0\n";
        let m = read(Cursor::new(src)).unwrap();
        assert_eq!(m.nnz(), 3); // (0,0), (1,0), (0,1)
        assert_eq!(m.row_cols(0), &[0, 1]);
    }

    #[test]
    fn reads_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n2 2\n";
        let m = read(Cursor::new(src)).unwrap();
        assert_eq!(m.data, vec![1.0, 1.0]);
    }

    #[test]
    fn tolerates_blank_and_comment_lines_everywhere() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % a comment before the size line\n\
                   \n\
                   3 3 4\n\
                   \n\
                   1 1 2.0\n\
                   % comment between entries\n\
                   2 1 1.0\n\
                   \n\
                   2 2 3.0\n\
                   3 3 5.0\n\
                   \n\
                   % trailing comment\n";
        let m = read(Cursor::new(src)).unwrap();
        assert_eq!(m.nrows, 3);
        assert_eq!(m.nnz(), 4);
        m.validate_lower_triangular().unwrap();
    }

    #[test]
    fn zero_index_is_a_1_based_violation() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        match read(Cursor::new(src)) {
            Err(crate::error::Error::MatrixMarket { line, msg }) => {
                assert_eq!(line, 3);
                assert!(msg.contains("1-based"), "{msg}");
            }
            other => panic!("expected MatrixMarket error, got {other:?}"),
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        // Out-of-range entry on line 5 (after a comment line).
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % c\n\
                   2 2 2\n\
                   1 1 1.0\n\
                   9 1 1.0\n";
        match read(Cursor::new(src)) {
            Err(crate::error::Error::MatrixMarket { line, msg }) => {
                assert_eq!(line, 5);
                assert!(msg.contains("out of range"), "{msg}");
            }
            other => panic!("expected MatrixMarket error, got {other:?}"),
        }
        // Bad value token.
        let src = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 zebra\n";
        match read(Cursor::new(src)) {
            Err(crate::error::Error::MatrixMarket { line, .. }) => assert_eq!(line, 3),
            other => panic!("expected MatrixMarket error, got {other:?}"),
        }
        // Truncated file: reported just past the last line.
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        match read(Cursor::new(src)) {
            Err(crate::error::Error::MatrixMarket { line, msg }) => {
                assert_eq!(line, 4);
                assert!(msg.contains("1/2"), "{msg}");
            }
            other => panic!("expected MatrixMarket error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_trailing_tokens_and_empty_file() {
        let src = "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 2.0 extra\n";
        assert!(matches!(
            read(Cursor::new(src)),
            Err(crate::error::Error::MatrixMarket { line: 3, .. })
        ));
        assert!(matches!(
            read(Cursor::new("")),
            Err(crate::error::Error::MatrixMarket { line: 1, .. })
        ));
    }

    #[test]
    fn rejects_bad_header_and_ranges() {
        assert!(read(Cursor::new("hello\n")).is_err());
        assert!(read(Cursor::new(
            "%%MatrixMarket matrix array real general\n2 2 1\n"
        ))
        .is_err());
        assert!(read(Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 1 1.0\n"
        ))
        .is_err());
        assert!(read(Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n"
        ))
        .is_err());
    }

    #[test]
    fn roundtrip_through_tempfile() {
        let mut b = LowerBuilder::new();
        b.row(&[], 2.0);
        b.row(&[(0, -1.25)], 3.5);
        b.row(&[(0, 0.5), (1, 4.0)], 5.0);
        let m = b.finish();
        let path = std::env::temp_dir().join(format!("sptrsv_mm_{}.mtx", std::process::id()));
        write_path(&m, &path).unwrap();
        let m2 = read_path(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(m, m2);
        m2.validate_lower_triangular().unwrap();
    }
}
