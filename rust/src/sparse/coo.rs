//! Coordinate (triplet) format — the interchange format of Matrix Market
//! files; converted to CSR at the system boundary.

use crate::error::Error;
use crate::sparse::Csr;

#[derive(Debug, Clone, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub entries: Vec<(u32, u32, f64)>, // (row, col, value)
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo {
            nrows,
            ncols,
            entries: Vec::new(),
        }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.nrows && c < self.ncols);
        self.entries.push((r as u32, c as u32, v));
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR. Duplicate (r, c) entries are summed (Matrix Market
    /// semantics); rows are sorted by column.
    pub fn to_csr(&self) -> Result<Csr, Error> {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(entries.len());
        let mut data: Vec<f64> = Vec::with_capacity(entries.len());
        indptr.push(0);
        let mut row = 0usize;
        for (r, c, v) in entries {
            let r = r as usize;
            if r >= self.nrows {
                return Err(Error::Invalid(format!("row {r} out of range")));
            }
            while row < r {
                indptr.push(indices.len());
                row += 1;
            }
            if let (Some(&lc), Some(lv)) = (indices.last(), data.last_mut()) {
                if *indptr.last().unwrap() < indices.len() && lc == c {
                    *lv += v; // duplicate: accumulate
                    continue;
                }
            }
            indices.push(c);
            data.push(v);
        }
        while row < self.nrows {
            indptr.push(indices.len());
            row += 1;
        }
        Csr::new(self.nrows, self.ncols, indptr, indices, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sorts_and_fills_empty_rows() {
        let mut m = Coo::new(4, 4);
        m.push(2, 1, 4.0);
        m.push(0, 0, 2.0);
        m.push(2, 2, 5.0);
        let c = m.to_csr().unwrap();
        assert_eq!(c.indptr, vec![0, 1, 1, 3, 3]);
        assert_eq!(c.indices, vec![0, 1, 2]);
        assert_eq!(c.data, vec![2.0, 4.0, 5.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut m = Coo::new(1, 1);
        m.push(0, 0, 1.5);
        m.push(0, 0, 2.5);
        let c = m.to_csr().unwrap();
        assert_eq!(c.data, vec![4.0]);
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn unsorted_row_within_row() {
        let mut m = Coo::new(2, 3);
        m.push(1, 2, 3.0);
        m.push(1, 0, 1.0);
        m.push(1, 1, 2.0);
        let c = m.to_csr().unwrap();
        assert_eq!(c.row_cols(1), &[0, 1, 2]);
        assert_eq!(c.row_vals(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = Coo::new(3, 3);
        let c = m.to_csr().unwrap();
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.indptr, vec![0, 0, 0, 0]);
    }
}
