//! Matrix reordering — the cache-locality optimization the paper's
//! related work (§V) pairs with level-set methods: permute rows so the
//! rows of each level are contiguous ("level-sorted order"). Threads then
//! stream consecutive memory within a level, and the rewritten systems'
//! x-vector gathers become near-sequential.
//!
//! A permutation P applied symmetrically keeps the system triangular
//! because level-sorted order is a topological order of DAG_L:
//! `(P L Pᵀ)(P x) = P b`.

use crate::error::Error;
use crate::graph::Levels;
use crate::sparse::csr::{Csr, LowerBuilder};

/// A row permutation: `perm[new] = old` and `inv[old] = new`.
#[derive(Debug, Clone)]
pub struct Permutation {
    pub perm: Vec<u32>,
    pub inv: Vec<u32>,
}

impl Permutation {
    pub fn identity(n: usize) -> Permutation {
        let perm: Vec<u32> = (0..n as u32).collect();
        Permutation {
            inv: perm.clone(),
            perm,
        }
    }

    pub fn from_new_to_old(perm: Vec<u32>) -> Result<Permutation, Error> {
        let n = perm.len();
        let mut inv = vec![u32::MAX; n];
        for (new, &old) in perm.iter().enumerate() {
            let o = old as usize;
            if o >= n || inv[o] != u32::MAX {
                return Err(Error::Invalid(format!(
                    "not a permutation: duplicate/out-of-range {old}"
                )));
            }
            inv[o] = new as u32;
        }
        Ok(Permutation { perm, inv })
    }

    /// Apply to a vector: out[new] = v[perm[new]].
    pub fn apply<T: Copy>(&self, v: &[T]) -> Vec<T> {
        self.perm.iter().map(|&old| v[old as usize]).collect()
    }

    /// Inverse application: out[old] = v[inv⁻¹...] i.e. out[perm[new]] = v[new].
    pub fn apply_inverse<T: Copy>(&self, v: &[T]) -> Vec<T> {
        let mut out: Vec<T> = v.to_vec();
        for (new, &old) in self.perm.iter().enumerate() {
            out[old as usize] = v[new];
        }
        out
    }
}

/// Level-sorted permutation: rows ordered by (level, original id).
pub fn level_sort(levels: &Levels) -> Permutation {
    let mut perm = Vec::with_capacity(levels.level_of.len());
    for lvl in &levels.levels {
        perm.extend_from_slice(lvl);
    }
    Permutation::from_new_to_old(perm).expect("levels form a permutation")
}

/// Symmetric permutation of a lower-triangular matrix: `P L Pᵀ`.
/// The permutation must be a topological order (level-sorted is), so the
/// result is again lower triangular with a full diagonal.
pub fn permute_symmetric(m: &Csr, p: &Permutation) -> Result<Csr, Error> {
    let n = m.nrows;
    if p.perm.len() != n {
        return Err(Error::Invalid("permutation size mismatch".into()));
    }
    let mut b = LowerBuilder::with_capacity(n, m.nnz());
    let mut deps: Vec<(u32, f64)> = Vec::new();
    for new in 0..n {
        let old = p.perm[new] as usize;
        deps.clear();
        for (&c, &v) in m.row_deps(old).iter().zip(m.row_dep_vals(old)) {
            let nc = p.inv[c as usize];
            if nc as usize >= new {
                return Err(Error::Invalid(format!(
                    "permutation is not topological: dep {c} of row {old} maps above"
                )));
            }
            deps.push((nc, v));
        }
        deps.sort_unstable_by_key(|&(c, _)| c);
        b.row(&deps, m.diag(old));
    }
    Ok(b.finish())
}

/// Average gap between consecutive dependency columns across all rows —
/// the spatial-locality proxy the §III.A "distance between indegrees < β"
/// constraint reasons about. Lower is better.
pub fn dependency_span_mean(m: &Csr) -> f64 {
    let mut total = 0u64;
    let mut count = 0u64;
    for i in 0..m.nrows {
        let deps = m.row_deps(i);
        if let (Some(&lo), Some(&hi)) = (deps.first(), deps.last()) {
            total += (hi - lo) as u64;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        total as f64 / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    #[test]
    fn identity_roundtrip() {
        let p = Permutation::identity(5);
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(p.apply(&v), v.to_vec());
        assert_eq!(p.apply_inverse(&v), v.to_vec());
    }

    #[test]
    fn rejects_non_permutation() {
        assert!(Permutation::from_new_to_old(vec![0, 0, 1]).is_err());
        assert!(Permutation::from_new_to_old(vec![0, 5]).is_err());
    }

    #[test]
    fn apply_and_inverse_are_inverse() {
        let mut rng = Rng::new(3);
        let mut perm: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut perm);
        let p = Permutation::from_new_to_old(perm).unwrap();
        let v: Vec<f64> = (0..50).map(|i| i as f64).collect();
        assert_eq!(p.apply_inverse(&p.apply(&v)), v);
        assert_eq!(p.apply(&p.apply_inverse(&v)), v);
    }

    #[test]
    fn level_sorted_solve_equivalence() {
        // Solve the permuted system and map back: must equal the original
        // solution. (P L Pᵀ)(P x) = P b.
        let m = generate::torso2_like(&GenOptions::with_scale(0.02));
        let lv = crate::graph::Levels::build(&m);
        let p = level_sort(&lv);
        let pm = permute_symmetric(&m, &p).unwrap();
        pm.validate_lower_triangular().unwrap();
        let mut rng = Rng::new(9);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let x = crate::solver::serial::solve(&m, &b);
        let pb = p.apply(&b);
        let px = crate::solver::serial::solve(&pm, &pb);
        let x_back = p.apply_inverse(&px);
        assert_allclose(&x_back, &x, 1e-12, 1e-14).unwrap();
    }

    #[test]
    fn level_sort_makes_levels_contiguous() {
        let m = generate::random_lower(300, 4, 0.8, &Default::default());
        let lv = crate::graph::Levels::build(&m);
        let p = level_sort(&lv);
        let pm = permute_symmetric(&m, &p).unwrap();
        let lv2 = crate::graph::Levels::build(&pm);
        assert_eq!(lv.num_levels(), lv2.num_levels());
        // Each level is now a contiguous id range.
        let mut next = 0u32;
        for l in &lv2.levels {
            for &r in l {
                assert_eq!(r, next);
                next += 1;
            }
        }
    }

    #[test]
    fn level_sort_improves_poisson_span() {
        // On the natural (row-major) Poisson ordering, a cell's deps are
        // {id-ny, id-1} (span ny); level-sorting brings anti-diagonal
        // neighbours together.
        let m = generate::poisson2d_ilu(40, 40, &Default::default());
        let lv = crate::graph::Levels::build(&m);
        let p = level_sort(&lv);
        let pm = permute_symmetric(&m, &p).unwrap();
        let before = dependency_span_mean(&m);
        let after = dependency_span_mean(&pm);
        assert!(
            after < before,
            "span {after:.1} not better than {before:.1}"
        );
    }

    #[test]
    fn non_topological_permutation_rejected() {
        let m = generate::tridiagonal(4, &Default::default());
        // Reversal is anti-topological for a chain.
        let p = Permutation::from_new_to_old(vec![3, 2, 1, 0]).unwrap();
        assert!(permute_symmetric(&m, &p).is_err());
    }
}
