//! Service metrics: request counters and per-lane log2-bucketed latency
//! histograms, lock-free on the hot path. Tuner events (registration-time
//! only, never on the solve path) additionally keep per-plan win counts
//! behind a mutex.
//!
//! Latency is tracked per [`Lane`] so interactive tail latency is never
//! masked by batch traffic; [`Snapshot`] carries both lanes plus the
//! combined view (summed histograms), and `Display` renders the combined
//! line as before. [`Snapshot::to_json`] serializes everything for the
//! `--metrics-json` dump and the BENCH emitter.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::batcher::Lane;
use crate::util::json::Json;

const BUCKETS: usize = 40; // 2^0 .. 2^39 microseconds
const LANES: usize = 2;
/// Residual histogram: bucket `k` counts batches whose worst achieved
/// relative residual landed in `[10^-(k+1), 10^-k)`; the last bucket
/// absorbs everything at or below `10^-RES_BUCKETS` (including exact
/// zeros).
const RES_BUCKETS: usize = 20;

fn lane_idx(lane: Lane) -> usize {
    match lane {
        Lane::Interactive => 0,
        Lane::Batch => 1,
    }
}

/// Liveness of one shard worker process, as seen by the supervisor: set
/// from the executor's gauges at snapshot time. `last_frame_age_ms` is
/// the time since the worker last answered a frame; `inflight` counts
/// frames written but not yet answered (a worker wedged mid-solve shows
/// a growing age with `inflight > 0`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardHealth {
    pub up: bool,
    pub last_frame_age_ms: u64,
    pub inflight: u64,
}

pub struct Metrics {
    pub solves: AtomicU64,
    pub batched_solves: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// requests refused by admission control (`max_pending`)
    pub rejections: AtomicU64,
    /// requests dropped before dispatch because their ticket was cancelled
    pub cancellations: AtomicU64,
    /// requests dropped before dispatch because their deadline had expired
    pub deadline_misses: AtomicU64,
    /// service-side wakeups triggered by `SolveTicket::cancel`/drop so
    /// queue capacity is reclaimed immediately instead of at the next
    /// flush
    pub cancel_wakeups: AtomicU64,
    /// `auto` registrations answered from the fingerprint plan cache
    pub tuner_cache_hits: AtomicU64,
    /// `auto` registrations that ran the cost model + race
    pub tuner_cache_misses: AtomicU64,
    /// registrations restored from the persistent analysis cache (zero
    /// rewrite/coarsening/placement passes)
    pub analysis_cache_hits: AtomicU64,
    /// registrations that had an analysis cache configured but built fresh
    pub analysis_cache_misses: AtomicU64,
    /// same-pattern value refreshes applied via `update_values`
    pub value_refreshes: AtomicU64,
    /// gauge: cumulative rewrite-analysis passes paid by the pipeline
    rewrite_passes: AtomicU64,
    /// gauge: cumulative coarsening passes paid by the pipeline
    coarsen_passes: AtomicU64,
    /// gauge: cumulative ETF placement passes paid by the pipeline
    placement_passes: AtomicU64,
    /// gauge: cumulative value-only numeric replays paid by the pipeline
    renumeric_passes: AtomicU64,
    /// summed latency per lane (interactive, batch)
    total_us: [AtomicU64; LANES],
    /// log2 latency histogram per lane (interactive, batch)
    hist: [[AtomicU64; BUCKETS]; LANES],
    /// gauge: queued right-hand sides in the interactive lane
    lane_interactive: AtomicU64,
    /// gauge: queued right-hand sides in the batch lane
    lane_batch: AtomicU64,
    /// gauge: coarsened blocks across all scheduled-backend matrices
    sched_blocks: AtomicU64,
    /// gauge: cross-worker block edges (static point-to-point waits)
    sched_cut_edges: AtomicU64,
    /// counter mirror: blocked ready-scans observed by elastic execution
    elastic_waits: AtomicU64,
    /// counter mirror: blocks executed out of order via the lookahead
    elastic_ooo: AtomicU64,
    /// counter mirror: blocks executed via work stealing
    elastic_steals: AtomicU64,
    /// gauge: shard worker processes respawned after a crash/timeout
    shard_respawns: AtomicU64,
    /// gauge: shard worker deaths/timeouts detected by the supervisor
    shard_crashes: AtomicU64,
    /// gauge: matrices re-registered onto a respawned shard
    shard_reregistered: AtomicU64,
    /// log10 histogram of worst achieved relative residuals, one entry
    /// per certified (toleranced) batch
    residual_hist: [AtomicU64; RES_BUCKETS],
    /// worst (largest) achieved residual so far, stored as f64 bits —
    /// valid because certified residuals are non-negative finite floats,
    /// whose IEEE-754 bit patterns order like the values themselves
    residual_max_bits: AtomicU64,
    /// right-hand sides served by the exact backend because an iterative
    /// plan could not certify the requested tolerance
    fallbacks_to_exact: AtomicU64,
    /// sweep-budget doublings paid by the accuracy ladder
    sweep_escalations: AtomicU64,
    /// per-shard worker health, mirrored from the sharded executor at
    /// snapshot time (empty under the in-process executor)
    shard_health: Mutex<Vec<ShardHealth>>,
    /// plan name -> times the tuner picked it
    plan_wins: Mutex<BTreeMap<String, u64>>,
    /// matrix id -> admission rejections charged to it (global cap and
    /// per-matrix cap alike; registration-time only map growth)
    matrix_rejections: Mutex<BTreeMap<String, u64>>,
    /// tenant -> admission rejections charged to its quota
    tenant_rejections: Mutex<BTreeMap<String, u64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            solves: AtomicU64::new(0),
            batched_solves: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejections: AtomicU64::new(0),
            cancellations: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            cancel_wakeups: AtomicU64::new(0),
            tuner_cache_hits: AtomicU64::new(0),
            tuner_cache_misses: AtomicU64::new(0),
            analysis_cache_hits: AtomicU64::new(0),
            analysis_cache_misses: AtomicU64::new(0),
            value_refreshes: AtomicU64::new(0),
            rewrite_passes: AtomicU64::new(0),
            coarsen_passes: AtomicU64::new(0),
            placement_passes: AtomicU64::new(0),
            renumeric_passes: AtomicU64::new(0),
            total_us: std::array::from_fn(|_| AtomicU64::new(0)),
            hist: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            lane_interactive: AtomicU64::new(0),
            lane_batch: AtomicU64::new(0),
            sched_blocks: AtomicU64::new(0),
            sched_cut_edges: AtomicU64::new(0),
            elastic_waits: AtomicU64::new(0),
            elastic_ooo: AtomicU64::new(0),
            elastic_steals: AtomicU64::new(0),
            shard_respawns: AtomicU64::new(0),
            shard_crashes: AtomicU64::new(0),
            shard_reregistered: AtomicU64::new(0),
            residual_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            residual_max_bits: AtomicU64::new(0),
            fallbacks_to_exact: AtomicU64::new(0),
            sweep_escalations: AtomicU64::new(0),
            shard_health: Mutex::new(Vec::new()),
            plan_wins: Mutex::new(BTreeMap::new()),
            matrix_rejections: Mutex::new(BTreeMap::new()),
            tenant_rejections: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one analysis-cache outcome for a fresh registration (only
    /// meaningful when a cache directory is configured).
    pub fn record_analysis_cache(&self, hit: bool) {
        if hit {
            self.analysis_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.analysis_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A registered matrix had its numeric values refreshed in place.
    pub fn record_value_refresh(&self) {
        self.value_refreshes.fetch_add(1, Ordering::Relaxed);
    }

    /// Gauge update: the pipeline's cumulative structural-pass counters
    /// (rewrite / coarsen / placement / renumeric), mirrored at snapshot
    /// time so "the warm cache really skipped the work" is observable.
    pub fn set_rebuilds(&self, rewrite: u64, coarsen: u64, placement: u64, renumeric: u64) {
        self.rewrite_passes.store(rewrite, Ordering::Relaxed);
        self.coarsen_passes.store(coarsen, Ordering::Relaxed);
        self.placement_passes.store(placement, Ordering::Relaxed);
        self.renumeric_passes.store(renumeric, Ordering::Relaxed);
    }

    /// Gauge update: scheduled-backend totals (blocks + static cut) and
    /// the cumulative elastic execution counters, aggregated over every
    /// prepared matrix served by the scheduled backend.
    pub fn set_sched(&self, blocks: u64, cut_edges: u64, waits: u64, ooo: u64, steals: u64) {
        self.sched_blocks.store(blocks, Ordering::Relaxed);
        self.sched_cut_edges.store(cut_edges, Ordering::Relaxed);
        self.elastic_waits.store(waits, Ordering::Relaxed);
        self.elastic_ooo.store(ooo, Ordering::Relaxed);
        self.elastic_steals.store(steals, Ordering::Relaxed);
    }

    /// Gauge update: shard-tier fault-containment counters (crashes
    /// detected, workers respawned, matrices re-registered warm), mirrored
    /// from the sharded executor at snapshot time. All zero under the
    /// in-process executor.
    pub fn set_shards(&self, respawns: u64, crashes: u64, reregistered: u64) {
        self.shard_respawns.store(respawns, Ordering::Relaxed);
        self.shard_crashes.store(crashes, Ordering::Relaxed);
        self.shard_reregistered.store(reregistered, Ordering::Relaxed);
    }

    /// Gauge update: per-shard worker liveness (indexed by shard),
    /// mirrored from the sharded executor at snapshot time. Cleared to
    /// empty under the in-process executor.
    pub fn set_shard_health(&self, health: Vec<ShardHealth>) {
        *self.shard_health.lock().unwrap() = health;
    }

    /// A request was refused by its tenant's pending quota. The global
    /// rejection counter is charged by the caller via
    /// [`Self::record_rejection`]; this only grows the per-tenant map.
    pub fn record_tenant_rejection(&self, tenant: &str) {
        let mut per = self.tenant_rejections.lock().unwrap();
        *per.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Record one tuner decision: whether the plan cache answered it and
    /// which plan won.
    pub fn record_tuner_choice(&self, plan: &str, cache_hit: bool) {
        if cache_hit {
            self.tuner_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.tuner_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        let mut wins = self.plan_wins.lock().unwrap();
        *wins.entry(plan.to_string()).or_insert(0) += 1;
    }

    /// Record one delivered solve into its lane's histogram.
    pub fn record_solve(&self, latency: Duration, batched: bool, lane: Lane) {
        let us = latency.as_micros() as u64;
        self.solves.fetch_add(1, Ordering::Relaxed);
        if batched {
            self.batched_solves.fetch_add(1, Ordering::Relaxed);
        }
        let li = lane_idx(lane);
        self.total_us[li].fetch_add(us, Ordering::Relaxed);
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.hist[li][bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one certified batch's worst achieved relative residual
    /// (only toleranced batches measure one).
    pub fn record_residual(&self, r: f64) {
        let bucket = if r <= 0.0 || !r.is_finite() {
            // Exactly zero (or degenerate input): better than anything
            // the histogram resolves.
            RES_BUCKETS - 1
        } else {
            (-r.log10()).floor().max(0.0) as usize
        }
        .min(RES_BUCKETS - 1);
        self.residual_hist[bucket].fetch_add(1, Ordering::Relaxed);
        let bits = r.max(0.0).to_bits();
        let _ = self.residual_max_bits.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| (bits > cur).then_some(bits),
        );
    }

    /// Accuracy-ladder outcomes for one dispatched batch: right-hand
    /// sides that fell back to the exact path, and sweep doublings paid.
    pub fn record_accuracy(&self, fallbacks: u64, escalations: u64) {
        if fallbacks > 0 {
            self.fallbacks_to_exact.fetch_add(fallbacks, Ordering::Relaxed);
        }
        if escalations > 0 {
            self.sweep_escalations.fetch_add(escalations, Ordering::Relaxed);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission control turned a request away (`Overloaded`). The
    /// rejection is also charged to the matrix id it targeted, so noisy
    /// tenants are identifiable per handle.
    pub fn record_rejection(&self, matrix_id: &str) {
        self.rejections.fetch_add(1, Ordering::Relaxed);
        let mut per = self.matrix_rejections.lock().unwrap();
        *per.entry(matrix_id.to_string()).or_insert(0) += 1;
    }

    /// A queued request was dropped because its ticket was cancelled.
    pub fn record_cancellation(&self) {
        self.cancellations.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued request was dropped because its deadline had expired.
    pub fn record_deadline_miss(&self) {
        self.deadline_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// A ticket cancellation woke the service for an immediate queue
    /// sweep (capacity reclaimed now, not at the next flush).
    pub fn record_cancel_wakeup(&self) {
        self.cancel_wakeups.fetch_add(1, Ordering::Relaxed);
    }

    /// Gauge update: queued right-hand sides per lane after a flush.
    pub fn set_lane_depths(&self, interactive: u64, batch: u64) {
        self.lane_interactive.store(interactive, Ordering::Relaxed);
        self.lane_batch.store(batch, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let lane_hist: Vec<Vec<u64>> = self
            .hist
            .iter()
            .map(|h| h.iter().map(|b| b.load(Ordering::Relaxed)).collect())
            .collect();
        let lane_total: Vec<u64> = self
            .total_us
            .iter()
            .map(|t| t.load(Ordering::Relaxed))
            .collect();
        let lane = |li: usize| LaneLatency::from_hist(&lane_hist[li], lane_total[li]);
        let combined_hist: Vec<u64> = (0..BUCKETS)
            .map(|b| lane_hist.iter().map(|h| h[b]).sum())
            .collect();
        let combined = LaneLatency::from_hist(&combined_hist, lane_total.iter().sum());
        let residual_hist: Vec<u64> = self
            .residual_hist
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let residual_solves = residual_hist.iter().sum();
        Snapshot {
            solves: self.solves.load(Ordering::Relaxed),
            batched_solves: self.batched_solves.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejections: self.rejections.load(Ordering::Relaxed),
            cancellations: self.cancellations.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            cancel_wakeups: self.cancel_wakeups.load(Ordering::Relaxed),
            lane_interactive_depth: self.lane_interactive.load(Ordering::Relaxed),
            lane_batch_depth: self.lane_batch.load(Ordering::Relaxed),
            sched_blocks: self.sched_blocks.load(Ordering::Relaxed),
            sched_cut_edges: self.sched_cut_edges.load(Ordering::Relaxed),
            elastic_waits: self.elastic_waits.load(Ordering::Relaxed),
            elastic_ooo: self.elastic_ooo.load(Ordering::Relaxed),
            elastic_steals: self.elastic_steals.load(Ordering::Relaxed),
            shard_respawns: self.shard_respawns.load(Ordering::Relaxed),
            shard_crashes: self.shard_crashes.load(Ordering::Relaxed),
            shard_reregistered: self.shard_reregistered.load(Ordering::Relaxed),
            tuner_cache_hits: self.tuner_cache_hits.load(Ordering::Relaxed),
            tuner_cache_misses: self.tuner_cache_misses.load(Ordering::Relaxed),
            analysis_cache_hits: self.analysis_cache_hits.load(Ordering::Relaxed),
            analysis_cache_misses: self.analysis_cache_misses.load(Ordering::Relaxed),
            value_refreshes: self.value_refreshes.load(Ordering::Relaxed),
            rewrite_passes: self.rewrite_passes.load(Ordering::Relaxed),
            coarsen_passes: self.coarsen_passes.load(Ordering::Relaxed),
            placement_passes: self.placement_passes.load(Ordering::Relaxed),
            renumeric_passes: self.renumeric_passes.load(Ordering::Relaxed),
            plan_wins: self
                .plan_wins
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            rejections_by_matrix: self
                .matrix_rejections
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            rejections_by_tenant: self
                .tenant_rejections
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            residual_hist,
            residual_solves,
            residual_max: f64::from_bits(self.residual_max_bits.load(Ordering::Relaxed)),
            fallbacks_to_exact: self.fallbacks_to_exact.load(Ordering::Relaxed),
            sweep_escalations: self.sweep_escalations.load(Ordering::Relaxed),
            shard_health: self.shard_health.lock().unwrap().clone(),
            interactive: lane(lane_idx(Lane::Interactive)),
            batch: lane(lane_idx(Lane::Batch)),
            mean_us: combined.mean_us,
            p50_us: combined.p50_us,
            p95_us: combined.p95_us,
            p99_us: combined.p99_us,
            lane_hist,
        }
    }
}

/// Upper bound of the log2 bucket containing the q-th percentile.
fn percentile(hist: &[u64], count: u64, q: f64) -> u64 {
    if count == 0 {
        return 0;
    }
    let want = (count as f64 * q).ceil() as u64;
    let mut seen = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= want {
            return 1u64 << (i + 1);
        }
    }
    1u64 << hist.len()
}

/// Latency summary for one lane (or the combined view): count, mean and
/// log2-bucket percentile upper bounds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LaneLatency {
    pub solves: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

impl LaneLatency {
    fn from_hist(hist: &[u64], total_us: u64) -> LaneLatency {
        let solves: u64 = hist.iter().sum();
        LaneLatency {
            solves,
            mean_us: if solves == 0 {
                0.0
            } else {
                total_us as f64 / solves as f64
            },
            p50_us: percentile(hist, solves, 0.50),
            p95_us: percentile(hist, solves, 0.95),
            p99_us: percentile(hist, solves, 0.99),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("solves", Json::Num(self.solves as f64)),
            ("mean_us", Json::Num(self.mean_us)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub solves: u64,
    pub batched_solves: u64,
    pub batches: u64,
    pub errors: u64,
    /// requests refused by `max_pending` admission control
    pub rejections: u64,
    /// requests dropped before dispatch via ticket cancellation
    pub cancellations: u64,
    /// requests dropped before dispatch with an expired deadline
    pub deadline_misses: u64,
    /// cancellation-triggered service wakeups (immediate queue sweeps)
    pub cancel_wakeups: u64,
    /// gauge: interactive-lane queue depth at the last flush
    pub lane_interactive_depth: u64,
    /// gauge: batch-lane queue depth at the last flush
    pub lane_batch_depth: u64,
    /// gauge: coarsened blocks across scheduled-backend matrices
    pub sched_blocks: u64,
    /// gauge: cross-worker block edges (static point-to-point waits)
    pub sched_cut_edges: u64,
    /// cumulative blocked ready-scans in elastic execution
    pub elastic_waits: u64,
    /// cumulative out-of-order block executions (lookahead hits)
    pub elastic_ooo: u64,
    /// cumulative blocks executed via work stealing
    pub elastic_steals: u64,
    /// shard worker processes respawned after a crash/timeout
    pub shard_respawns: u64,
    /// shard worker deaths/timeouts detected by the supervisor
    pub shard_crashes: u64,
    /// matrices re-registered warm onto a respawned shard
    pub shard_reregistered: u64,
    pub tuner_cache_hits: u64,
    pub tuner_cache_misses: u64,
    /// registrations restored from the persistent analysis cache
    pub analysis_cache_hits: u64,
    /// fresh builds despite a configured analysis cache
    pub analysis_cache_misses: u64,
    /// same-pattern value refreshes applied via `update_values`
    pub value_refreshes: u64,
    /// gauge: cumulative rewrite-analysis passes paid by the pipeline
    pub rewrite_passes: u64,
    /// gauge: cumulative coarsening passes paid by the pipeline
    pub coarsen_passes: u64,
    /// gauge: cumulative ETF placement passes paid by the pipeline
    pub placement_passes: u64,
    /// gauge: cumulative value-only numeric replays paid by the pipeline
    pub renumeric_passes: u64,
    /// (plan, times chosen) pairs, sorted by plan name
    pub plan_wins: Vec<(String, u64)>,
    /// (matrix id, admission rejections charged to it), sorted by id
    pub rejections_by_matrix: Vec<(String, u64)>,
    /// (tenant, quota rejections charged to it), sorted by tenant
    pub rejections_by_tenant: Vec<(String, u64)>,
    /// log10 histogram of worst achieved relative residuals across
    /// certified batches: entry `k` counts batches landing in
    /// `[10^-(k+1), 10^-k)`, last entry absorbs everything tighter
    pub residual_hist: Vec<u64>,
    /// certified (toleranced) batches measured into `residual_hist`
    pub residual_solves: u64,
    /// worst achieved relative residual across certified batches (0.0
    /// when nothing was measured)
    pub residual_max: f64,
    /// right-hand sides served by the exact fallback because an
    /// iterative plan could not certify the requested tolerance
    pub fallbacks_to_exact: u64,
    /// sweep-budget doublings paid by the accuracy ladder
    pub sweep_escalations: u64,
    /// per-shard worker liveness, indexed by shard (empty in-process)
    pub shard_health: Vec<ShardHealth>,
    /// interactive-lane latency summary
    pub interactive: LaneLatency,
    /// batch-lane latency summary
    pub batch: LaneLatency,
    /// combined mean across both lanes
    pub mean_us: f64,
    /// combined (both-lane) percentile bounds
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// raw log2 latency bucket counts per lane, `[interactive, batch]`,
    /// each `BUCKETS` long — the exact histograms the percentiles above
    /// were computed from, exported so BENCH trajectories can carry the
    /// full distribution instead of three pre-cooked quantiles
    pub lane_hist: Vec<Vec<u64>>,
}

impl Snapshot {
    /// Serialize every field (both lanes, combined view, per-plan wins,
    /// per-matrix rejections) for `--metrics-json` and the BENCH emitter.
    pub fn to_json(&self) -> Json {
        let counts = |pairs: &[(String, u64)]| {
            Json::Obj(
                pairs
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("solves", Json::Num(self.solves as f64)),
            ("batched_solves", Json::Num(self.batched_solves as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("rejections", Json::Num(self.rejections as f64)),
            ("cancellations", Json::Num(self.cancellations as f64)),
            ("deadline_misses", Json::Num(self.deadline_misses as f64)),
            ("cancel_wakeups", Json::Num(self.cancel_wakeups as f64)),
            (
                "lane_interactive_depth",
                Json::Num(self.lane_interactive_depth as f64),
            ),
            ("lane_batch_depth", Json::Num(self.lane_batch_depth as f64)),
            ("sched_blocks", Json::Num(self.sched_blocks as f64)),
            ("sched_cut_edges", Json::Num(self.sched_cut_edges as f64)),
            ("elastic_waits", Json::Num(self.elastic_waits as f64)),
            ("elastic_ooo", Json::Num(self.elastic_ooo as f64)),
            ("elastic_steals", Json::Num(self.elastic_steals as f64)),
            ("shard_respawns", Json::Num(self.shard_respawns as f64)),
            ("shard_crashes", Json::Num(self.shard_crashes as f64)),
            (
                "shard_reregistered",
                Json::Num(self.shard_reregistered as f64),
            ),
            ("tuner_cache_hits", Json::Num(self.tuner_cache_hits as f64)),
            (
                "tuner_cache_misses",
                Json::Num(self.tuner_cache_misses as f64),
            ),
            (
                "analysis_cache_hits",
                Json::Num(self.analysis_cache_hits as f64),
            ),
            (
                "analysis_cache_misses",
                Json::Num(self.analysis_cache_misses as f64),
            ),
            ("value_refreshes", Json::Num(self.value_refreshes as f64)),
            ("rewrite_passes", Json::Num(self.rewrite_passes as f64)),
            ("coarsen_passes", Json::Num(self.coarsen_passes as f64)),
            ("placement_passes", Json::Num(self.placement_passes as f64)),
            ("renumeric_passes", Json::Num(self.renumeric_passes as f64)),
            (
                "residual_hist",
                Json::Arr(
                    self.residual_hist
                        .iter()
                        .map(|&c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
            ("residual_solves", Json::Num(self.residual_solves as f64)),
            ("residual_max", Json::Num(self.residual_max)),
            (
                "fallbacks_to_exact",
                Json::Num(self.fallbacks_to_exact as f64),
            ),
            ("sweep_escalations", Json::Num(self.sweep_escalations as f64)),
            ("plan_wins", counts(&self.plan_wins)),
            ("rejections_by_matrix", counts(&self.rejections_by_matrix)),
            ("rejections_by_tenant", counts(&self.rejections_by_tenant)),
            (
                "shard_health",
                Json::Arr(
                    self.shard_health
                        .iter()
                        .enumerate()
                        .map(|(i, h)| {
                            Json::obj(vec![
                                ("shard", Json::Num(i as f64)),
                                ("up", Json::Bool(h.up)),
                                (
                                    "last_frame_age_ms",
                                    Json::Num(h.last_frame_age_ms as f64),
                                ),
                                ("inflight", Json::Num(h.inflight as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "lane_hist",
                Json::obj(
                    ["interactive", "batch"]
                        .iter()
                        .zip(self.lane_hist.iter())
                        .map(|(name, hist)| {
                            (
                                *name,
                                Json::Arr(
                                    hist.iter().map(|&c| Json::Num(c as f64)).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "latency_us",
                Json::obj(vec![
                    ("interactive", self.interactive.to_json()),
                    ("batch", self.batch.to_json()),
                    (
                        "combined",
                        Json::obj(vec![
                            ("solves", Json::Num(self.solves as f64)),
                            ("mean_us", Json::Num(self.mean_us)),
                            ("p50_us", Json::Num(self.p50_us as f64)),
                            ("p95_us", Json::Num(self.p95_us as f64)),
                            ("p99_us", Json::Num(self.p99_us as f64)),
                        ]),
                    ),
                ]),
            ),
        ])
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "solves={} (batched {}), batches={}, errors={}, rejected={}, \
             cancelled={}, deadline_missed={}, depth i/b={}/{}, \
             latency mean={:.0}us p50<{}us p95<{}us p99<{}us",
            self.solves, self.batched_solves, self.batches, self.errors,
            self.rejections, self.cancellations, self.deadline_misses,
            self.lane_interactive_depth, self.lane_batch_depth,
            self.mean_us, self.p50_us, self.p95_us, self.p99_us
        )?;
        // Surface the interactive tail whenever both lanes carried
        // traffic — the combined line alone would mask it.
        if self.interactive.solves > 0 && self.batch.solves > 0 {
            write!(
                f,
                ", interactive p50<{}us p99<{}us",
                self.interactive.p50_us, self.interactive.p99_us
            )?;
        }
        if self.cancel_wakeups > 0 {
            write!(f, ", cancel_wakeups={}", self.cancel_wakeups)?;
        }
        if self.value_refreshes > 0 {
            write!(f, ", value_refreshes={}", self.value_refreshes)?;
        }
        if self.analysis_cache_hits + self.analysis_cache_misses > 0 {
            write!(
                f,
                ", analysis cache hit/miss={}/{}",
                self.analysis_cache_hits, self.analysis_cache_misses
            )?;
        }
        if self.rewrite_passes + self.coarsen_passes + self.placement_passes + self.renumeric_passes
            > 0
        {
            write!(
                f,
                ", passes rewrite={} coarsen={} place={} renumeric={}",
                self.rewrite_passes,
                self.coarsen_passes,
                self.placement_passes,
                self.renumeric_passes
            )?;
        }
        if !self.rejections_by_matrix.is_empty() {
            write!(f, ", rejected[")?;
            for (i, (id, n)) in self.rejections_by_matrix.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                write!(f, "{id}={n}")?;
            }
            write!(f, "]")?;
        }
        if !self.rejections_by_tenant.is_empty() {
            write!(f, ", tenant_rejected[")?;
            for (i, (id, n)) in self.rejections_by_tenant.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                write!(f, "{id}={n}")?;
            }
            write!(f, "]")?;
        }
        if self.residual_solves + self.fallbacks_to_exact + self.sweep_escalations > 0 {
            write!(
                f,
                ", accuracy certified={} worst_residual={:.1e} \
                 fallbacks={} escalations={}",
                self.residual_solves,
                self.residual_max,
                self.fallbacks_to_exact,
                self.sweep_escalations
            )?;
        }
        if self.sched_blocks > 0 {
            write!(
                f,
                ", sched blocks={} cut={} waits={} ooo={} steals={}",
                self.sched_blocks,
                self.sched_cut_edges,
                self.elastic_waits,
                self.elastic_ooo,
                self.elastic_steals
            )?;
        }
        if self.shard_crashes + self.shard_respawns + self.shard_reregistered > 0 {
            write!(
                f,
                ", shards crashes={} respawns={} reregistered={}",
                self.shard_crashes, self.shard_respawns, self.shard_reregistered
            )?;
        }
        if !self.shard_health.is_empty() {
            write!(f, ", shard_health[")?;
            for (i, h) in self.shard_health.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                if h.up {
                    write!(f, "{i}:age={}ms inflight={}", h.last_frame_age_ms, h.inflight)?;
                } else {
                    write!(f, "{i}:down")?;
                }
            }
            write!(f, "]")?;
        }
        if self.tuner_cache_hits + self.tuner_cache_misses > 0 {
            write!(
                f,
                ", tuner cache hit/miss={}/{}",
                self.tuner_cache_hits, self.tuner_cache_misses
            )?;
            if !self.plan_wins.is_empty() {
                write!(f, " wins[")?;
                for (i, (s, n)) in self.plan_wins.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{s}={n}")?;
                }
                write!(f, "]")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_solve(Duration::from_micros(i * 10), i % 2 == 0, Lane::Batch);
        }
        m.record_batch();
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.solves, 100);
        assert_eq!(s.batched_solves, 50);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 1);
        assert!((s.mean_us - 505.0).abs() < 1.0);
        // p50 of 10..1000us is ~500us -> bucket upper bound 512us.
        assert!(s.p50_us >= 256 && s.p50_us <= 1024, "{}", s.p50_us);
        assert!(s.p95_us >= s.p50_us);
        assert!(s.p99_us >= s.p95_us);
        // All traffic rode the batch lane; the combined view equals it.
        assert_eq!(s.interactive.solves, 0);
        assert_eq!(s.batch.solves, 100);
        assert_eq!(s.batch.p99_us, s.p99_us);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.solves, 0);
        assert_eq!(s.mean_us, 0.0);
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.interactive, LaneLatency::default());
        assert_eq!(s.batch, LaneLatency::default());
        assert_eq!(s.tuner_cache_hits, 0);
        assert!(s.plan_wins.is_empty());
        // Without tuner activity the rendering is unchanged.
        assert!(!s.to_string().contains("tuner"));
    }

    #[test]
    fn lanes_keep_separate_histograms() {
        let m = Metrics::new();
        // Fast interactive traffic under a pile of slow batch solves: the
        // per-lane split must keep the interactive tail visible.
        for _ in 0..90 {
            m.record_solve(Duration::from_micros(60_000), true, Lane::Batch);
        }
        for _ in 0..10 {
            m.record_solve(Duration::from_micros(100), false, Lane::Interactive);
        }
        let s = m.snapshot();
        assert_eq!(s.interactive.solves, 10);
        assert_eq!(s.batch.solves, 90);
        assert_eq!(s.interactive.p99_us, 128);
        assert!(s.batch.p50_us >= 65_536);
        // The combined view is dominated by the batch lane (the masking
        // the split exists to undo)...
        assert!(s.p99_us >= 65_536);
        // ...and the mean splits correctly per lane.
        assert!((s.interactive.mean_us - 100.0).abs() < 1e-9);
        assert!((s.batch.mean_us - 60_000.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("interactive p50<128us p99<128us"), "{text}");
    }

    #[test]
    fn tuner_choice_accounting() {
        let m = Metrics::new();
        m.record_tuner_choice("avgcost+scheduled", false);
        m.record_tuner_choice("avgcost+scheduled", true);
        m.record_tuner_choice("manual:10+levelset", false);
        let s = m.snapshot();
        assert_eq!(s.tuner_cache_hits, 1);
        assert_eq!(s.tuner_cache_misses, 2);
        assert_eq!(
            s.plan_wins,
            vec![
                ("avgcost+scheduled".to_string(), 2),
                ("manual:10+levelset".to_string(), 1)
            ]
        );
        let text = s.to_string();
        assert!(text.contains("tuner cache hit/miss=1/2"), "{text}");
        assert!(text.contains("avgcost+scheduled=2"), "{text}");
    }

    #[test]
    fn admission_and_lane_accounting() {
        let m = Metrics::new();
        m.record_rejection("noisy");
        m.record_cancellation();
        m.record_cancellation();
        m.record_deadline_miss();
        m.record_cancel_wakeup();
        m.set_lane_depths(3, 7);
        let s = m.snapshot();
        assert_eq!(s.rejections, 1);
        assert_eq!(s.cancellations, 2);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.cancel_wakeups, 1);
        assert_eq!(s.lane_interactive_depth, 3);
        assert_eq!(s.lane_batch_depth, 7);
        assert_eq!(s.rejections_by_matrix, vec![("noisy".to_string(), 1)]);
        let text = s.to_string();
        assert!(text.contains("rejected=1"), "{text}");
        assert!(text.contains("rejected[noisy=1]"), "{text}");
        assert!(text.contains("cancelled=2"), "{text}");
        assert!(text.contains("deadline_missed=1"), "{text}");
        assert!(text.contains("cancel_wakeups=1"), "{text}");
        assert!(text.contains("depth i/b=3/7"), "{text}");
        // Gauges overwrite rather than accumulate.
        m.set_lane_depths(0, 0);
        assert_eq!(m.snapshot().lane_interactive_depth, 0);
    }

    #[test]
    fn sched_gauges_render_only_when_present() {
        let m = Metrics::new();
        assert!(!m.snapshot().to_string().contains("sched"));
        m.set_sched(12, 5, 100, 7, 3);
        let s = m.snapshot();
        assert_eq!(s.sched_blocks, 12);
        assert_eq!(s.sched_cut_edges, 5);
        assert_eq!(s.elastic_waits, 100);
        assert_eq!(s.elastic_ooo, 7);
        assert_eq!(s.elastic_steals, 3);
        let text = s.to_string();
        assert!(
            text.contains("sched blocks=12 cut=5 waits=100 ooo=7 steals=3"),
            "{text}"
        );
        // Gauges overwrite.
        m.set_sched(1, 0, 0, 0, 0);
        assert_eq!(m.snapshot().sched_blocks, 1);
    }

    #[test]
    fn shard_gauges_render_only_when_present() {
        let m = Metrics::new();
        assert!(!m.snapshot().to_string().contains("shards"));
        m.set_shards(1, 2, 3);
        let s = m.snapshot();
        assert_eq!(
            (s.shard_respawns, s.shard_crashes, s.shard_reregistered),
            (1, 2, 3)
        );
        let text = s.to_string();
        assert!(
            text.contains("shards crashes=2 respawns=1 reregistered=3"),
            "{text}"
        );
        // Gauges overwrite.
        m.set_shards(0, 0, 0);
        assert_eq!(m.snapshot().shard_respawns, 0);
    }

    #[test]
    fn shard_health_gauges_render_and_serialize() {
        let m = Metrics::new();
        assert!(!m.snapshot().to_string().contains("shard_health"));
        m.set_shard_health(vec![
            ShardHealth {
                up: true,
                last_frame_age_ms: 12,
                inflight: 1,
            },
            ShardHealth {
                up: false,
                ..Default::default()
            },
        ]);
        let s = m.snapshot();
        assert_eq!(s.shard_health.len(), 2);
        assert!(s.shard_health[0].up);
        assert!(!s.shard_health[1].up);
        let text = s.to_string();
        assert!(
            text.contains("shard_health[0:age=12ms inflight=1 1:down]"),
            "{text}"
        );
        let j = s.to_json();
        let arr = match j.get("shard_health").unwrap() {
            Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("up"), Some(&Json::Bool(true)));
        assert_eq!(arr[0].get("inflight").unwrap().as_f64(), Some(1.0));
        assert_eq!(arr[1].get("up"), Some(&Json::Bool(false)));
        // Gauges overwrite: clearing empties the rendering again.
        m.set_shard_health(Vec::new());
        assert!(m.snapshot().shard_health.is_empty());
    }

    #[test]
    fn snapshot_exports_raw_lane_histograms() {
        let m = Metrics::new();
        m.record_solve(Duration::from_micros(100), false, Lane::Interactive);
        m.record_solve(Duration::from_micros(100), false, Lane::Interactive);
        m.record_solve(Duration::from_micros(3000), true, Lane::Batch);
        let s = m.snapshot();
        assert_eq!(s.lane_hist.len(), 2);
        assert_eq!(s.lane_hist[0].len(), BUCKETS);
        // 100us lands in bucket 6 (2^6=64 <= 100 < 128), 3000us in
        // bucket 11 (2048 <= 3000 < 4096).
        assert_eq!(s.lane_hist[0][6], 2);
        assert_eq!(s.lane_hist[1][11], 1);
        assert_eq!(s.lane_hist[0].iter().sum::<u64>(), s.interactive.solves);
        assert_eq!(s.lane_hist[1].iter().sum::<u64>(), s.batch.solves);
        let j = s.to_json();
        let hist = j.get("lane_hist").unwrap();
        let inter = match hist.get("interactive").unwrap() {
            Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(inter.len(), BUCKETS);
        assert_eq!(inter[6].as_f64(), Some(2.0));
    }

    #[test]
    fn tenant_rejections_accumulate_and_render() {
        let m = Metrics::new();
        assert!(!m.snapshot().to_string().contains("tenant_rejected"));
        m.record_tenant_rejection("acme");
        m.record_tenant_rejection("acme");
        m.record_tenant_rejection("zed");
        let s = m.snapshot();
        assert_eq!(
            s.rejections_by_tenant,
            vec![("acme".to_string(), 2), ("zed".to_string(), 1)]
        );
        let text = s.to_string();
        assert!(text.contains("tenant_rejected[acme=2 zed=1]"), "{text}");
        let j = s.to_json();
        assert_eq!(
            j.get("rejections_by_tenant").unwrap().get("acme").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn analysis_lifecycle_accounting() {
        let m = Metrics::new();
        // Without analysis activity the rendering is unchanged.
        assert!(!m.snapshot().to_string().contains("analysis"));
        m.record_analysis_cache(true);
        m.record_analysis_cache(false);
        m.record_value_refresh();
        m.set_rebuilds(2, 1, 1, 3);
        let s = m.snapshot();
        assert_eq!(s.analysis_cache_hits, 1);
        assert_eq!(s.analysis_cache_misses, 1);
        assert_eq!(s.value_refreshes, 1);
        assert_eq!(
            (s.rewrite_passes, s.coarsen_passes, s.placement_passes, s.renumeric_passes),
            (2, 1, 1, 3)
        );
        let text = s.to_string();
        assert!(text.contains("analysis cache hit/miss=1/1"), "{text}");
        assert!(text.contains("value_refreshes=1"), "{text}");
        assert!(
            text.contains("passes rewrite=2 coarsen=1 place=1 renumeric=3"),
            "{text}"
        );
        // Gauges overwrite rather than accumulate.
        m.set_rebuilds(0, 0, 0, 0);
        assert_eq!(m.snapshot().coarsen_passes, 0);
    }

    #[test]
    fn residual_accounting_buckets_and_monotone_max() {
        let m = Metrics::new();
        // No accuracy activity: the rendering and histogram stay silent.
        let s = m.snapshot();
        assert_eq!(s.residual_solves, 0);
        assert_eq!(s.residual_max, 0.0);
        assert!(!s.to_string().contains("accuracy"));

        m.record_residual(3.2e-9); // [1e-9, 1e-8) -> bucket 8
        m.record_residual(5e-5); // [1e-5, 1e-4) -> bucket 4
        m.record_residual(0.0); // perfect -> last bucket
        m.record_residual(2.5); // worse than 1 -> bucket 0
        m.record_accuracy(3, 2);
        m.record_accuracy(0, 0); // zeros must not disturb anything
        let s = m.snapshot();
        assert_eq!(s.residual_solves, 4);
        assert_eq!(s.residual_hist.len(), RES_BUCKETS);
        assert_eq!(s.residual_hist[8], 1);
        assert_eq!(s.residual_hist[4], 1);
        assert_eq!(s.residual_hist[RES_BUCKETS - 1], 1);
        assert_eq!(s.residual_hist[0], 1);
        assert_eq!(s.residual_max, 2.5, "max tracks the worst, monotone");
        m.record_residual(1e-12);
        assert_eq!(m.snapshot().residual_max, 2.5, "a better residual never lowers it");
        assert_eq!(s.fallbacks_to_exact, 3);
        assert_eq!(s.sweep_escalations, 2);
        let text = s.to_string();
        assert!(
            text.contains("accuracy certified=4 worst_residual=2.5e0 fallbacks=3 escalations=2"),
            "{text}"
        );
        let j = s.to_json();
        assert_eq!(j.get("residual_solves").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("residual_max").unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("fallbacks_to_exact").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("sweep_escalations").unwrap().as_f64(), Some(2.0));
        let hist = match j.get("residual_hist").unwrap() {
            Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(hist.len(), RES_BUCKETS);
        assert_eq!(hist[8].as_f64(), Some(1.0));
    }

    #[test]
    fn percentile_edges() {
        let mut hist = vec![0u64; 40];
        hist[5] = 10;
        assert_eq!(percentile(&hist, 10, 0.5), 64);
        assert_eq!(percentile(&hist, 10, 1.0), 64);
    }

    #[test]
    fn percentile_empty_histogram_is_zero() {
        let hist = vec![0u64; 40];
        assert_eq!(percentile(&hist, 0, 0.5), 0);
        assert_eq!(percentile(&hist, 0, 0.99), 0);
        assert_eq!(percentile(&hist, 0, 1.0), 0);
    }

    #[test]
    fn percentile_single_bucket_answers_every_quantile() {
        let mut hist = vec![0u64; 40];
        hist[0] = 1;
        // One sub-microsecond sample: every quantile lands in bucket 0,
        // whose upper bound is 2us.
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&hist, 1, q), 2, "q={q}");
        }
    }

    #[test]
    fn percentile_saturating_top_bucket() {
        let mut hist = vec![0u64; 40];
        hist[39] = 5;
        // Samples clamped into the last bucket report its upper bound
        // (2^40us), and a count larger than the histogram's mass falls
        // through to the same overflow bound instead of panicking.
        assert_eq!(percentile(&hist, 5, 0.5), 1u64 << 40);
        assert_eq!(percentile(&hist, 5, 1.0), 1u64 << 40);
        assert_eq!(percentile(&hist, 10, 1.0), 1u64 << 40);
        // A clamped record_solve lands there too.
        let m = Metrics::new();
        m.record_solve(Duration::from_secs(10_000_000), false, Lane::Batch);
        assert_eq!(m.snapshot().p99_us, 1u64 << 40);
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::new();
        m.record_solve(Duration::from_micros(100), false, Lane::Interactive);
        m.record_solve(Duration::from_micros(3000), true, Lane::Batch);
        m.record_tuner_choice("avgcost+scheduled", true);
        m.record_rejection("noisy");
        m.set_sched(4, 2, 9, 1, 6);
        m.set_shards(1, 1, 2);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("solves").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("elastic_waits").unwrap().as_f64(), Some(9.0));
        assert_eq!(j.get("elastic_steals").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("shard_respawns").unwrap().as_f64(), Some(1.0));
        let lat = j.get("latency_us").unwrap();
        assert_eq!(
            lat.get("interactive").unwrap().get("solves").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            lat.get("interactive").unwrap().get("p99_us").unwrap().as_f64(),
            Some(128.0)
        );
        assert_eq!(
            lat.get("combined").unwrap().get("solves").unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            j.get("plan_wins").unwrap().get("avgcost+scheduled").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            j.get("rejections_by_matrix").unwrap().get("noisy").unwrap().as_f64(),
            Some(1.0)
        );
        // The dump round-trips through the crate's own parser.
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }
}
