//! The request loop: an mpsc-driven service thread owning the batcher,
//! the admission policies and an [`Executor`] (the tier where prepared
//! analyses live and solves run — in-process, or a pool of shard worker
//! processes). Clients hold a cheap cloneable [`SolveHandle`].
//!
//! This is the typed client surface: solve plans cross the boundary as
//! [`PlanSpec`] (parsed once at the edge — the `rewrite+exec` grammar,
//! legacy single names, `auto`), failures as [`ServiceError`] (never
//! `String`), async solves as [`SolveTicket`]s with
//! `wait`/`wait_timeout`/`try_get`/`cancel` (cancel wakes the service
//! for an immediate queue sweep), scheduling intent as [`SolveOptions`]
//! (deadline + [`Lane`] priority + tenant attribution), multi-RHS blocks
//! via [`SolveHandle::solve_many`], and admission control via the
//! `max_pending` config key (`Overloaded` rejections instead of an
//! unbounded queue), per-tenant `tenant_max_pending` quotas, and
//! per-matrix caps with a choice of [`ShedPolicy`] under burst arrivals.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::coordinator::batcher::{Batcher, Lane, Pending};
use crate::coordinator::metrics::{Metrics, ShardHealth, Snapshot};
use crate::coordinator::pipeline::AnalysisSource;
use crate::error::ServiceError;
use crate::exec_tier::{self, ExecGauges, Executor};
use crate::sparse::Csr;
use crate::telemetry::journal::{matrix_digest, structure_digest, Event, Journal};
use crate::trace::{Phase, PhaseTotals, TraceReport, Tracer, DEFAULT_RING_CAPACITY};
use crate::transform::PlanSpec;

/// Per-request scheduling options, builder style:
///
/// ```
/// use std::time::Duration;
/// use sptrsv_gt::coordinator::{Lane, SolveOptions};
///
/// let opts = SolveOptions::new()
///     .deadline(Duration::from_millis(20))
///     .priority(Lane::Interactive)
///     .tolerance(1e-8);
/// # let _ = opts;
/// ```
#[derive(Debug, Clone, Default)]
pub struct SolveOptions {
    /// drop the request (replying `DeadlineExceeded`) if it has not been
    /// dispatched within this budget of its submission
    pub deadline: Option<Duration>,
    /// scheduling lane; [`Lane::Batch`] unless set
    pub lane: Lane,
    /// tenant this request's queue usage is charged to; falls back to
    /// the matrix's registered tenant ([`RegisterOptions::tenant`]) when
    /// unset. Quota rejections under `tenant_max_pending` are reported
    /// per tenant in the metrics snapshot.
    pub tenant: Option<String>,
    /// relative-residual bound (`‖Lx−b‖∞/‖b‖∞`) this request will accept.
    /// Unset falls back to the matrix's registered
    /// [`RegisterOptions::default_tolerance`], then the service-wide
    /// `default_tolerance` config key; unset everywhere means the request
    /// demands the exact path. A stated tolerance lets an iterative plan
    /// serve the request, but the service *certifies* it: the achieved
    /// residual is measured, sweep budgets escalate when it misses, and
    /// the exact backend takes over if the ladder cannot deliver —
    /// [`ServiceError::AccuracyUnsatisfiable`] only when even the exact
    /// solve misses the bound.
    pub tolerance: Option<f64>,
}

impl SolveOptions {
    pub fn new() -> SolveOptions {
        SolveOptions::default()
    }

    /// Latency budget measured from submission.
    pub fn deadline(mut self, budget: Duration) -> SolveOptions {
        self.deadline = Some(budget);
        self
    }

    /// Scheduling lane (interactive dispatches before batch).
    pub fn priority(mut self, lane: Lane) -> SolveOptions {
        self.lane = lane;
        self
    }

    /// Shorthand for `SolveOptions::new().priority(Lane::Interactive)`.
    pub fn interactive() -> SolveOptions {
        SolveOptions::new().priority(Lane::Interactive)
    }

    /// Charge this request's queue usage to `tenant` (overriding the
    /// matrix's registered tenant, if any).
    pub fn tenant(mut self, tenant: &str) -> SolveOptions {
        self.tenant = Some(tenant.to_string());
        self
    }

    /// Accept any answer whose relative residual is within `tol`
    /// (overriding the matrix and service defaults).
    pub fn tolerance(mut self, tol: f64) -> SolveOptions {
        self.tolerance = Some(tol);
        self
    }
}

/// Handle to one in-flight request. Dropping a ticket cancels the request
/// (a queued solve whose ticket is gone is dropped before dispatch and
/// never counted as a served solve). Cancellation — explicit or by drop —
/// also **wakes the service** so the queued request is swept out and its
/// queue capacity reclaimed immediately, instead of at the next flush.
pub struct Ticket<R> {
    rx: Receiver<Result<R, ServiceError>>,
    cancel: Arc<AtomicBool>,
    /// channel back to the service, used to nudge it awake on cancel
    nudge: Sender<Request>,
    /// set once a result (or typed failure) was received — a delivered
    /// ticket's drop must not wake the service for nothing
    got: Cell<bool>,
    submitted: Instant,
}

/// Ticket for a single right-hand side ([`SolveHandle::solve_async`]).
pub type SolveTicket = Ticket<Vec<f64>>;
/// Ticket for a multi-RHS block ([`SolveHandle::solve_many`]).
pub type BlockTicket = Ticket<Vec<Vec<f64>>>;

impl<R> Ticket<R> {
    /// Block until the result (or a typed failure) arrives.
    pub fn wait(self) -> Result<R, ServiceError> {
        let r = match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServiceError::Shutdown),
        };
        self.got.set(true);
        r
    }

    /// Block up to `timeout`; `None` means still pending.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<R, ServiceError>> {
        let r = match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(ServiceError::Shutdown)),
        };
        if r.is_some() {
            self.got.set(true);
        }
        r
    }

    /// Non-blocking poll; `None` means still pending.
    pub fn try_get(&self) -> Option<Result<R, ServiceError>> {
        let r = match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(ServiceError::Shutdown)),
        };
        if r.is_some() {
            self.got.set(true);
        }
        r
    }

    /// Cancel the request. If it is still queued it is swept out before
    /// dispatch, replied `Cancelled`, and counted in the cancellation
    /// metrics; a request already dispatched completes normally. The
    /// first cancel also wakes the service so the queue slot is reclaimed
    /// immediately (observable as `cancel_wakeups` in the metrics) — a
    /// cancelled request frees `max_pending` capacity right away instead
    /// of at the next flush.
    pub fn cancel(&self) {
        if !self.cancel.swap(true, Ordering::Relaxed) {
            // Best-effort: a service that is already gone needs no nudge.
            let _ = self.nudge.send(Request::CancelWakeup);
        }
    }

    /// When the request was submitted (latency accounting).
    pub fn submitted(&self) -> Instant {
        self.submitted
    }

    /// Time since submission.
    pub fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }
}

impl<R> Drop for Ticket<R> {
    fn drop(&mut self) {
        // An abandoned ticket is a cancellation: the service must not burn
        // a solve on a result nobody can receive. A ticket whose result
        // was already delivered is not abandoned — no wakeup for those.
        if !self.got.get() {
            self.cancel();
        }
    }
}

/// Reply channel of one queued request: a single solution vector or a
/// multi-RHS block. Both carry [`ServiceError`], never `String`.
enum Reply {
    One(Sender<Result<Vec<f64>, ServiceError>>),
    Many(Sender<Result<Vec<Vec<f64>>, ServiceError>>),
}

impl Reply {
    fn send_err(self, e: ServiceError) {
        match self {
            Reply::One(tx) => {
                let _ = tx.send(Err(e));
            }
            Reply::Many(tx) => {
                let _ = tx.send(Err(e));
            }
        }
    }
}

/// What happens when a request would push a matrix past its per-matrix
/// admission cap ([`RegisterOptions::max_pending`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// bounce the arriving request with `Overloaded` (the default — the
    /// queue's contents are sacred, latecomers pay)
    #[default]
    RejectNewest,
    /// shed the oldest queued requests for this matrix (by admission
    /// order, across both lanes) until the newcomer fits — freshest work
    /// wins, stale queue heads pay. Shed requests resolve `Overloaded`
    /// and count as rejections charged to the matrix.
    DropOldest,
}

/// Per-registration options. The plan is the headline choice; the rest
/// are per-matrix serving policies layered on top of the global config.
///
/// ```
/// use sptrsv_gt::coordinator::{RegisterOptions, ShedPolicy};
/// use sptrsv_gt::transform::PlanSpec;
///
/// let opts = RegisterOptions::new()
///     .plan(PlanSpec::parse("avgcost+scheduled").unwrap())
///     .max_pending(64)
///     .shed_policy(ShedPolicy::DropOldest)
///     .tenant("acme");
/// # let _ = opts;
/// ```
#[derive(Debug, Clone, Default)]
pub struct RegisterOptions {
    /// solve plan; [`PlanSpec::Default`] defers to the configured
    /// service-wide plan
    pub plan: PlanSpec,
    /// per-matrix admission cap, counted in queued right-hand sides for
    /// this id only; `None` leaves only the global `max_pending` cap.
    /// Rejections are charged to the matrix in the metrics.
    pub max_pending: Option<usize>,
    /// what to do when the per-matrix cap trips; stated outright on
    /// every registration (reject-newest unless set)
    pub shed_policy: ShedPolicy,
    /// tenant whose `tenant_max_pending` quota this matrix's requests
    /// are charged to by default; a request's own
    /// [`SolveOptions::tenant`] overrides it
    pub tenant: Option<String>,
    /// default relative-residual bound for this matrix's requests; a
    /// request's own [`SolveOptions::tolerance`] overrides it, and the
    /// service-wide `default_tolerance` config key backstops both
    pub default_tolerance: Option<f64>,
}

impl RegisterOptions {
    pub fn new() -> RegisterOptions {
        RegisterOptions::default()
    }

    pub fn plan(mut self, plan: PlanSpec) -> RegisterOptions {
        self.plan = plan;
        self
    }

    /// Cap this matrix's queued right-hand sides (admission control per
    /// handle, on top of the global `max_pending`).
    pub fn max_pending(mut self, cap: usize) -> RegisterOptions {
        self.max_pending = Some(cap);
        self
    }

    /// Load-shedding policy when the per-matrix cap trips.
    pub fn shed_policy(mut self, policy: ShedPolicy) -> RegisterOptions {
        self.shed_policy = policy;
        self
    }

    /// Default tenant for this matrix's requests.
    pub fn tenant(mut self, tenant: &str) -> RegisterOptions {
        self.tenant = Some(tenant.to_string());
        self
    }

    /// Default accuracy bound for this matrix's requests.
    pub fn default_tolerance(mut self, tol: f64) -> RegisterOptions {
        self.default_tolerance = Some(tol);
        self
    }
}

enum Request {
    Register {
        id: String,
        matrix: Box<Csr>,
        opts: RegisterOptions,
        reply: Sender<Result<RegisterInfo, ServiceError>>,
    },
    /// same-pattern numeric refresh of a registered matrix: queued work
    /// for the id drains against the old analysis first, then the
    /// pipeline swaps in the re-numeric'd one
    UpdateValues {
        id: String,
        matrix: Box<Csr>,
        reply: Sender<Result<RegisterInfo, ServiceError>>,
    },
    Solve {
        id: String,
        rhs: Vec<Vec<f64>>,
        reply: Reply,
        submitted: Instant,
        deadline: Option<Instant>,
        lane: Lane,
        cancelled: Arc<AtomicBool>,
        tenant: Option<String>,
        tolerance: Option<f64>,
    },
    /// a ticket was cancelled: sweep the queues now so capacity frees up
    /// immediately instead of at the next flush
    CancelWakeup,
    Snapshot(Sender<Snapshot>),
    /// drain the phase tracer's aggregates (empty when tracing is off)
    TraceReport(Sender<TraceReport>),
    Shutdown,
}

/// What `register` / `update_values` report back (preprocessing summary).
#[derive(Debug, Clone)]
pub struct RegisterInfo {
    pub levels_before: usize,
    pub levels_after: usize,
    pub rows_rewritten: usize,
    pub backend: &'static str,
    /// solve plan that prepared the matrix (the tuner's pick under
    /// `auto`)
    pub plan: String,
    /// Some(hit?) when the tuner decided *for this registration*; None
    /// for fixed strategies and for same-id re-registrations, which
    /// return the memoized preparation without consulting the tuner
    pub tuner_cache_hit: Option<bool>,
    /// where the structural work came from: a fresh analysis, the
    /// persistent analysis cache (zero coarsening/placement), a value
    /// refresh, or the memoized same-id preparation
    pub source: AnalysisSource,
    pub prepare_ms: f64,
}

/// A registered matrix, as the client holds it: the typed per-matrix
/// surface over the service-resident shared `Arc<Analysis>`. Cheap to
/// clone; all clones address the same server-side analysis, and
/// [`MatrixHandle::update_values`] swaps that analysis in place for every
/// holder at once (in-flight solves drain against the old one first).
///
/// Derefs to the registration-time [`RegisterInfo`] snapshot for
/// convenience (`handle.levels_after`, `handle.plan`, ...).
#[derive(Clone)]
pub struct MatrixHandle {
    id: String,
    handle: SolveHandle,
    info: Arc<RegisterInfo>,
}

impl MatrixHandle {
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The registration-time preprocessing summary.
    pub fn info(&self) -> &RegisterInfo {
        &self.info
    }

    /// Blocking solve with default options (batch lane, no deadline).
    pub fn solve(&self, b: Vec<f64>) -> Result<Vec<f64>, ServiceError> {
        self.handle.solve(&self.id, b)
    }

    /// Blocking solve with explicit [`SolveOptions`].
    pub fn solve_with(&self, b: Vec<f64>, opts: SolveOptions) -> Result<Vec<f64>, ServiceError> {
        self.handle.solve_with(&self.id, b, opts)
    }

    /// Asynchronous solve: returns a [`SolveTicket`] immediately.
    pub fn solve_async(
        &self,
        b: Vec<f64>,
        opts: SolveOptions,
    ) -> Result<SolveTicket, ServiceError> {
        self.handle.solve_async(&self.id, b, opts)
    }

    /// Submit a block of right-hand sides as one unit.
    pub fn solve_many(
        &self,
        bs: Vec<Vec<f64>>,
        opts: SolveOptions,
    ) -> Result<BlockTicket, ServiceError> {
        self.handle.solve_many(&self.id, bs, opts)
    }

    /// Same-pattern numeric refresh: see [`SolveHandle::update_values`].
    pub fn update_values(&self, matrix: Csr) -> Result<RegisterInfo, ServiceError> {
        self.handle.update_values(&self.id, matrix)
    }
}

impl std::ops::Deref for MatrixHandle {
    type Target = RegisterInfo;

    fn deref(&self) -> &RegisterInfo {
        &self.info
    }
}

#[derive(Clone)]
pub struct SolveHandle {
    tx: Sender<Request>,
}

impl SolveHandle {
    /// Preprocess and register a matrix under `id`. The plan arrives
    /// pre-parsed: pass [`PlanSpec::Default`] to use the service's
    /// configured plan, [`PlanSpec::Auto`] for the tuner, or
    /// `PlanSpec::parse("avgcost+scheduled")?` etc. Returns a
    /// [`MatrixHandle`] addressing the service-side shared analysis.
    pub fn register(
        &self,
        id: &str,
        matrix: Csr,
        plan: PlanSpec,
    ) -> Result<MatrixHandle, ServiceError> {
        self.register_with(id, matrix, RegisterOptions::new().plan(plan))
    }

    /// [`SolveHandle::register`] with the full [`RegisterOptions`]
    /// surface (per-matrix admission cap, ...).
    pub fn register_with(
        &self,
        id: &str,
        matrix: Csr,
        opts: RegisterOptions,
    ) -> Result<MatrixHandle, ServiceError> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Register {
                id: id.to_string(),
                matrix: Box::new(matrix),
                opts,
                reply: tx,
            })
            .map_err(|_| ServiceError::Shutdown)?;
        let info = rx.recv().map_err(|_| ServiceError::Shutdown)??;
        Ok(MatrixHandle {
            id: id.to_string(),
            handle: self.clone(),
            info: Arc::new(info),
        })
    }

    /// Refresh a registered matrix's numeric values in place. The
    /// sparsity pattern must match the registration
    /// (fingerprint-checked, `InvalidRequest` otherwise). Queued solves
    /// for the id are dispatched against the **old** values first — a
    /// request submitted before the update never sees the new numerics —
    /// then the analysis is re-numeric'd without re-running rewrite
    /// analysis, coarsening or placement.
    pub fn update_values(&self, id: &str, matrix: Csr) -> Result<RegisterInfo, ServiceError> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::UpdateValues {
                id: id.to_string(),
                matrix: Box::new(matrix),
                reply: tx,
            })
            .map_err(|_| ServiceError::Shutdown)?;
        rx.recv().map_err(|_| ServiceError::Shutdown)?
    }

    /// Blocking solve with default options (batch lane, no deadline).
    pub fn solve(&self, id: &str, b: Vec<f64>) -> Result<Vec<f64>, ServiceError> {
        self.solve_async(id, b, SolveOptions::default())?.wait()
    }

    /// Blocking solve with explicit [`SolveOptions`].
    pub fn solve_with(
        &self,
        id: &str,
        b: Vec<f64>,
        opts: SolveOptions,
    ) -> Result<Vec<f64>, ServiceError> {
        self.solve_async(id, b, opts)?.wait()
    }

    /// Asynchronous solve: returns a [`SolveTicket`] immediately.
    pub fn solve_async(
        &self,
        id: &str,
        b: Vec<f64>,
        opts: SolveOptions,
    ) -> Result<SolveTicket, ServiceError> {
        let (tx, rx) = mpsc::channel();
        let (cancel, submitted) = self.submit(id, vec![b], Reply::One(tx), &opts)?;
        Ok(Ticket {
            rx,
            cancel,
            nudge: self.tx.clone(),
            got: Cell::new(false),
            submitted,
        })
    }

    /// Submit a block of right-hand sides as **one unit**: the block lands
    /// in the batcher unsplit, so a block sized to the configured
    /// `batch_size` hits the staged batched-XLA path deliberately rather
    /// than by coincidence of arrival timing. Solutions come back in
    /// submission order.
    pub fn solve_many(
        &self,
        id: &str,
        bs: Vec<Vec<f64>>,
        opts: SolveOptions,
    ) -> Result<BlockTicket, ServiceError> {
        let (tx, rx) = mpsc::channel();
        let (cancel, submitted) = self.submit(id, bs, Reply::Many(tx), &opts)?;
        Ok(Ticket {
            rx,
            cancel,
            nudge: self.tx.clone(),
            got: Cell::new(false),
            submitted,
        })
    }

    fn submit(
        &self,
        id: &str,
        rhs: Vec<Vec<f64>>,
        reply: Reply,
        opts: &SolveOptions,
    ) -> Result<(Arc<AtomicBool>, Instant), ServiceError> {
        let submitted = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        self.tx
            .send(Request::Solve {
                id: id.to_string(),
                rhs,
                reply,
                submitted,
                deadline: opts.deadline.and_then(|d| submitted.checked_add(d)),
                lane: opts.lane,
                cancelled: Arc::clone(&cancelled),
                tenant: opts.tenant.clone(),
                tolerance: opts.tolerance,
            })
            .map_err(|_| ServiceError::Shutdown)?;
        Ok((cancelled, submitted))
    }

    pub fn metrics(&self) -> Result<Snapshot, ServiceError> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Snapshot(tx))
            .map_err(|_| ServiceError::Shutdown)?;
        rx.recv().map_err(|_| ServiceError::Shutdown)
    }

    /// Per-matrix phase/span aggregates recorded since startup. Empty
    /// unless the service was started with `trace_enabled = true` (the
    /// bench harness forces it on).
    pub fn trace_report(&self) -> Result<TraceReport, ServiceError> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::TraceReport(tx))
            .map_err(|_| ServiceError::Shutdown)?;
        rx.recv().map_err(|_| ServiceError::Shutdown)
    }
}

pub struct Service {
    handle: SolveHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    pub fn start(cfg: Config) -> Service {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("sptrsv-service".into())
            .spawn(move || service_loop(cfg, rx))
            .expect("spawn service");
        Service {
            handle: SolveHandle { tx },
            join: Some(join),
        }
    }

    pub fn handle(&self) -> SolveHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct Waiting {
    reply: Reply,
    submitted: Instant,
    cancelled: Arc<AtomicBool>,
    /// effective tenant this request's queue usage is charged to
    /// (request override, else the matrix's registered tenant)
    tenant: Option<String>,
    /// effective accuracy bound, resolved at admission (request, else
    /// matrix default, else service default); `None` = exact demanded
    tolerance: Option<f64>,
}

/// The service loop's per-matrix bookkeeping: the executor owns the
/// prepared analysis; the loop owns the admission policy.
struct MatrixMeta {
    nrows: usize,
    /// per-matrix admission cap ([`RegisterOptions::max_pending`])
    cap: Option<usize>,
    /// what happens when the cap trips
    shed: ShedPolicy,
    /// default tenant for this matrix's requests
    tenant: Option<String>,
    /// default accuracy bound for this matrix's requests
    tolerance: Option<f64>,
}

/// Return `n` queued right-hand sides' worth of quota to `tenant`.
fn release_tenant(tp: &mut BTreeMap<String, usize>, tenant: &Option<String>, n: usize) {
    if let Some(t) = tenant {
        if let Some(c) = tp.get_mut(t) {
            *c = c.saturating_sub(n);
            if *c == 0 {
                tp.remove(t);
            }
        }
    }
}

fn service_loop(cfg: Config, rx: Receiver<Request>) {
    let max_pending = cfg.max_pending;
    let tenant_cap = cfg.tenant_max_pending;
    // Service-wide accuracy backstop: 0.0 (the default) means "exact
    // unless a request or registration says otherwise".
    let cfg_tolerance = (cfg.default_tolerance > 0.0).then_some(cfg.default_tolerance);
    let sharded = cfg.shard_count().is_some();
    let tracer = Tracer::new(cfg.trace_enabled, DEFAULT_RING_CAPACITY);
    let metrics = Arc::new(Metrics::new());
    // Live-traffic journal (`journal_enabled`): every shaping-relevant
    // request is appended as one JSONL event, on a bounded writer that
    // drops rather than ever blocking this loop.
    let journal = Journal::from_config(&cfg);
    // Where prepared analyses live and solves run: in this process, or
    // routed across a pool of shard worker processes.
    let mut executor = exec_tier::make_executor(&cfg);
    let mut batcher: Batcher<Waiting> = Batcher::new(
        cfg.batch_size,
        Duration::from_micros(cfg.batch_deadline_us),
    );
    let mut matrices: BTreeMap<String, MatrixMeta> = BTreeMap::new();
    // Queued right-hand sides currently charged to each tenant.
    let mut tenant_pending: BTreeMap<String, usize> = BTreeMap::new();
    // Per-matrix watermark of worker-side trace totals already folded
    // into the coordinator tracer — the solve path advances it with each
    // propagated delta; the gauges path folds only the excess above it
    // (work whose delta was lost, e.g. a shard that crashed mid-batch).
    let mut trace_seen: BTreeMap<String, PhaseTotals> = BTreeMap::new();

    loop {
        // Wait for work, but never past the oldest batching deadline.
        let req = match batcher.next_deadline() {
            Some(d) => match rx.recv_timeout(d) {
                Ok(r) => Some(r),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            },
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => return,
            },
        };

        match req {
            Some(Request::Shutdown) => {
                flush(
                    &mut batcher,
                    executor.as_mut(),
                    &metrics,
                    &tracer,
                    &mut tenant_pending,
                    &mut trace_seen,
                    true,
                );
                executor.shutdown();
                return;
            }
            Some(Request::Register {
                id,
                matrix,
                opts,
                reply,
            }) => {
                let fresh = !matrices.contains_key(&id);
                let (nrows, nnz) = (matrix.nrows, matrix.nnz());
                // Hash the payload before `register` consumes it; replay
                // uses the digests to flag structural divergence.
                let hashed = journal
                    .as_ref()
                    .map(|_| (matrix_digest(&matrix), structure_digest(&matrix)));
                let res = executor.register(&id, *matrix, &opts.plan).map(|out| {
                    if let Some((plan, hit)) = &out.tuned {
                        metrics.record_tuner_choice(plan, *hit);
                    }
                    if let Some(hit) = out.analysis_cache_hit {
                        metrics.record_analysis_cache(hit);
                    }
                    // A memo hit returns all-zero phase clocks and
                    // records nothing.
                    tracer.record_phases(&id, out.phase_times);
                    // Policy bookkeeping: a fresh registration states the
                    // matrix's policy outright; a memoized same-id
                    // re-registration only changes the cap/tenant when it
                    // explicitly carries one (a defensive re-register
                    // with plain defaults must not silently drop a
                    // previously configured cap).
                    let meta = matrices.entry(id.clone()).or_insert(MatrixMeta {
                        nrows: out.nrows,
                        cap: None,
                        shed: ShedPolicy::RejectNewest,
                        tenant: None,
                        tolerance: None,
                    });
                    meta.nrows = out.nrows;
                    match (opts.max_pending, fresh) {
                        (Some(cap), _) => meta.cap = Some(cap),
                        (None, true) => meta.cap = None,
                        (None, false) => {}
                    }
                    match (&opts.tenant, fresh) {
                        (Some(t), _) => meta.tenant = Some(t.clone()),
                        (None, true) => meta.tenant = None,
                        (None, false) => {}
                    }
                    match (opts.default_tolerance, fresh) {
                        (Some(t), _) => meta.tolerance = Some(t),
                        (None, true) => meta.tolerance = None,
                        (None, false) => {}
                    }
                    meta.shed = opts.shed_policy;
                    out.info
                });
                if let (Some(j), Ok(info)) = (&journal, &res) {
                    let mut ev = Event::register(&id, nrows, nnz, &info.plan);
                    if let Some((d, s)) = hashed {
                        (ev.digest, ev.sdigest) = (Some(d), Some(s));
                    }
                    j.record(ev);
                }
                let _ = reply.send(res);
            }
            Some(Request::UpdateValues { id, matrix, reply }) => {
                if !matrices.contains_key(&id) {
                    let _ = reply.send(Err(ServiceError::NotRegistered(id)));
                } else {
                    // Drain every queued request for this id against the
                    // OLD analysis first: work admitted before the update
                    // must never see the new numerics mid-batch.
                    loop {
                        let batch = batcher.take(&id);
                        if batch.is_empty() {
                            break;
                        }
                        dispatch(
                            executor.as_mut(),
                            &id,
                            batch,
                            &metrics,
                            &tracer,
                            &mut tenant_pending,
                            &mut trace_seen,
                        );
                    }
                    let hashed = journal
                        .as_ref()
                        .map(|_| (matrix_digest(&matrix), structure_digest(&matrix)));
                    let res = executor.update_values(&id, *matrix).map(|out| {
                        metrics.record_value_refresh();
                        tracer.record_phases(&id, out.phase_times);
                        if let Some(meta) = matrices.get_mut(&id) {
                            meta.nrows = out.nrows;
                        }
                        out.info
                    });
                    if let (Some(j), Ok(_)) = (&journal, &res) {
                        let mut ev = Event::update(&id);
                        if let Some((d, s)) = hashed {
                            (ev.digest, ev.sdigest) = (Some(d), Some(s));
                        }
                        j.record(ev);
                    }
                    let _ = reply.send(res);
                }
            }
            Some(Request::Solve {
                id,
                rhs,
                reply,
                submitted,
                deadline,
                lane,
                cancelled,
                tenant,
                tolerance,
            }) => {
                // Journal the offered load as it arrives (before any
                // admission decision): replay reproduces what clients
                // asked for, not what this run happened to admit.
                if let Some(j) = &journal {
                    let wait = deadline.map(|d| d.saturating_duration_since(submitted));
                    j.record(
                        Event::solve(
                            &id,
                            rhs.len(),
                            matches!(lane, Lane::Interactive),
                            wait.map(|w| w.as_micros() as u64),
                            tenant.as_deref(),
                        )
                        .with_tolerance(tolerance),
                    );
                }
                let pending = batcher.pending();
                match matrices.get(&id) {
                    None => {
                        metrics.record_error();
                        reply.send_err(ServiceError::NotRegistered(id));
                    }
                    Some(_) if rhs.is_empty() => {
                        // An empty block is vacuously solved.
                        if let Reply::Many(tx) = reply {
                            let _ = tx.send(Ok(Vec::new()));
                        }
                    }
                    // Validate here, not in the backend: a wrong-length
                    // right-hand side must come back as a typed error,
                    // never panic the service thread mid-dispatch.
                    Some(meta) if rhs.iter().any(|b| b.len() != meta.nrows) => {
                        metrics.record_error();
                        let n = meta.nrows;
                        let got = rhs
                            .iter()
                            .map(Vec::len)
                            .find(|&len| len != n)
                            .unwrap_or(0);
                        reply.send_err(ServiceError::InvalidRequest(format!(
                            "rhs length {got} does not match the {n} rows of '{id}'"
                        )));
                    }
                    Some(_) if max_pending > 0 && pending + rhs.len() > max_pending => {
                        metrics.record_rejection(&id);
                        reply.send_err(ServiceError::Overloaded {
                            pending,
                            max_pending,
                        });
                    }
                    // Tenant quota: the request's own tenant (or the
                    // matrix's registered one) may not hold more than
                    // `tenant_max_pending` queued right-hand sides across
                    // all matrices. Checked before the per-matrix cap so
                    // a quota breach is reported as the tenant's, not the
                    // matrix's shed policy.
                    Some(meta)
                        if tenant_cap > 0 && {
                            let t = tenant.as_ref().or(meta.tenant.as_ref());
                            t.is_some_and(|t| {
                                tenant_pending.get(t).copied().unwrap_or(0) + rhs.len()
                                    > tenant_cap
                            })
                        } =>
                    {
                        let t = tenant
                            .as_ref()
                            .or(meta.tenant.as_ref())
                            .cloned()
                            .unwrap_or_default();
                        let used = tenant_pending.get(&t).copied().unwrap_or(0);
                        metrics.record_rejection(&id);
                        metrics.record_tenant_rejection(&t);
                        reply.send_err(ServiceError::Overloaded {
                            pending: used,
                            max_pending: tenant_cap,
                        });
                    }
                    // Per-matrix cap, when the registration set one:
                    // resolve the overflow by the matrix's shed policy.
                    Some(meta)
                        if meta.cap.is_some_and(|c| {
                            c > 0 && batcher.matrix_pending(&id) + rhs.len() > c
                        }) =>
                    {
                        let cap = meta.cap.unwrap_or(0);
                        match meta.shed {
                            ShedPolicy::RejectNewest => {
                                metrics.record_rejection(&id);
                                reply.send_err(ServiceError::Overloaded {
                                    pending: batcher.matrix_pending(&id),
                                    max_pending: cap,
                                });
                            }
                            ShedPolicy::DropOldest => {
                                // Shed queue heads until the newcomer fits;
                                // each shed request resolves Overloaded and
                                // returns its tenant quota.
                                while batcher.matrix_pending(&id) + rhs.len() > cap {
                                    match batcher.pop_oldest(&id) {
                                        Some(p) => {
                                            metrics.record_rejection(&id);
                                            release_tenant(
                                                &mut tenant_pending,
                                                &p.token.tenant,
                                                p.rhs.len(),
                                            );
                                            p.token.reply.send_err(
                                                ServiceError::Overloaded {
                                                    pending: cap,
                                                    max_pending: cap,
                                                },
                                            );
                                        }
                                        None => break,
                                    }
                                }
                                if batcher.matrix_pending(&id) + rhs.len() > cap {
                                    // A block bigger than the cap itself:
                                    // shedding the whole queue cannot make
                                    // room, bounce the newcomer after all.
                                    metrics.record_rejection(&id);
                                    reply.send_err(ServiceError::Overloaded {
                                        pending: batcher.matrix_pending(&id),
                                        max_pending: cap,
                                    });
                                } else {
                                    let eff = tenant.or_else(|| meta.tenant.clone());
                                    if let Some(t) = &eff {
                                        *tenant_pending.entry(t.clone()).or_insert(0) +=
                                            rhs.len();
                                    }
                                    batcher.push(
                                        &id,
                                        rhs,
                                        lane,
                                        deadline,
                                        Waiting {
                                            reply,
                                            submitted,
                                            cancelled,
                                            tenant: eff,
                                            tolerance: tolerance
                                                .or(meta.tolerance)
                                                .or(cfg_tolerance),
                                        },
                                    );
                                }
                            }
                        }
                    }
                    Some(meta) => {
                        let eff = tenant.or_else(|| meta.tenant.clone());
                        if let Some(t) = &eff {
                            *tenant_pending.entry(t.clone()).or_insert(0) += rhs.len();
                        }
                        batcher.push(
                            &id,
                            rhs,
                            lane,
                            deadline,
                            Waiting {
                                reply,
                                submitted,
                                cancelled,
                                tenant: eff,
                                tolerance: tolerance.or(meta.tolerance).or(cfg_tolerance),
                            },
                        );
                    }
                }
            }
            Some(Request::CancelWakeup) => {
                // Reclaim the cancelled requests' queue capacity now:
                // reply, count, and let the gauge update below see the
                // shrunken queues. (dispatch() still weeds any cancel
                // that races past this sweep.)
                metrics.record_cancel_wakeup();
                if let Some(j) = &journal {
                    j.record(Event::cancel());
                }
                for q in batcher.sweep(|w: &Waiting| w.cancelled.load(Ordering::Relaxed)) {
                    metrics.record_cancellation();
                    release_tenant(&mut tenant_pending, &q.token.tenant, q.rhs.len());
                    q.token.reply.send_err(ServiceError::Cancelled);
                }
            }
            Some(Request::Snapshot(tx)) => {
                // Fold the executor's observability into the gauges before
                // snapshotting: schedule blocks + static cut, cumulative
                // elastic counters, structural-pass counters (a warm
                // analysis cache is *observably* free), and — under the
                // sharded executor — the crash/respawn/re-register tallies.
                let g = executor.gauges();
                metrics.set_sched(
                    g.sched_blocks,
                    g.sched_cut,
                    g.elastic_waits,
                    g.elastic_ooo,
                    g.elastic_steals,
                );
                metrics.set_rebuilds(
                    g.rebuilds.rewrite_passes,
                    g.rebuilds.coarsen_passes,
                    g.rebuilds.placement_passes,
                    g.rebuilds.renumeric_passes,
                );
                metrics.set_shards(g.shard_respawns, g.shard_crashes, g.shard_reregistered);
                metrics.set_shard_health(
                    g.shard_liveness
                        .iter()
                        .map(|l| ShardHealth {
                            up: l.up,
                            last_frame_age_ms: l.last_frame_age_ms,
                            inflight: l.inflight,
                        })
                        .collect(),
                );
                reconcile_trace(&tracer, &mut trace_seen, &g);
                let _ = tx.send(metrics.snapshot());
            }
            Some(Request::TraceReport(tx)) => {
                // Under the sharded executor, pull the workers' cumulative
                // totals first so execution attributed since the last poll
                // (including anything whose solve delta was lost to a
                // crash) lands in this report.
                if sharded && tracer.enabled() {
                    let g = executor.gauges();
                    reconcile_trace(&tracer, &mut trace_seen, &g);
                }
                let _ = tx.send(tracer.report());
            }
            None => {} // timeout: fall through to flush
        }
        flush(
            &mut batcher,
            executor.as_mut(),
            &metrics,
            &tracer,
            &mut tenant_pending,
            &mut trace_seen,
            false,
        );
        // Fold any spans the dispatches just pushed; the ring stays
        // near-empty outside bursts.
        tracer.drain();
        metrics.set_lane_depths(
            batcher.lane_depth(Lane::Interactive) as u64,
            batcher.lane_depth(Lane::Batch) as u64,
        );
    }
}

/// Drain every due queue. Unlike v1, which served at most one batch per
/// matrix per wakeup, this keeps taking until nothing is due — a deep
/// backlog drains in consecutive batches instead of one per deadline tick.
fn flush(
    batcher: &mut Batcher<Waiting>,
    executor: &mut dyn Executor,
    metrics: &Metrics,
    tracer: &Tracer,
    tenant_pending: &mut BTreeMap<String, usize>,
    trace_seen: &mut BTreeMap<String, PhaseTotals>,
    force: bool,
) {
    loop {
        let ready = batcher.ready(force);
        if ready.is_empty() {
            return;
        }
        for id in ready {
            let batch = batcher.take(&id);
            if batch.is_empty() {
                continue;
            }
            dispatch(
                executor,
                &id,
                batch,
                metrics,
                tracer,
                tenant_pending,
                trace_seen,
            );
        }
    }
}

/// Fold the part of the workers' cumulative per-matrix trace totals the
/// coordinator tracer has not seen yet. The executor's `trace_totals`
/// are monotone (the supervisor retires a crashed shard's last-polled
/// totals before respawning), so the excess over the `trace_seen`
/// watermark is exactly the work whose solve-response delta never
/// arrived; folding only that excess makes the two propagation channels
/// — per-solve deltas and cumulative gauges — safe to run together.
fn reconcile_trace(
    tracer: &Tracer,
    trace_seen: &mut BTreeMap<String, PhaseTotals>,
    g: &ExecGauges,
) {
    if !tracer.enabled() {
        return;
    }
    for (id, cum) in &g.trace_totals {
        let seen = trace_seen.entry(id.clone()).or_default();
        let missing = cum.saturating_sub(seen);
        if !missing.is_zero() {
            tracer.fold_totals(id, missing);
            *seen = *seen + missing;
        }
    }
}

/// Serve one taken batch: weed out cancelled/expired requests, hand the
/// live block to the executor (which batches internally when the staged
/// path matches), and resolve **every** ticket — an executor failure
/// (backend error, dead shard) resolves the whole batch `Backend`, it
/// never leaves a ticket hanging.
///
/// Execute-phase attribution depends on where the solve ran. The
/// in-process executor returns no trace delta and the coordinator's own
/// bracket around `solve_block` is the measurement. A shard worker
/// measures execution in its own process and sends the delta back on
/// the solve response; that delta is folded into the coordinator tracer
/// (and into `trace_seen`, the per-matrix watermark the gauges
/// reconciliation subtracts against) **instead of** the bracket, which
/// over a process boundary would conflate execution with frame I/O.
fn dispatch(
    executor: &mut dyn Executor,
    id: &str,
    batch: Vec<Pending<Waiting>>,
    metrics: &Metrics,
    tracer: &Tracer,
    tenant_pending: &mut BTreeMap<String, usize>,
    trace_seen: &mut BTreeMap<String, PhaseTotals>,
) {
    // Queued-RHS accounting ends at take: whatever happens below, these
    // right-hand sides no longer occupy tenant quota.
    for q in &batch {
        release_tenant(tenant_pending, &q.token.tenant, q.rhs.len());
    }

    let now = Instant::now();
    let mut live: Vec<Pending<Waiting>> = Vec::with_capacity(batch.len());
    for q in batch {
        if q.token.cancelled.load(Ordering::Relaxed) {
            metrics.record_cancellation();
            q.token.reply.send_err(ServiceError::Cancelled);
        } else if q.deadline.is_some_and(|d| now >= d) {
            metrics.record_deadline_miss();
            q.token.reply.send_err(ServiceError::DeadlineExceeded);
        } else {
            live.push(q);
        }
    }
    if live.is_empty() {
        return;
    }

    // Trace the batcher wait (admission to this dispatch) per request,
    // then bracket the execution; the executor samples the elastic
    // counters around the block so the stalls it caused land on this
    // matrix.
    if tracer.enabled() {
        for q in &live {
            tracer.record(id, Phase::Wait, now.saturating_duration_since(q.enqueued));
        }
    }
    let exec_start = Instant::now();

    // The batch's accuracy bound is the strictest any member carries —
    // and one member demanding the exact path (no tolerance) makes the
    // whole batch exact, since every member is served from the same
    // dispatched block.
    let tolerance = live
        .iter()
        .map(|q| q.token.tolerance)
        .reduce(|a, b| match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            _ => None,
        })
        .flatten();

    let rhs: Vec<Vec<f64>> = live.iter().flat_map(|q| q.rhs.iter().cloned()).collect();
    match executor.solve_block(id, &rhs, tolerance) {
        Ok(out) => {
            metrics.record_batch();
            if let Some(r) = out.residual {
                metrics.record_residual(r);
            }
            metrics.record_accuracy(out.fallbacks_to_exact, out.sweep_escalations);
            let mut xs = out.xs.into_iter();
            for q in live {
                let k = q.rhs.len();
                let outs: Vec<Vec<f64>> = xs.by_ref().take(k).collect();
                deliver(q, outs, out.batched, metrics);
            }
            if tracer.enabled() {
                match out.trace {
                    // Worker-measured delta: fold it verbatim and advance
                    // the reconciliation watermark so the next gauges poll
                    // does not fold the same work again.
                    Some(delta) => {
                        tracer.fold_totals(id, delta);
                        let seen = trace_seen.entry(id.to_string()).or_default();
                        *seen = *seen + delta;
                    }
                    // In-process: the coordinator's bracket IS execution.
                    None => {
                        tracer.record(id, Phase::Execute, exec_start.elapsed());
                        if out.residual_us > 0 {
                            tracer.record(
                                id,
                                Phase::Residual,
                                Duration::from_micros(out.residual_us),
                            );
                        }
                        let (w, o, s) = out.elastic;
                        tracer.record_elastic(id, w, o, s);
                    }
                }
            }
        }
        Err(e) => {
            metrics.record_error();
            if tracer.enabled() {
                tracer.record(id, Phase::Execute, exec_start.elapsed());
            }
            for q in live {
                q.token.reply.send_err(e.clone());
            }
        }
    }
}

/// Send a block's solutions back and account for them. A receiver that
/// disappeared between the cancellation sweep and delivery is not a served
/// request: nothing is recorded for it.
fn deliver(q: Pending<Waiting>, outs: Vec<Vec<f64>>, batched: bool, metrics: &Metrics) {
    let k = outs.len();
    let lane = q.lane;
    let latency = q.token.submitted.elapsed();
    let delivered = match q.token.reply {
        Reply::One(tx) => {
            let x = outs.into_iter().next().unwrap_or_default();
            tx.send(Ok(x)).is_ok()
        }
        Reply::Many(tx) => tx.send(Ok(outs)).is_ok(),
    };
    if delivered {
        for _ in 0..k {
            metrics.record_solve(latency, batched, lane);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    fn spec(s: &str) -> PlanSpec {
        PlanSpec::parse(s).unwrap()
    }

    fn test_cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            batch_size: 4,
            batch_deadline_us: 500,
            ..Default::default()
        }
    }

    #[test]
    fn register_solve_roundtrip() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::random_lower(200, 3, 0.8, &Default::default());
        let info = h.register("m", m.clone(), spec("avgcost")).unwrap();
        assert!(info.levels_after <= info.levels_before);
        let b = vec![1.0; 200];
        let x = h.solve("m", b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 1);
        svc.shutdown();
    }

    #[test]
    fn toleranced_solves_certify_inexact_plans_and_report_residuals() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::random_lower(150, 3, 0.8, &Default::default());
        let handle = h
            .register("inexact", m.clone(), spec("none+jacobi:2"))
            .unwrap();
        assert_eq!(handle.plan, "none+jacobi:2");
        let b = vec![1.0; 150];
        // A toleranced request may be served iteratively — but certified:
        // the answer's residual is within the bound, whatever ladder
        // escalations or fallbacks that took.
        let x = handle
            .solve_with(b.clone(), SolveOptions::new().tolerance(1e-8))
            .unwrap();
        // ‖b‖∞ = 1 here, so the absolute residual IS the relative one.
        assert!(m.residual_inf(&x, &b) <= 1e-8);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 1);
        assert_eq!(snap.residual_solves, 1, "certified batch measured");
        assert!(snap.residual_max <= 1e-8);
        assert!(snap.to_string().contains("accuracy certified=1"));

        // No tolerance anywhere = the exact path is demanded: the
        // iterative plan falls back and the fallback is observable.
        let x = handle.solve(b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-12);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 2);
        assert_eq!(snap.fallbacks_to_exact, 1);
        svc.shutdown();
    }

    #[test]
    fn registration_default_tolerance_applies_and_impossible_bounds_are_typed() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::random_lower(100, 3, 0.8, &Default::default());
        let handle = h
            .register_with(
                "acc",
                m.clone(),
                RegisterOptions::new()
                    .plan(spec("none+jacobi:2"))
                    .default_tolerance(1e-8),
            )
            .unwrap();
        // Plain solve inherits the registration's bound — served and
        // certified without the request saying anything.
        let b = vec![1.0; 100];
        let x = handle.solve(b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) <= 1e-8);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.residual_solves, 1);
        // A bound below what f64 arithmetic can deliver is a typed
        // failure — after the exact fallback also missed it.
        assert!(matches!(
            handle.solve_with(b.clone(), SolveOptions::new().tolerance(1e-300)),
            Err(ServiceError::AccuracyUnsatisfiable(_))
        ));
        let snap = h.metrics().unwrap();
        assert_eq!(snap.errors, 1);
        svc.shutdown();
    }

    #[test]
    fn auto_registration_hits_plan_cache_and_reports_metrics() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let i1 = h.register("m1", m.clone(), spec("auto")).unwrap();
        assert_eq!(i1.tuner_cache_hit, Some(false));
        assert!(!i1.plan.is_empty());
        // The tuner's decision is a full two-axis plan name.
        sptrsv_gt_plan_parses(&i1.plan);
        // Same structure, new id: answered from the fingerprint cache.
        let i2 = h.register("m2", m.clone(), spec("auto")).unwrap();
        assert_eq!(i2.tuner_cache_hit, Some(true));
        assert_eq!(i2.plan, i1.plan);
        // Same-id re-registration returns the memoized preparation: no
        // tuner consult, no metrics movement, no stale cache-hit claim.
        let i3 = h.register("m1", m.clone(), spec("auto")).unwrap();
        assert_eq!(i3.tuner_cache_hit, None);
        assert_eq!(i3.plan, i1.plan);
        let ones = vec![1.0; n];
        let x = h.solve("m2", ones.clone()).unwrap();
        assert!(m.residual_inf(&x, &ones) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.tuner_cache_hits, 1);
        assert_eq!(snap.tuner_cache_misses, 1);
        let total_wins: u64 = snap.plan_wins.iter().map(|(_, n)| n).sum();
        assert_eq!(total_wins, 2);
        svc.shutdown();
    }

    fn sptrsv_gt_plan_parses(name: &str) {
        crate::transform::SolvePlan::parse(name)
            .unwrap_or_else(|e| panic!("tuned plan '{name}' unparseable: {e}"));
    }

    #[test]
    fn unregistered_matrix_is_a_typed_error() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        assert_eq!(
            h.solve("ghost", vec![1.0]),
            Err(ServiceError::NotRegistered("ghost".into()))
        );
        assert_eq!(h.metrics().unwrap().errors, 1);
    }

    #[test]
    fn concurrent_async_solves_batch_up() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        h.register("lung", m.clone(), PlanSpec::Default).unwrap();
        let tickets: Vec<SolveTicket> = (0..8)
            .map(|i| {
                let b = vec![(i + 1) as f64; n];
                h.solve_async("lung", b, SolveOptions::default()).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let x = t.wait().unwrap();
            let b = vec![(i + 1) as f64; n];
            assert!(m.residual_inf(&x, &b) < 1e-9, "request {i}");
        }
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 8);
        svc.shutdown();
    }

    #[test]
    fn scheduled_plan_serves_and_reports_sched_metrics() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let info = h.register("sched", m.clone(), spec("scheduled")).unwrap();
        assert_eq!(info.plan, "scheduled");
        assert_eq!(info.rows_rewritten, 0, "legacy scheduled pairs with none");
        assert_eq!(info.backend, "native");
        let b = vec![1.0; n];
        let x = h.solve("sched", b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 1);
        assert!(snap.sched_blocks > 0, "schedule stats surfaced");
        assert!(snap.to_string().contains("sched blocks="));
        svc.shutdown();
    }

    #[test]
    fn composed_plan_serves_through_the_service() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let info = h
            .register("comp", m.clone(), spec("avgcost+scheduled"))
            .unwrap();
        assert_eq!(info.plan, "avgcost+scheduled");
        assert!(info.rows_rewritten > 0, "rewrite axis ran");
        assert!(info.levels_after < info.levels_before);
        let b = vec![1.0; n];
        let x = h.solve("comp", b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let snap = h.metrics().unwrap();
        assert!(snap.sched_blocks > 0, "exec axis ran on the scheduled backend");
        svc.shutdown();
    }

    #[test]
    fn multiple_matrices() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m1 = generate::tridiagonal(50, &Default::default());
        let m2 = generate::banded(80, 4, 0.5, &Default::default());
        h.register("t", m1.clone(), spec("manual:5")).unwrap();
        h.register("b", m2.clone(), spec("none")).unwrap();
        let x1 = h.solve("t", vec![2.0; 50]).unwrap();
        let x2 = h.solve("b", vec![3.0; 80]).unwrap();
        assert!(m1.residual_inf(&x1, &vec![2.0; 50]) < 1e-10);
        assert!(m2.residual_inf(&x2, &vec![3.0; 80]) < 1e-10);
        svc.shutdown();
    }

    #[test]
    fn wrong_length_rhs_is_a_typed_error_not_a_panic() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::tridiagonal(50, &Default::default());
        h.register("t", m.clone(), spec("none")).unwrap();
        // Single solve with the wrong length: typed rejection.
        assert!(matches!(
            h.solve("t", vec![1.0; 7]),
            Err(ServiceError::InvalidRequest(_))
        ));
        // One bad vector poisons the whole block, before it is queued.
        let bs = vec![vec![1.0; 50], vec![1.0; 49]];
        assert!(matches!(
            h.solve_many("t", bs, SolveOptions::default()).unwrap().wait(),
            Err(ServiceError::InvalidRequest(_))
        ));
        // The service thread survived and still serves good requests.
        let x = h.solve("t", vec![1.0; 50]).unwrap();
        assert!(m.residual_inf(&x, &vec![1.0; 50]) < 1e-10);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.errors, 2);
        assert_eq!(snap.solves, 1);
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_is_rejected_not_solved() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::tridiagonal(50, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        // A zero budget has expired by dispatch time, always.
        let t = h
            .solve_async(
                "t",
                vec![1.0; 50],
                SolveOptions::new().deadline(Duration::ZERO),
            )
            .unwrap();
        assert_eq!(t.wait(), Err(ServiceError::DeadlineExceeded));
        let snap = h.metrics().unwrap();
        assert_eq!(snap.deadline_misses, 1);
        assert_eq!(snap.solves, 0, "expired request must not be solved");
        // A generous deadline still solves normally.
        let x = h
            .solve_with(
                "t",
                vec![1.0; 50],
                SolveOptions::interactive().deadline(Duration::from_secs(10)),
            )
            .unwrap();
        assert_eq!(x.len(), 50);
        assert_eq!(h.metrics().unwrap().solves, 1);
        svc.shutdown();
    }

    #[test]
    fn cancelled_ticket_is_observable_in_metrics() {
        let svc = Service::start(Config {
            // Long batching deadline: the cancel always lands before the
            // flush that would have dispatched the request.
            batch_deadline_us: 50_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(40, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        let t = h
            .solve_async("t", vec![1.0; 40], SolveOptions::default())
            .unwrap();
        t.cancel();
        assert_eq!(t.wait(), Err(ServiceError::Cancelled));
        let snap = h.metrics().unwrap();
        assert_eq!(snap.cancellations, 1);
        assert_eq!(snap.solves, 0, "cancelled request must not be solved");
        assert!(snap.cancel_wakeups >= 1, "cancel woke the service");
        svc.shutdown();
    }

    #[test]
    fn cancel_wakes_service_and_reclaims_capacity_immediately() {
        // One admission slot, a batching deadline far beyond the test:
        // without the cancel wakeup the slot would stay occupied until
        // the (minute-long) flush and the second request would bounce
        // Overloaded.
        let svc = Service::start(Config {
            max_pending: 1,
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        let t1 = h
            .solve_async("t", vec![1.0; 30], SolveOptions::default())
            .unwrap();
        t1.cancel();
        // The sweep replies Cancelled without waiting for any flush.
        assert_eq!(
            t1.wait_timeout(Duration::from_secs(5)),
            Some(Err(ServiceError::Cancelled))
        );
        // Capacity is back: the next request is admitted (no Overloaded
        // reply arrives), not rejected.
        let t2 = h
            .solve_async("t", vec![2.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(t2.wait_timeout(Duration::from_millis(200)), None);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.cancellations, 1);
        assert_eq!(snap.rejections, 0, "slot was reclaimed before t2 arrived");
        assert!(snap.cancel_wakeups >= 1);
        assert_eq!(snap.lane_batch_depth, 1, "only t2 still queued");
        svc.shutdown();
    }

    #[test]
    fn dropped_ticket_does_not_count_as_solve() {
        let svc = Service::start(Config {
            // Wide enough that the drop always lands before the flush.
            batch_deadline_us: 20_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(40, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        drop(
            h.solve_async("t", vec![1.0; 40], SolveOptions::default())
                .unwrap(),
        );
        // Wait out the batching deadline (generously) so the service has
        // flushed the abandoned request.
        std::thread::sleep(Duration::from_millis(100));
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 0);
        assert_eq!(snap.cancellations, 1);
        svc.shutdown();
    }

    #[test]
    fn solve_many_lands_as_one_batch() {
        let svc = Service::start(test_cfg()); // batch_size 4
        let h = svc.handle();
        let m = generate::tridiagonal(60, &Default::default());
        h.register("t", m.clone(), spec("manual:5")).unwrap();
        let bs: Vec<Vec<f64>> = (1..=4).map(|i| vec![i as f64; 60]).collect();
        let t = h.solve_many("t", bs.clone(), SolveOptions::default()).unwrap();
        let xs = t.wait().unwrap();
        assert_eq!(xs.len(), 4);
        for (b, x) in bs.iter().zip(&xs) {
            assert!(m.residual_inf(x, b) < 1e-10);
        }
        let snap = h.metrics().unwrap();
        assert_eq!(snap.batches, 1, "a batch-sized block is exactly one batch");
        assert_eq!(snap.solves, 4);
        // An empty block is vacuously solved without touching the queue.
        let empty = h
            .solve_many("t", Vec::new(), SolveOptions::default())
            .unwrap();
        assert_eq!(empty.wait().unwrap(), Vec::<Vec<f64>>::new());
        svc.shutdown();
    }

    #[test]
    fn admission_control_rejects_over_max_pending() {
        let svc = Service::start(Config {
            max_pending: 2,
            batch_size: 100,                 // nothing fills
            batch_deadline_us: 60_000_000,   // nothing expires mid-test
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        let _t1 = h
            .solve_async("t", vec![1.0; 30], SolveOptions::default())
            .unwrap();
        let _t2 = h
            .solve_async("t", vec![2.0; 30], SolveOptions::interactive())
            .unwrap();
        let t3 = h
            .solve_async("t", vec![3.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(
            t3.wait(),
            Err(ServiceError::Overloaded {
                pending: 2,
                max_pending: 2
            })
        );
        let snap = h.metrics().unwrap();
        assert_eq!(snap.rejections, 1);
        // The lane-depth gauges see the two admitted requests.
        assert_eq!(snap.lane_interactive_depth, 1);
        assert_eq!(snap.lane_batch_depth, 1);
        svc.shutdown();
    }

    #[test]
    fn update_values_refreshes_behind_the_batcher() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let handle = h
            .register("m", m.clone(), spec("avgcost+scheduled"))
            .unwrap();
        assert_eq!(handle.source, crate::coordinator::AnalysisSource::Fresh);
        assert_eq!(handle.id(), "m");
        let b = vec![1.0; n];
        let x = handle.solve(b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);

        // Refresh with perturbed values: same pattern, new numerics.
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 1.25;
        }
        let info = handle.update_values(m2.clone()).unwrap();
        assert_eq!(info.source, crate::coordinator::AnalysisSource::Refreshed);
        assert_eq!(info.plan, handle.plan, "plan survives the refresh");
        // Solves now target the refreshed system, through the same handle.
        let x2 = handle.solve(b.clone()).unwrap();
        assert!(m2.residual_inf(&x2, &b) < 1e-9);
        assert!(m.residual_inf(&x2, &b) > 1e-3, "values really changed");

        // A changed sparsity pattern is the caller's error, typed.
        let other = generate::tridiagonal(n, &Default::default());
        assert!(matches!(
            handle.update_values(other),
            Err(ServiceError::InvalidRequest(_))
        ));
        // Unknown ids are NotRegistered.
        assert_eq!(
            h.update_values("ghost", m.clone()),
            Err(ServiceError::NotRegistered("ghost".into()))
        );

        let snap = h.metrics().unwrap();
        assert_eq!(snap.value_refreshes, 1);
        // The refresh paid a renumeric pass but no structural pass beyond
        // the original registration's.
        assert_eq!(snap.renumeric_passes, 1);
        assert_eq!(snap.coarsen_passes, 1);
        assert_eq!(snap.placement_passes, 1);
        assert!(snap.to_string().contains("value_refreshes=1"));
        svc.shutdown();
    }

    #[test]
    fn queued_solves_drain_against_old_values_before_a_refresh() {
        // A minute-long batching deadline: queued work only dispatches
        // when something forces it — here, the update_values drain.
        let svc = Service::start(Config {
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(40, &Default::default());
        let handle = h.register("t", m.clone(), spec("none")).unwrap();
        let b = vec![1.0; 40];
        let t1 = handle.solve_async(b.clone(), SolveOptions::default()).unwrap();
        // Scale the whole system by 4: solutions under the new values
        // differ from the old by 4x.
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 4.0;
        }
        handle.update_values(m2.clone()).unwrap();
        // The queued request was served against the OLD matrix.
        let x1 = t1.wait().unwrap();
        assert!(m.residual_inf(&x1, &b) < 1e-10, "pre-update request saw new values");
        // A request submitted after the update sees the new matrix.
        let x2 = handle.solve(b.clone()).unwrap();
        assert!(m2.residual_inf(&x2, &b) < 1e-10);
        svc.shutdown();
    }

    #[test]
    fn per_matrix_max_pending_overrides_and_is_charged_to_the_matrix() {
        let svc = Service::start(Config {
            max_pending: 100, // generous global cap
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        let capped = h
            .register_with(
                "capped",
                m.clone(),
                RegisterOptions::new()
                    .plan(spec("none"))
                    .max_pending(1),
            )
            .unwrap();
        let free = h.register("free", m.clone(), spec("none")).unwrap();

        let _q1 = capped
            .solve_async(vec![1.0; 30], SolveOptions::default())
            .unwrap();
        // Second request for the capped matrix bounces with the
        // per-matrix numbers, well under the global cap.
        let q2 = capped
            .solve_async(vec![2.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(
            q2.wait(),
            Err(ServiceError::Overloaded {
                pending: 1,
                max_pending: 1
            })
        );
        // The uncapped matrix is unaffected.
        let f1 = free
            .solve_async(vec![3.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(f1.wait_timeout(Duration::from_millis(100)), None);

        let snap = h.metrics().unwrap();
        assert_eq!(snap.rejections, 1);
        assert_eq!(snap.rejections_by_matrix, vec![("capped".to_string(), 1)]);
        svc.shutdown();
    }

    #[test]
    fn tenant_quota_caps_queued_work_and_reports_per_tenant() {
        let svc = Service::start(Config {
            tenant_max_pending: 2,
            batch_size: 100,               // nothing fills
            batch_deadline_us: 60_000_000, // nothing expires mid-test
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        let acme = h
            .register_with(
                "acme-m",
                m.clone(),
                RegisterOptions::new().plan(spec("none")).tenant("acme"),
            )
            .unwrap();

        // Two queued right-hand sides fill acme's quota...
        let t1 = acme
            .solve_async(vec![1.0; 30], SolveOptions::default())
            .unwrap();
        let t2 = acme
            .solve_async(vec![2.0; 30], SolveOptions::default())
            .unwrap();
        // ...and the third bounces with the tenant's numbers.
        let t3 = acme
            .solve_async(vec![3.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(
            t3.wait(),
            Err(ServiceError::Overloaded {
                pending: 2,
                max_pending: 2
            })
        );
        // A per-request tenant override is charged to its own quota, so
        // it is admitted even though acme is full.
        let z = acme
            .solve_async(vec![4.0; 30], SolveOptions::new().tenant("zen"))
            .unwrap();
        assert_eq!(z.wait_timeout(Duration::from_millis(50)), None);

        let snap = h.metrics().unwrap();
        assert_eq!(snap.rejections, 1);
        assert_eq!(snap.rejections_by_tenant, vec![("acme".to_string(), 1)]);

        // Shutdown force-flushes: the admitted requests still resolve.
        svc.shutdown();
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
        assert!(z.wait().is_ok());
    }

    #[test]
    fn drop_oldest_sheds_queue_heads_under_burst_arrivals() {
        let svc = Service::start(Config {
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        let cap2 = h
            .register_with(
                "bursty",
                m.clone(),
                RegisterOptions::new()
                    .plan(spec("none"))
                    .max_pending(2)
                    .shed_policy(ShedPolicy::DropOldest),
            )
            .unwrap();

        // Burst of three: under drop-oldest the FIRST request is shed to
        // make room for the third, instead of the third bouncing.
        let t1 = cap2
            .solve_async(vec![1.0; 30], SolveOptions::default())
            .unwrap();
        let t2 = cap2
            .solve_async(vec![2.0; 30], SolveOptions::default())
            .unwrap();
        let t3 = cap2
            .solve_async(vec![3.0; 30], SolveOptions::default())
            .unwrap();
        assert_eq!(
            t1.wait(),
            Err(ServiceError::Overloaded {
                pending: 2,
                max_pending: 2
            })
        );

        let snap = h.metrics().unwrap();
        assert_eq!(snap.rejections, 1);
        assert_eq!(snap.rejections_by_matrix, vec![("bursty".to_string(), 1)]);

        // The survivors are the two freshest; both serve on shutdown.
        svc.shutdown();
        let b2 = vec![2.0; 30];
        let x2 = t2.wait().unwrap();
        assert!(m.residual_inf(&x2, &b2) < 1e-9);
        assert!(t3.wait().is_ok());
    }

    #[test]
    fn reject_newest_bounces_the_burst_tail_by_default() {
        let svc = Service::start(Config {
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        // Default policy: no shed_policy stated.
        let cap2 = h
            .register_with(
                "bursty",
                m.clone(),
                RegisterOptions::new().plan(spec("none")).max_pending(2),
            )
            .unwrap();

        let t1 = cap2
            .solve_async(vec![1.0; 30], SolveOptions::default())
            .unwrap();
        let t2 = cap2
            .solve_async(vec![2.0; 30], SolveOptions::default())
            .unwrap();
        let t3 = cap2
            .solve_async(vec![3.0; 30], SolveOptions::default())
            .unwrap();
        // The latecomer pays; the queue's contents survive.
        assert_eq!(
            t3.wait(),
            Err(ServiceError::Overloaded {
                pending: 2,
                max_pending: 2
            })
        );

        svc.shutdown();
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
    }

    #[test]
    fn warm_analysis_cache_registration_skips_coarsening_and_placement() {
        let dir = std::env::temp_dir().join(format!(
            "sptrsv_svc_acache_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = Config {
            analysis_cache: dir.to_str().unwrap().to_string(),
            ..test_cfg()
        };
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        {
            let svc = Service::start(cfg.clone());
            let h = svc.handle();
            let info = h.register("cold", m.clone(), spec("avgcost+scheduled")).unwrap();
            assert_eq!(info.source, crate::coordinator::AnalysisSource::Fresh);
            let snap = h.metrics().unwrap();
            assert_eq!(snap.analysis_cache_misses, 1);
            assert!(snap.coarsen_passes > 0);
            svc.shutdown();
        }
        // A fresh service (restart) re-registers the known structure:
        // zero coarsening, zero placement, zero rewrite analysis — the
        // counter-asserted acceptance criterion.
        let svc = Service::start(cfg);
        let h = svc.handle();
        let handle = h.register("warm", m.clone(), spec("avgcost+scheduled")).unwrap();
        assert_eq!(handle.source, crate::coordinator::AnalysisSource::DiskCache);
        let b = vec![1.0; n];
        let x = handle.solve(b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.analysis_cache_hits, 1);
        assert_eq!(snap.coarsen_passes, 0, "warm registration coarsened");
        assert_eq!(snap.placement_passes, 0, "warm registration placed");
        assert_eq!(snap.rewrite_passes, 0, "warm registration rewrote");
        assert_eq!(snap.renumeric_passes, 1);
        assert!(snap.to_string().contains("analysis cache hit/miss=1/0"));
        svc.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tracing_attributes_phases_and_spans_per_matrix() {
        let svc = Service::start(Config {
            trace_enabled: true,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let handle = h
            .register("traced", m.clone(), spec("avgcost+scheduled"))
            .unwrap();
        let b = vec![1.0; n];
        handle.solve(b.clone()).unwrap();
        handle
            .solve_with(b.clone(), SolveOptions::interactive())
            .unwrap();
        // A refresh adds a renumeric span for the same matrix.
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 1.5;
        }
        handle.update_values(m2).unwrap();

        let r = h.trace_report().unwrap();
        let t = r.get("traced").expect("matrix has trace totals");
        // Registration recorded analyze-side spans, dispatch recorded
        // wait + execute ones. Sub-microsecond phases may round to 0us,
        // so assert the span structure, not the clock values.
        assert!(t.spans >= 4, "expected register + dispatch spans, got {t:?}");
        let r2 = h.trace_report().unwrap();
        assert_eq!(
            r2.get("traced").unwrap().spans,
            t.spans,
            "report is a snapshot, not a destructive drain"
        );
        // The combined + per-lane latency accounting saw both lanes.
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 2);
        assert_eq!(snap.interactive.solves, 1);
        assert_eq!(snap.batch.solves, 1);
        svc.shutdown();
    }

    #[test]
    fn tracing_disabled_by_default_reports_empty() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::tridiagonal(40, &Default::default());
        h.register("t", m, spec("none")).unwrap();
        h.solve("t", vec![1.0; 40]).unwrap();
        assert!(h.trace_report().unwrap().matrices.is_empty());
        svc.shutdown();
    }

    #[test]
    fn shutdown_force_flushes_pending_work() {
        let svc = Service::start(Config {
            batch_size: 100,
            batch_deadline_us: 60_000_000,
            ..test_cfg()
        });
        let h = svc.handle();
        let m = generate::tridiagonal(30, &Default::default());
        h.register("t", m.clone(), spec("none")).unwrap();
        let tickets: Vec<SolveTicket> = (0..3)
            .map(|_| {
                h.solve_async("t", vec![1.0; 30], SolveOptions::default())
                    .unwrap()
            })
            .collect();
        svc.shutdown(); // force flush serves the queue before exiting
        for t in tickets {
            let x = t.wait().unwrap();
            assert!(m.residual_inf(&x, &vec![1.0; 30]) < 1e-10);
        }
    }
}
