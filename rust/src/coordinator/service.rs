//! The request loop: an mpsc-driven service thread owning the pipeline,
//! the batcher and the backends. Clients hold a cheap cloneable
//! [`SolveHandle`].

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::coordinator::pipeline::{Backend, Pipeline, Prepared};
use crate::error::Error;
use crate::runtime::XlaSolver;
use crate::sparse::Csr;

type SolveReply = Sender<Result<Vec<f64>, String>>;

enum Request {
    Register {
        id: String,
        matrix: Box<Csr>,
        strategy: Option<String>,
        reply: Sender<Result<RegisterInfo, String>>,
    },
    Solve {
        id: String,
        b: Vec<f64>,
        reply: SolveReply,
        submitted: Instant,
    },
    Snapshot(Sender<Snapshot>),
    Shutdown,
}

/// What `register` reports back (preprocessing summary).
#[derive(Debug, Clone)]
pub struct RegisterInfo {
    pub levels_before: usize,
    pub levels_after: usize,
    pub rows_rewritten: usize,
    pub backend: &'static str,
    /// strategy that prepared the matrix (the tuner's pick under `auto`)
    pub strategy: String,
    /// Some(hit?) when the tuner decided *for this registration*; None
    /// for fixed strategies and for same-id re-registrations, which
    /// return the memoized preparation without consulting the tuner
    pub tuner_cache_hit: Option<bool>,
    pub prepare_ms: f64,
}

#[derive(Clone)]
pub struct SolveHandle {
    tx: Sender<Request>,
}

impl SolveHandle {
    pub fn register(
        &self,
        id: &str,
        matrix: Csr,
        strategy: Option<&str>,
    ) -> Result<RegisterInfo, Error> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Register {
                id: id.to_string(),
                matrix: Box::new(matrix),
                strategy: strategy.map(str::to_string),
                reply: tx,
            })
            .map_err(|_| Error::Runtime("service stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("service stopped".into()))?
            .map_err(Error::Runtime)
    }

    /// Blocking solve (the caller's thread waits for the batch).
    pub fn solve(&self, id: &str, b: Vec<f64>) -> Result<Vec<f64>, Error> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Solve {
                id: id.to_string(),
                b,
                reply: tx,
                submitted: Instant::now(),
            })
            .map_err(|_| Error::Runtime("service stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("service stopped".into()))?
            .map_err(Error::Runtime)
    }

    /// Fire-and-forget async solve; returns the receiving end.
    pub fn solve_async(
        &self,
        id: &str,
        b: Vec<f64>,
    ) -> Result<Receiver<Result<Vec<f64>, String>>, Error> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Solve {
                id: id.to_string(),
                b,
                reply: tx,
                submitted: Instant::now(),
            })
            .map_err(|_| Error::Runtime("service stopped".into()))?;
        Ok(rx)
    }

    pub fn metrics(&self) -> Result<Snapshot, Error> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Snapshot(tx))
            .map_err(|_| Error::Runtime("service stopped".into()))?;
        rx.recv().map_err(|_| Error::Runtime("service stopped".into()))
    }
}

pub struct Service {
    handle: SolveHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    pub fn start(cfg: Config) -> Service {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("sptrsv-service".into())
            .spawn(move || service_loop(cfg, rx))
            .expect("spawn service");
        Service {
            handle: SolveHandle { tx },
            join: Some(join),
        }
    }

    pub fn handle(&self) -> SolveHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct Waiting {
    reply: SolveReply,
    submitted: Instant,
}

fn service_loop(cfg: Config, rx: Receiver<Request>) {
    let mut pipeline = Pipeline::new(cfg.clone());
    let xla: Option<XlaSolver> = pipeline.xla_solver();
    let metrics = Arc::new(Metrics::new());
    let mut batcher: Batcher<Waiting> = Batcher::new(
        cfg.batch_size,
        Duration::from_micros(cfg.batch_deadline_us),
    );
    let mut prepared: BTreeMap<String, Arc<Prepared>> = BTreeMap::new();

    loop {
        // Wait for work, but never past the oldest batching deadline.
        let req = match batcher.next_deadline() {
            Some(d) => match rx.recv_timeout(d) {
                Ok(r) => Some(r),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            },
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => return,
            },
        };

        match req {
            Some(Request::Shutdown) => {
                flush(&mut batcher, &prepared, &xla, &metrics, true);
                return;
            }
            Some(Request::Register {
                id,
                matrix,
                strategy,
                reply,
            }) => {
                // A same-id re-registration returns the memoized
                // preparation; only fresh preparations count as tuner
                // decisions in the metrics.
                let fresh = !prepared.contains_key(&id);
                let res = pipeline
                    .prepare(&id, *matrix, strategy.as_deref())
                    .map(|p| {
                        if fresh {
                            if let Some(tuned) = &p.tuned {
                                metrics.record_tuner_choice(&tuned.strategy, tuned.cache_hit);
                            }
                        }
                        prepared.insert(id.clone(), Arc::clone(&p));
                        RegisterInfo {
                            levels_before: p.t.stats.levels_before,
                            levels_after: p.t.stats.levels_after,
                            rows_rewritten: p.t.stats.rows_rewritten,
                            backend: match p.backend {
                                Backend::Native => "native",
                                Backend::Xla => "xla",
                            },
                            strategy: p.strategy_name.clone(),
                            tuner_cache_hit: if fresh {
                                p.tuned.as_ref().map(|t| t.cache_hit)
                            } else {
                                None
                            },
                            prepare_ms: p.prepare_time.as_secs_f64() * 1e3,
                        }
                    })
                    .map_err(|e| e.to_string());
                let _ = reply.send(res);
            }
            Some(Request::Solve {
                id,
                b,
                reply,
                submitted,
            }) => {
                if !prepared.contains_key(&id) {
                    metrics.record_error();
                    let _ = reply.send(Err(format!("matrix '{id}' not registered")));
                } else {
                    batcher.push(&id, b, Waiting { reply, submitted });
                }
            }
            Some(Request::Snapshot(tx)) => {
                let _ = tx.send(metrics.snapshot());
            }
            None => {} // timeout: fall through to flush
        }
        flush(&mut batcher, &prepared, &xla, &metrics, false);
    }
}

fn flush(
    batcher: &mut Batcher<Waiting>,
    prepared: &BTreeMap<String, Arc<Prepared>>,
    xla: &Option<XlaSolver>,
    metrics: &Metrics,
    force: bool,
) {
    for id in batcher.ready(force) {
        let Some(p) = prepared.get(&id) else { continue };
        loop {
            let batch = batcher.take(&id);
            if batch.is_empty() {
                break;
            }
            serve_batch(p, batch, xla, metrics);
            if !force {
                break;
            }
        }
    }
}

fn serve_batch(
    p: &Prepared,
    batch: Vec<crate::coordinator::batcher::Pending<Waiting>>,
    xla: &Option<XlaSolver>,
    metrics: &Metrics,
) {
    // Try the staged batched XLA path when the batch size matches
    // exactly; otherwise solve each RHS on the chosen backend.
    if batch.len() > 1 {
        if let (Backend::Xla, Some(solver), Some(padded), Some(staged)) =
            (p.backend, xla, &p.padded, &p.staged)
        {
            if staged.batch_size() == Some(batch.len()) {
                let bs: Vec<Vec<f64>> = batch.iter().map(|q| q.b.clone()).collect();
                if let Ok(xs) = solver.solve_batched_staged(staged, padded, &bs) {
                    metrics.record_batch();
                    for (q, x) in batch.into_iter().zip(xs) {
                        metrics.record_solve(q.token.submitted.elapsed(), true);
                        let _ = q.token.reply.send(Ok(x));
                    }
                    return;
                }
            }
        }
    }
    metrics.record_batch();
    for q in batch {
        let res = match (p.backend, xla, &p.padded, &p.staged) {
            (Backend::Xla, Some(solver), Some(padded), Some(staged)) => solver
                .solve_staged(staged, padded, &q.b)
                .map_err(|e| e.to_string())
                .or_else(|_| Ok::<_, String>(p.native.solve(&q.b))),
            _ => Ok(p.native.solve(&q.b)),
        };
        if res.is_err() {
            metrics.record_error();
        }
        metrics.record_solve(q.token.submitted.elapsed(), false);
        let _ = q.token.reply.send(res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    fn test_cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            batch_size: 4,
            batch_deadline_us: 500,
            ..Default::default()
        }
    }

    #[test]
    fn register_solve_roundtrip() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::random_lower(200, 3, 0.8, &Default::default());
        let info = h.register("m", m.clone(), Some("avgcost")).unwrap();
        assert!(info.levels_after <= info.levels_before);
        let b = vec![1.0; 200];
        let x = h.solve("m", b.clone()).unwrap();
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 1);
        svc.shutdown();
    }

    #[test]
    fn auto_registration_hits_plan_cache_and_reports_metrics() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let i1 = h.register("m1", m.clone(), Some("auto")).unwrap();
        assert_eq!(i1.tuner_cache_hit, Some(false));
        assert!(!i1.strategy.is_empty());
        // Same structure, new id: answered from the fingerprint cache.
        let i2 = h.register("m2", m.clone(), Some("auto")).unwrap();
        assert_eq!(i2.tuner_cache_hit, Some(true));
        assert_eq!(i2.strategy, i1.strategy);
        // Same-id re-registration returns the memoized preparation: no
        // tuner consult, no metrics movement, no stale cache-hit claim.
        let i3 = h.register("m1", m.clone(), Some("auto")).unwrap();
        assert_eq!(i3.tuner_cache_hit, None);
        assert_eq!(i3.strategy, i1.strategy);
        let ones = vec![1.0; n];
        let x = h.solve("m2", ones.clone()).unwrap();
        assert!(m.residual_inf(&x, &ones) < 1e-9);
        let snap = h.metrics().unwrap();
        assert_eq!(snap.tuner_cache_hits, 1);
        assert_eq!(snap.tuner_cache_misses, 1);
        let total_wins: u64 = snap.strategy_wins.iter().map(|(_, n)| n).sum();
        assert_eq!(total_wins, 2);
        svc.shutdown();
    }

    #[test]
    fn unregistered_matrix_errors() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        assert!(h.solve("ghost", vec![1.0]).is_err());
        assert_eq!(h.metrics().unwrap().errors, 1);
    }

    #[test]
    fn concurrent_async_solves_batch_up() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        h.register("lung", m.clone(), None).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let b = vec![(i + 1) as f64; n];
                h.solve_async("lung", b).unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let x = rx.recv().unwrap().unwrap();
            let b = vec![(i + 1) as f64; n];
            assert!(m.residual_inf(&x, &b) < 1e-9, "request {i}");
        }
        let snap = h.metrics().unwrap();
        assert_eq!(snap.solves, 8);
        svc.shutdown();
    }

    #[test]
    fn multiple_matrices() {
        let svc = Service::start(test_cfg());
        let h = svc.handle();
        let m1 = generate::tridiagonal(50, &Default::default());
        let m2 = generate::banded(80, 4, 0.5, &Default::default());
        h.register("t", m1.clone(), Some("manual:5")).unwrap();
        h.register("b", m2.clone(), Some("none")).unwrap();
        let x1 = h.solve("t", vec![2.0; 50]).unwrap();
        let x2 = h.solve("b", vec![3.0; 80]).unwrap();
        assert!(m1.residual_inf(&x1, &vec![2.0; 50]) < 1e-10);
        assert!(m2.residual_inf(&x2, &vec![3.0; 80]) < 1e-10);
        svc.shutdown();
    }
}
