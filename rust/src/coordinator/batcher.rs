//! RHS batching queue: requests for the same matrix are grouped up to the
//! configured batch size, or flushed when the oldest request exceeds the
//! batching deadline. The batched XLA executable then solves all
//! right-hand sides in one call (vmapped scan — see model.py).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One queued solve request.
pub struct Pending<T> {
    pub b: Vec<f64>,
    pub token: T,
    pub enqueued: Instant,
}

pub struct Batcher<T> {
    queues: BTreeMap<String, Vec<Pending<T>>>,
    pub batch_size: usize,
    pub deadline: Duration,
}

impl<T> Batcher<T> {
    pub fn new(batch_size: usize, deadline: Duration) -> Batcher<T> {
        Batcher {
            queues: BTreeMap::new(),
            batch_size: batch_size.max(1),
            deadline,
        }
    }

    pub fn push(&mut self, matrix_id: &str, b: Vec<f64>, token: T) {
        self.queues
            .entry(matrix_id.to_string())
            .or_default()
            .push(Pending {
                b,
                token,
                enqueued: Instant::now(),
            });
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(Vec::len).sum()
    }

    /// Matrices whose queue is ready: full batch, or deadline expired.
    /// `force` flushes everything non-empty.
    pub fn ready(&self, force: bool) -> Vec<String> {
        let now = Instant::now();
        self.queues
            .iter()
            .filter(|(_, q)| {
                !q.is_empty()
                    && (force
                        || q.len() >= self.batch_size
                        || q.iter()
                            .any(|p| now.duration_since(p.enqueued) >= self.deadline))
            })
            .map(|(id, _)| id.clone())
            .collect()
    }

    /// Take up to `batch_size` requests for a matrix (FIFO).
    pub fn take(&mut self, matrix_id: &str) -> Vec<Pending<T>> {
        match self.queues.get_mut(matrix_id) {
            None => Vec::new(),
            Some(q) => {
                let n = q.len().min(self.batch_size);
                q.drain(..n).collect()
            }
        }
    }

    /// Time until the oldest pending request hits its deadline (service
    /// loop uses this for recv_timeout).
    pub fn next_deadline(&self) -> Option<Duration> {
        let now = Instant::now();
        self.queues
            .values()
            .flat_map(|q| q.iter())
            .map(|p| {
                self.deadline
                    .saturating_sub(now.duration_since(p.enqueued))
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_fill_and_flush() {
        let mut b: Batcher<usize> = Batcher::new(3, Duration::from_secs(60));
        b.push("m", vec![1.0], 0);
        b.push("m", vec![2.0], 1);
        assert!(b.ready(false).is_empty()); // not full, not expired
        b.push("m", vec![3.0], 2);
        assert_eq!(b.ready(false), vec!["m".to_string()]);
        let taken = b.take("m");
        assert_eq!(taken.len(), 3);
        assert_eq!(taken[0].token, 0); // FIFO
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_millis(1));
        b.push("m", vec![1.0], 0);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.ready(false), vec!["m".to_string()]);
        assert_eq!(b.take("m").len(), 1);
    }

    #[test]
    fn force_flush() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        b.push("a", vec![1.0], 0);
        b.push("z", vec![2.0], 1);
        let mut r = b.ready(true);
        r.sort();
        assert_eq!(r, vec!["a".to_string(), "z".to_string()]);
    }

    #[test]
    fn take_caps_at_batch_size() {
        let mut b: Batcher<usize> = Batcher::new(2, Duration::from_secs(60));
        for i in 0..5 {
            b.push("m", vec![i as f64], i);
        }
        assert_eq!(b.take("m").len(), 2);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.take("missing").len(), 0);
    }

    #[test]
    fn next_deadline_monotone() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_millis(100));
        assert!(b.next_deadline().is_none());
        b.push("m", vec![1.0], 0);
        let d = b.next_deadline().unwrap();
        assert!(d <= Duration::from_millis(100));
    }
}
