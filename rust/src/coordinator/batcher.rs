//! RHS batching queue: requests for the same matrix are grouped up to the
//! configured batch size, or flushed when the oldest request exceeds the
//! batching deadline. The batched XLA executable then solves all
//! right-hand sides in one call (vmapped scan — see model.py).
//!
//! v2 surface: every queued request is a *block* of one or more
//! right-hand sides (so `solve_many` lands in the batcher as a unit and
//! hits the batched backend deliberately), each matrix keeps **two lanes**
//! ([`Lane::Interactive`] dispatches before [`Lane::Batch`]), and requests
//! may carry an absolute deadline that tightens the flush timer — an
//! expired request is surfaced by `ready`/`take` so the service can reply
//! `DeadlineExceeded` instead of solving late.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Scheduling priority of a request. Interactive requests dispatch before
/// batch requests whenever both lanes hold work for the same flush.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Lane {
    /// latency-sensitive: dispatched first
    Interactive,
    /// throughput work: fills whatever batch capacity remains
    #[default]
    Batch,
}

const LANES: usize = 2;

fn lane_index(lane: Lane) -> usize {
    match lane {
        Lane::Interactive => 0,
        Lane::Batch => 1,
    }
}

/// One queued solve request: a block of right-hand sides submitted
/// together (a single `solve` is a block of one).
pub struct Pending<T> {
    /// the block's right-hand sides; never split across batches
    pub rhs: Vec<Vec<f64>>,
    pub token: T,
    pub enqueued: Instant,
    pub lane: Lane,
    /// absolute drop-dead time; the batcher flushes the request *by* this
    /// instant so the service can reject it if it is already late
    pub deadline: Option<Instant>,
}

/// Earliest-deadline-first dispatch key: deadline-carrying requests sort
/// first (soonest deadline wins), then admission order — so a queue with
/// no deadlines anywhere degenerates to plain FIFO. The trailing
/// admission sequence number makes every key unique.
type EdfKey = (bool, Option<Instant>, u64);

fn edf_key(deadline: Option<Instant>, seq: u64) -> EdfKey {
    (deadline.is_none(), deadline, seq)
}

/// One lane's queue, ordered by [`EdfKey`]: the first entry is always the
/// next request to dispatch, so `take` pops in O(log n) instead of
/// re-scanning the lane per dispatched request.
type LaneQueue<T> = BTreeMap<EdfKey, Pending<T>>;

pub struct Batcher<T> {
    /// matrix id -> [interactive queue, batch queue]
    queues: BTreeMap<String, [LaneQueue<T>; LANES]>,
    /// running per-lane RHS counts, so admission control and the depth
    /// gauges are O(1) instead of a scan of every queue per request
    lane_rhs: [usize; LANES],
    next_seq: u64,
    pub batch_size: usize,
    pub deadline: Duration,
}

impl<T> Batcher<T> {
    pub fn new(batch_size: usize, deadline: Duration) -> Batcher<T> {
        Batcher {
            queues: BTreeMap::new(),
            lane_rhs: [0; LANES],
            next_seq: 0,
            batch_size: batch_size.max(1),
            deadline,
        }
    }

    pub fn push(
        &mut self,
        matrix_id: &str,
        rhs: Vec<Vec<f64>>,
        lane: Lane,
        deadline: Option<Instant>,
        token: T,
    ) {
        self.lane_rhs[lane_index(lane)] += rhs.len();
        let seq = self.next_seq;
        self.next_seq += 1;
        let lanes = self
            .queues
            .entry(matrix_id.to_string())
            .or_insert_with(|| [BTreeMap::new(), BTreeMap::new()]);
        lanes[lane_index(lane)].insert(
            edf_key(deadline, seq),
            Pending {
                rhs,
                token,
                enqueued: Instant::now(),
                lane,
                deadline,
            },
        );
    }

    /// Total queued right-hand sides across all matrices and lanes (the
    /// quantity `max_pending` admission control caps).
    pub fn pending(&self) -> usize {
        self.lane_rhs.iter().sum()
    }

    /// Queued right-hand sides in one lane across all matrices.
    pub fn lane_depth(&self, lane: Lane) -> usize {
        self.lane_rhs[lane_index(lane)]
    }

    /// Queued right-hand sides for one matrix across both lanes (the
    /// quantity a per-matrix `max_pending` override caps). O(queue
    /// length) for that matrix only.
    pub fn matrix_pending(&self, matrix_id: &str) -> usize {
        self.queues
            .get(matrix_id)
            .map(|lanes| {
                lanes
                    .iter()
                    .flat_map(LaneQueue::values)
                    .map(|p| p.rhs.len())
                    .sum()
            })
            .unwrap_or(0)
    }

    /// The instant a request must be flushed by: its batching deadline,
    /// tightened by the request's own deadline when that is sooner.
    ///
    /// A deadline-capped request is dispatched one batching deadline
    /// *early*: flushing exactly at the request deadline would always
    /// arrive at dispatch already expired. A deadline too tight to wait
    /// at all (including one already expired) flushes immediately — the
    /// dispatch-time check then serves it just in time or rejects it.
    fn flush_by(&self, p: &Pending<T>) -> Instant {
        let batch_due = p.enqueued + self.deadline;
        match p.deadline {
            Some(d) => {
                let early = d
                    .checked_sub(self.deadline)
                    .map_or(p.enqueued, |e| e.max(p.enqueued));
                batch_due.min(early)
            }
            None => batch_due,
        }
    }

    /// Matrices whose queue is ready: full batch (counted in right-hand
    /// sides), or some request's flush-by instant has passed. `force`
    /// flushes everything non-empty. Matrices with interactive work are
    /// listed first (interactive-first dispatch across matrices too).
    pub fn ready(&self, force: bool) -> Vec<String> {
        let now = Instant::now();
        let mut ids: Vec<(bool, String)> = Vec::new();
        for (id, lanes) in &self.queues {
            let total: usize = lanes
                .iter()
                .flat_map(LaneQueue::values)
                .map(|p| p.rhs.len())
                .sum();
            if total == 0 {
                continue;
            }
            let due = force
                || total >= self.batch_size
                || lanes
                    .iter()
                    .flat_map(LaneQueue::values)
                    .any(|p| now >= self.flush_by(p));
            if due {
                ids.push((lanes[0].is_empty(), id.clone()));
            }
        }
        // Stable sort: interactive-bearing matrices first, BTreeMap
        // (name) order within each class.
        ids.sort_by_key(|(no_interactive, _)| *no_interactive);
        ids.into_iter().map(|(_, id)| id).collect()
    }

    /// Take up to `batch_size` right-hand sides for a matrix, interactive
    /// lane first, **earliest-deadline-first within a lane** (requests
    /// without a deadline dispatch after deadline-carrying ones, in
    /// admission order — all-FIFO when nothing carries a deadline).
    /// Blocks are never split: a block larger than the batch size is
    /// returned alone, and when the most urgent block would overflow the
    /// batch it is not skipped for a less urgent one — it anchors the
    /// next batch instead.
    pub fn take(&mut self, matrix_id: &str) -> Vec<Pending<T>> {
        let Some(lanes) = self.queues.get_mut(matrix_id) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut taken = 0usize;
        'lanes: for (lane, q) in lanes.iter_mut().enumerate() {
            loop {
                // The lane queue is EDF-ordered: its first entry is the
                // most urgent queued request.
                let k = match q.first_key_value() {
                    Some((_, p)) => p.rhs.len(),
                    None => break,
                };
                if !out.is_empty() && taken + k > self.batch_size {
                    break 'lanes;
                }
                let (_, p) = q.pop_first().expect("first_key_value was Some");
                self.lane_rhs[lane] -= k;
                taken += k;
                out.push(p);
                if taken >= self.batch_size {
                    break 'lanes;
                }
            }
        }
        out
    }

    /// Remove the *oldest-admitted* queued request for one matrix across
    /// both lanes (minimum admission sequence number, regardless of lane
    /// or deadline), returning it so the caller can reply. This is the
    /// `drop-oldest` load-shedding primitive: when a per-matrix cap
    /// trips, the service evicts stale queued work to admit fresh work
    /// instead of bouncing the newcomer.
    pub fn pop_oldest(&mut self, matrix_id: &str) -> Option<Pending<T>> {
        let lanes = self.queues.get_mut(matrix_id)?;
        let (lane, key) = lanes
            .iter()
            .enumerate()
            .flat_map(|(lane, q)| q.keys().map(move |k| (lane, *k)))
            .min_by_key(|&(_, k)| k.2)?;
        let p = lanes[lane].remove(&key).expect("min key present");
        self.lane_rhs[lane] -= p.rhs.len();
        Some(p)
    }

    /// Remove every queued request whose token the predicate marks dead
    /// (cancelled tickets), returning them so the caller can reply and
    /// account for them. Queue capacity (`pending`/`lane_depth`) is
    /// reclaimed immediately — this is what the service's cancel wakeup
    /// runs, instead of waiting for the next flush to weed the entries.
    pub fn sweep<F: Fn(&T) -> bool>(&mut self, dead: F) -> Vec<Pending<T>> {
        let mut out = Vec::new();
        for lanes in self.queues.values_mut() {
            for (lane, q) in lanes.iter_mut().enumerate() {
                let keys: Vec<EdfKey> = q
                    .iter()
                    .filter(|(_, p)| dead(&p.token))
                    .map(|(k, _)| *k)
                    .collect();
                for k in keys {
                    let p = q.remove(&k).expect("swept key present");
                    self.lane_rhs[lane] -= p.rhs.len();
                    out.push(p);
                }
            }
        }
        out
    }

    /// Time until the next pending request hits its flush-by instant (the
    /// service loop uses this for recv_timeout). Zero when something is
    /// already overdue.
    pub fn next_deadline(&self) -> Option<Duration> {
        let now = Instant::now();
        self.queues
            .values()
            .flat_map(|lanes| lanes.iter().flat_map(LaneQueue::values))
            .map(|p| self.flush_by(p).saturating_duration_since(now))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(b: f64) -> Vec<Vec<f64>> {
        vec![vec![b]]
    }

    #[test]
    fn batches_fill_and_flush() {
        let mut b: Batcher<usize> = Batcher::new(3, Duration::from_secs(60));
        b.push("m", one(1.0), Lane::Batch, None, 0);
        b.push("m", one(2.0), Lane::Batch, None, 1);
        assert!(b.ready(false).is_empty()); // not full, not expired
        b.push("m", one(3.0), Lane::Batch, None, 2);
        assert_eq!(b.ready(false), vec!["m".to_string()]);
        let taken = b.take("m");
        assert_eq!(taken.len(), 3);
        assert_eq!(taken[0].token, 0); // FIFO
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_millis(1));
        b.push("m", one(1.0), Lane::Batch, None, 0);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.ready(false), vec!["m".to_string()]);
        assert_eq!(b.take("m").len(), 1);
    }

    #[test]
    fn force_flush() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        b.push("a", one(1.0), Lane::Batch, None, 0);
        b.push("z", one(2.0), Lane::Batch, None, 1);
        let mut r = b.ready(true);
        r.sort();
        assert_eq!(r, vec!["a".to_string(), "z".to_string()]);
    }

    #[test]
    fn take_caps_at_batch_size() {
        let mut b: Batcher<usize> = Batcher::new(2, Duration::from_secs(60));
        for i in 0..5 {
            b.push("m", one(i as f64), Lane::Batch, None, i);
        }
        assert_eq!(b.take("m").len(), 2);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.take("missing").len(), 0);
    }

    #[test]
    fn force_flush_drains_multi_batch_queues() {
        let mut b: Batcher<usize> = Batcher::new(3, Duration::from_secs(60));
        for i in 0..7 {
            b.push("m", one(i as f64), Lane::Batch, None, i);
        }
        assert_eq!(b.ready(true), vec!["m".to_string()]);
        // Draining a deep queue takes several batches, each capped.
        let sizes: Vec<usize> = std::iter::from_fn(|| {
            let t = b.take("m");
            (!t.is_empty()).then_some(t.len())
        })
        .collect();
        assert_eq!(sizes, vec![3, 3, 1]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn interactive_lane_dispatches_first() {
        let mut b: Batcher<usize> = Batcher::new(3, Duration::from_secs(60));
        b.push("m", one(1.0), Lane::Batch, None, 0);
        b.push("m", one(2.0), Lane::Batch, None, 1);
        // Submitted last, dispatched first.
        b.push("m", one(3.0), Lane::Interactive, None, 2);
        assert_eq!(b.lane_depth(Lane::Interactive), 1);
        assert_eq!(b.lane_depth(Lane::Batch), 2);
        let taken = b.take("m");
        assert_eq!(taken.len(), 3);
        assert_eq!(taken[0].token, 2);
        assert_eq!(taken[0].lane, Lane::Interactive);
        assert_eq!(taken[1].token, 0);
    }

    #[test]
    fn interactive_matrices_flush_before_batch_matrices() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        b.push("aaa", one(1.0), Lane::Batch, None, 0);
        b.push("zzz", one(2.0), Lane::Interactive, None, 1);
        assert_eq!(
            b.ready(true),
            vec!["zzz".to_string(), "aaa".to_string()]
        );
    }

    #[test]
    fn request_deadline_tightens_flush_across_matrices() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_millis(100));
        b.push("slow", one(1.0), Lane::Batch, None, 0);
        b.push(
            "urgent",
            one(2.0),
            Lane::Batch,
            Some(Instant::now() + Duration::from_millis(1)),
            1,
        );
        // The tight per-request deadline, not the 100ms batch deadline,
        // drives the wakeup...
        assert!(b.next_deadline().unwrap() <= Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        // ...and only the urgent matrix is due once it passes.
        assert_eq!(b.ready(false), vec!["urgent".to_string()]);
    }

    #[test]
    fn edf_dispatches_most_urgent_first_within_a_lane() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        let now = Instant::now();
        b.push("m", one(1.0), Lane::Batch, None, 0);
        b.push("m", one(2.0), Lane::Batch, Some(now + Duration::from_millis(500)), 1);
        b.push("m", one(3.0), Lane::Batch, Some(now + Duration::from_millis(5)), 2);
        b.push("m", one(4.0), Lane::Batch, Some(now + Duration::from_millis(100)), 3);
        let taken = b.take("m");
        let order: Vec<usize> = taken.iter().map(|p| p.token).collect();
        // Deadlines ascending first, then the deadline-free request.
        assert_eq!(order, vec![2, 3, 1, 0]);
    }

    #[test]
    fn mixed_deadline_requests_miss_less_under_edf() {
        // One-RHS batches force strictly sequential dispatch. Under FIFO
        // the tight-deadline request (submitted last) would be served
        // third and miss; EDF serves it first. Modelled with a fixed
        // per-batch service time, the EDF take order meets every deadline
        // the FIFO order cannot.
        let service_time = Duration::from_millis(10);
        let mut b: Batcher<usize> = Batcher::new(1, Duration::from_secs(60));
        let now = Instant::now();
        let deadlines = [
            Some(now + 10 * service_time), // relaxed, submitted first
            Some(now + 8 * service_time),  // relaxed
            Some(now + service_time),      // tight, submitted last
        ];
        for (i, d) in deadlines.iter().enumerate() {
            b.push("m", one(i as f64), Lane::Batch, *d, i);
        }
        let mut order = Vec::new();
        loop {
            let t = b.take("m");
            if t.is_empty() {
                break;
            }
            order.extend(t.iter().map(|p| p.token));
        }
        assert_eq!(order, vec![2, 1, 0], "EDF order");
        // Every request is dispatched before its own deadline under EDF:
        // request at dispatch position k completes at (k+1)*service_time.
        for (pos, &tok) in order.iter().enumerate() {
            let finish = now + (pos as u32 + 1) * service_time;
            assert!(
                finish <= deadlines[tok].unwrap(),
                "request {tok} misses at position {pos}"
            );
        }
        // FIFO (0, 1, 2) would put the tight request at position 3:
        // 3 * service_time > its 1 * service_time budget — a certain miss.
        assert!(now + 3 * service_time > deadlines[2].unwrap());
    }

    #[test]
    fn edf_never_starves_the_most_urgent_oversize_block() {
        let mut b: Batcher<usize> = Batcher::new(4, Duration::from_secs(60));
        let now = Instant::now();
        b.push("m", vec![vec![1.0]; 2], Lane::Batch, Some(now + Duration::from_millis(50)), 0);
        // Most urgent, but 3 RHS would overflow the batch after the first
        // block: it must anchor the NEXT batch, not be skipped for the
        // later, less urgent small block.
        b.push("m", vec![vec![2.0]; 3], Lane::Batch, Some(now + Duration::from_millis(1)), 1);
        b.push("m", one(3.0), Lane::Batch, None, 2);
        let t1 = b.take("m");
        assert_eq!(t1.iter().map(|p| p.token).collect::<Vec<_>>(), vec![1]);
        let t2 = b.take("m");
        assert_eq!(t2.iter().map(|p| p.token).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn blocks_are_never_split() {
        let mut b: Batcher<usize> = Batcher::new(4, Duration::from_secs(60));
        b.push("m", vec![vec![1.0]; 3], Lane::Batch, None, 0);
        b.push("m", vec![vec![2.0]; 2], Lane::Batch, None, 1);
        assert_eq!(b.pending(), 5);
        assert_eq!(b.ready(false), vec!["m".to_string()]); // 5 >= 4
        // The 2-RHS block would overflow the 4-RHS batch: it waits.
        let t1 = b.take("m");
        assert_eq!(t1.len(), 1);
        assert_eq!(t1[0].rhs.len(), 3);
        let t2 = b.take("m");
        assert_eq!(t2.len(), 1);
        assert_eq!(t2[0].rhs.len(), 2);
        // An oversized block is returned alone rather than split.
        b.push("m", vec![vec![3.0]; 9], Lane::Batch, None, 2);
        let t3 = b.take("m");
        assert_eq!(t3.len(), 1);
        assert_eq!(t3[0].rhs.len(), 9);
    }

    #[test]
    fn sweep_reclaims_capacity_immediately() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        b.push("m", one(1.0), Lane::Batch, None, 0);
        b.push("m", vec![vec![2.0]; 3], Lane::Interactive, None, 1);
        b.push("z", one(3.0), Lane::Batch, None, 2);
        assert_eq!(b.pending(), 5);
        // Tokens 1 and 2 are "cancelled": swept out of every queue/lane.
        let removed = b.sweep(|&t| t != 0);
        let mut tokens: Vec<usize> = removed.iter().map(|p| p.token).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, vec![1, 2]);
        assert_eq!(b.pending(), 1, "capacity reclaimed without a flush");
        assert_eq!(b.lane_depth(Lane::Interactive), 0);
        assert_eq!(b.lane_depth(Lane::Batch), 1);
        // The surviving request still dispatches normally.
        let taken = b.take("m");
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].token, 0);
        // An all-alive sweep is a no-op.
        assert!(b.sweep(|_| false).is_empty());
    }

    #[test]
    fn pop_oldest_removes_earliest_admission_across_lanes() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        let now = Instant::now();
        // Oldest admission is a *batch*-lane request with a relaxed
        // deadline; EDF would dispatch token 2 first, but shedding is by
        // admission age, not urgency.
        b.push("m", one(1.0), Lane::Batch, Some(now + Duration::from_secs(5)), 0);
        b.push("m", vec![vec![2.0]; 2], Lane::Interactive, None, 1);
        b.push("m", one(3.0), Lane::Batch, Some(now + Duration::from_millis(1)), 2);
        b.push("z", one(4.0), Lane::Batch, None, 3);
        let shed = b.pop_oldest("m").expect("non-empty queue");
        assert_eq!(shed.token, 0);
        assert_eq!(b.matrix_pending("m"), 3);
        let shed = b.pop_oldest("m").expect("non-empty queue");
        assert_eq!(shed.token, 1, "interactive lane sheds too");
        assert_eq!(b.lane_depth(Lane::Interactive), 0);
        assert_eq!(b.matrix_pending("m"), 1);
        // Other matrices are untouched; an empty id yields None.
        assert_eq!(b.matrix_pending("z"), 1);
        assert!(b.pop_oldest("missing").is_none());
        // The survivor still dispatches.
        assert_eq!(b.take("m")[0].token, 2);
    }

    #[test]
    fn matrix_pending_counts_both_lanes_per_id() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_secs(60));
        assert_eq!(b.matrix_pending("m"), 0);
        b.push("m", vec![vec![1.0]; 2], Lane::Batch, None, 0);
        b.push("m", one(2.0), Lane::Interactive, None, 1);
        b.push("z", one(3.0), Lane::Batch, None, 2);
        assert_eq!(b.matrix_pending("m"), 3);
        assert_eq!(b.matrix_pending("z"), 1);
        assert_eq!(b.pending(), 4);
        b.take("m");
        assert_eq!(b.matrix_pending("m"), 0);
        assert_eq!(b.matrix_pending("z"), 1);
    }

    #[test]
    fn next_deadline_monotone() {
        let mut b: Batcher<usize> = Batcher::new(8, Duration::from_millis(100));
        assert!(b.next_deadline().is_none());
        b.push("m", one(1.0), Lane::Batch, None, 0);
        let d = b.next_deadline().unwrap();
        assert!(d <= Duration::from_millis(100));
    }
}
