//! Preprocessing pipeline: matrix -> levels -> solve plan -> transformed
//! system -> execution backend -> (optionally) padded XLA system, cached
//! per matrix id.
//!
//! When the configured (or per-register) plan is `auto`, the pipeline
//! consults its persistent [`Tuner`]: the matrix fingerprint is looked up
//! in the plan cache, and only unknown structures pay for the cost-model
//! shortlist + race over the rewrite × exec cross product.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::config::Config;
use crate::error::Error;
use crate::runtime::backend::StagedSystem;
use crate::runtime::{PaddedSystem, Registry, XlaSolver};
use crate::sched::SchedOptions;
use crate::solver::dispatch::ExecSolver;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{Exec, PlanSpec, ResolvedPlan, SolvePlan, TransformResult};
use crate::tuner::{PlanSource, Tuner, TunerOptions};

/// Which backend serves a prepared matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// rust execution backend over the transformed system (whichever the
    /// plan's exec axis picked)
    Native,
    /// AOT XLA executable (artifact shape fitted)
    Xla,
}

/// How the tuner decided a prepared matrix's plan (None when the plan was
/// fixed by name).
#[derive(Debug, Clone)]
pub struct TunedInfo {
    /// plan the tuner picked, in `SolvePlan::parse` syntax
    pub plan: String,
    /// whether the fingerprint plan cache answered the decision
    pub cache_hit: bool,
    /// hex sparsity fingerprint
    pub fingerprint: String,
}

/// A matrix after preprocessing: everything the request path needs.
pub struct Prepared {
    pub id: String,
    pub m: Arc<Csr>,
    pub t: Arc<TransformResult>,
    /// the execution backend the plan's exec axis calls for: level-set
    /// executor, coarsened schedule, sync-free, or reordered (see
    /// [`crate::solver::ExecSolver`])
    pub native: ExecSolver,
    pub padded: Option<Arc<PaddedSystem>>,
    /// system arrays pre-uploaded to the PJRT device (§Perf: avoids
    /// re-transferring megabytes of structure per request)
    pub staged: Option<StagedSystem>,
    pub backend: Backend,
    /// the plan that produced `t` and `native` (the tuner's pick under
    /// `auto`)
    pub plan: SolvePlan,
    /// plan label for logs/metrics (source text for named plans, the
    /// canonical winner name under `auto`)
    pub plan_name: String,
    /// tuner decision details when the plan was `auto`
    pub tuned: Option<TunedInfo>,
    /// preprocessing wall-clock (the offline cost the paper discusses)
    pub prepare_time: std::time::Duration,
}

/// The config's scheduling knobs as the `SchedOptions` fallback every
/// schedule-building site shares (tuner race and serving executor alike).
fn sched_fallback(cfg: &Config) -> SchedOptions {
    SchedOptions {
        block_target: Some(cfg.sched_block_target),
        stale_window: Some(cfg.sched_stale_window),
    }
}

pub struct Pipeline {
    pub cfg: Config,
    pool: Arc<Pool>,
    pub registry: Option<Arc<Registry>>,
    cache: BTreeMap<String, Arc<Prepared>>,
    /// persistent plan autotuner consulted for `auto` registrations
    pub tuner: Tuner,
}

impl Pipeline {
    pub fn new(cfg: Config) -> Pipeline {
        let pool = Arc::new(Pool::new(cfg.workers));
        let tuner = Tuner::new(TunerOptions {
            top_k: cfg.tuner_top_k.max(1),
            race_solves: cfg.tuner_race_solves.max(1),
            workers: cfg.workers.max(1),
            cache_path: if cfg.tuner_cache.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.tuner_cache))
            },
            cache_ttl_secs: cfg.tuner_cache_ttl,
            // Race scheduled candidates with the same knobs serving will
            // build with — a plan decided at one block target must not be
            // served at another.
            sched: sched_fallback(&cfg),
            // Race on the serving pool: a cache miss must not pay (or be
            // skewed by) spawning a throwaway thread pool.
            pool: Some(Arc::clone(&pool)),
            ..Default::default()
        });
        // The registry is optional: without artifacts the coordinator
        // serves everything natively.
        let registry = if cfg.use_xla {
            match Registry::load(Path::new(&cfg.artifacts_dir)) {
                Ok(r) => Some(Arc::new(r)),
                Err(e) => {
                    eprintln!(
                        "warning: XLA registry unavailable ({e}); native backend only"
                    );
                    None
                }
            }
        } else {
            None
        };
        Pipeline {
            cfg,
            pool,
            registry,
            cache: BTreeMap::new(),
            tuner,
        }
    }

    pub fn xla_solver(&self) -> Option<XlaSolver> {
        self.registry.as_ref().map(|r| XlaSolver::new(Arc::clone(r)))
    }

    /// Preprocess and cache a matrix under `id`. The plan arrives as an
    /// already-parsed [`PlanSpec`]: `Default` defers to the configured
    /// service-wide plan, `Auto` to the tuner — no plan-name string ever
    /// reaches this layer.
    pub fn prepare(
        &mut self,
        id: &str,
        m: Csr,
        spec: &PlanSpec,
    ) -> Result<Arc<Prepared>, Error> {
        if let Some(p) = self.cache.get(id) {
            return Ok(Arc::clone(p));
        }
        let start = Instant::now();
        m.validate_lower_triangular()?;
        // Arc the matrix up front: the tuner's race lanes and the solver
        // share it by reference count instead of copying.
        let m = Arc::new(m);
        let (plan_name, plan, t, tuned) = match spec.resolve(&self.cfg.plan) {
            ResolvedPlan::Auto => {
                let tp = self.tuner.choose_arc(&m)?;
                let info = TunedInfo {
                    plan: tp.plan_name.clone(),
                    cache_hit: tp.source == PlanSource::CacheHit,
                    fingerprint: tp.fingerprint.to_hex(),
                };
                (tp.plan_name, tp.plan, tp.transform, Some(info))
            }
            ResolvedPlan::Fixed(name, plan) => {
                let t = plan.apply(&m);
                (name, plan, t, None)
            }
        };
        t.validate(&m).map_err(Error::Invalid)?;

        let t = Arc::new(t);
        // Fit an XLA artifact if the registry is present, and stage the
        // system arrays on the device. Only level-set execution is
        // XLA-eligible: the padded level solve would silently discard the
        // schedule / sync-free counters / reordering other exec axes were
        // chosen for. The rewrite axis composes either way.
        let xla_eligible = matches!(plan.exec, Exec::Levelset);
        let mut backend = Backend::Native;
        let mut padded = None;
        let mut staged = None;
        if let (Some(reg), true) = (&self.registry, xla_eligible) {
            let req = PaddedSystem::requirements(&m, &t);
            if let Some(meta) = reg.best_fit("solve", &req) {
                let p = PaddedSystem::build(&m, &t, meta.pad_shape())?;
                let solver = XlaSolver::new(Arc::clone(reg));
                staged = Some(solver.stage(&p)?);
                padded = Some(Arc::new(p));
                backend = Backend::Xla;
            }
        }
        // Scheduling knobs the plan left unset come from the config.
        let native = ExecSolver::build(
            Arc::clone(&m),
            Arc::clone(&t),
            &plan.exec,
            Arc::clone(&self.pool),
            sched_fallback(&self.cfg),
        )?;
        let prepared = Arc::new(Prepared {
            id: id.to_string(),
            m,
            t,
            native,
            padded,
            staged,
            backend,
            plan,
            plan_name,
            tuned,
            prepare_time: start.elapsed(),
        });
        self.cache.insert(id.to_string(), Arc::clone(&prepared));
        Ok(prepared)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Prepared>> {
        self.cache.get(id).cloned()
    }

    pub fn evict(&mut self, id: &str) -> bool {
        self.cache.remove(id).is_some()
    }

    pub fn cached_ids(&self) -> Vec<String> {
        self.cache.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    fn cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            ..Default::default()
        }
    }

    fn spec(s: &str) -> PlanSpec {
        PlanSpec::parse(s).unwrap()
    }

    #[test]
    fn prepare_caches_and_solves() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("lung2", m, &PlanSpec::Default).unwrap();
        assert_eq!(p.backend, Backend::Native);
        assert!(p.t.stats.levels_after < p.t.stats.levels_before);
        // Cache hit returns the same Arc.
        let p2 = pl.prepare(
            "lung2",
            generate::tridiagonal(5, &Default::default()),
            &PlanSpec::Default,
        );
        assert!(Arc::ptr_eq(&p, &p2.unwrap()));
        // And it solves.
        let b = vec![1.0; n];
        let x = p.native.solve(&b);
        assert!(p.m.residual_inf(&x, &b) < 1e-9);
    }

    #[test]
    fn auto_plan_consults_tuner_and_plan_cache() {
        let mut pl = Pipeline::new(cfg());
        // The tuner races on the pipeline's own worker pool instead of
        // spawning a throwaway one per cache miss.
        assert!(pl.tuner.opts.pool.is_some());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let p1 = pl.prepare("a", m.clone(), &spec("auto")).unwrap();
        let t1 = p1.tuned.as_ref().expect("auto decision recorded");
        assert!(!t1.cache_hit);
        assert_eq!(t1.plan, p1.plan_name);
        assert_eq!(t1.fingerprint.len(), 16);
        // The tuned decision is a full two-axis plan.
        assert_eq!(SolvePlan::parse(&t1.plan).unwrap(), p1.plan);
        // Same structure under a new id: the fingerprint cache answers.
        let p2 = pl.prepare("b", m.clone(), &spec("auto")).unwrap();
        let t2 = p2.tuned.as_ref().unwrap();
        assert!(t2.cache_hit);
        assert_eq!(t2.plan, t1.plan);
        assert_eq!(p2.t.stats.levels_after, p1.t.stats.levels_after);
        // And the plan solves correctly.
        let b = vec![1.0; n];
        let x = p2.native.solve(&b);
        assert!(p2.m.residual_inf(&x, &b) < 1e-9);
        // Fixed-name registrations carry no tuner decision.
        let p3 = pl.prepare("c", m, &spec("none")).unwrap();
        assert!(p3.tuned.is_none());
        assert_eq!(p3.plan_name, "none");
    }

    #[test]
    fn plan_override() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::tridiagonal(50, &Default::default());
        let p = pl.prepare("tri", m, &spec("manual:5")).unwrap();
        assert_eq!(p.t.num_levels(), 10);
    }

    #[test]
    fn scheduled_plan_builds_the_scheduled_backend() {
        let mut pl = Pipeline::new(Config {
            sched_block_target: 32,
            sched_stale_window: 2,
            ..cfg()
        });
        let m = generate::tridiagonal(120, &Default::default());
        let p = pl.prepare("tri", m, &spec("scheduled")).unwrap();
        assert_eq!(p.backend, Backend::Native);
        assert_eq!(p.native.mode(), "scheduled");
        let sched = p.native.scheduled().expect("scheduled solver");
        // A pure chain collapses into one block with no cross-worker
        // edges — the schedule-level win over 119 barriers.
        assert_eq!(sched.stats().num_blocks, 1);
        assert_eq!(sched.stats().cut_edges, 0);
        assert_eq!(sched.stats().levelset_barriers, 119);
        let b = vec![1.0; 120];
        let x = p.native.solve(&b);
        assert!(p.m.residual_inf(&x, &b) < 1e-10);
        // No rewriting happened: the legacy name pairs with `none`.
        assert_eq!(p.t.stats.rows_rewritten, 0);
        assert_eq!(p.plan_name, "scheduled");
    }

    #[test]
    fn composed_plan_prepares_rewrite_and_backend() {
        // The redesign's point: one registration carries BOTH axes.
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("c", m, &spec("avgcost+scheduled")).unwrap();
        assert_eq!(p.native.mode(), "scheduled");
        assert!(p.t.stats.rows_rewritten > 0, "rewrite axis ran");
        assert!(p.t.num_levels() < p.t.stats.levels_before);
        // The schedule was built over the *transformed* levels.
        let sched = p.native.scheduled().unwrap();
        assert_eq!(sched.t.num_levels(), p.t.num_levels());
        let b = vec![1.0; n];
        let x = p.native.solve(&b);
        assert!(p.m.residual_inf(&x, &b) < 1e-9);
    }

    #[test]
    fn execution_plans_prepare_and_solve() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        for (id, s, mode) in [
            ("sf", "syncfree", "syncfree"),
            ("ro", "reorder", "reordered"),
            ("sc", "scheduled:64:1", "scheduled"),
            ("c1", "avgcost+syncfree", "syncfree"),
            ("c2", "guarded:5+reorder", "reordered"),
        ] {
            let p = pl.prepare(id, m.clone(), &spec(s)).unwrap();
            assert_eq!(p.native.mode(), mode, "{s}");
            let b = vec![1.0; n];
            let x = p.native.solve(&b);
            assert!(p.m.residual_inf(&x, &b) < 1e-9, "{s}");
        }
    }

    #[test]
    fn invalid_matrix_rejected() {
        let mut pl = Pipeline::new(cfg());
        let bad = Csr::new(2, 2, vec![0, 1, 3], vec![0, 0, 1], vec![0.0, 1.0, 1.0]).unwrap();
        assert!(pl.prepare("bad", bad, &PlanSpec::Default).is_err());
    }

    #[test]
    fn evict_and_ids() {
        let mut pl = Pipeline::new(cfg());
        pl.prepare(
            "a",
            generate::tridiagonal(10, &Default::default()),
            &PlanSpec::Default,
        )
        .unwrap();
        assert_eq!(pl.cached_ids(), vec!["a"]);
        assert!(pl.evict("a"));
        assert!(!pl.evict("a"));
    }
}
