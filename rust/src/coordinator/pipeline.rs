//! Preprocessing pipeline: matrix -> levels -> strategy -> transformed
//! system -> (optionally) padded XLA system, cached per matrix id.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::config::Config;
use crate::error::Error;
use crate::runtime::backend::StagedSystem;
use crate::runtime::{PaddedSystem, Registry, XlaSolver};
use crate::solver::executor::TransformedSolver;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{Strategy, TransformResult};

/// Which backend serves a prepared matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// rust level-set executor over the transformed system
    Native,
    /// AOT XLA executable (artifact shape fitted)
    Xla,
}

/// A matrix after preprocessing: everything the request path needs.
pub struct Prepared {
    pub id: String,
    pub m: Arc<Csr>,
    pub t: Arc<TransformResult>,
    pub native: TransformedSolver,
    pub padded: Option<Arc<PaddedSystem>>,
    /// system arrays pre-uploaded to the PJRT device (§Perf: avoids
    /// re-transferring megabytes of structure per request)
    pub staged: Option<StagedSystem>,
    pub backend: Backend,
    /// preprocessing wall-clock (the offline cost the paper discusses)
    pub prepare_time: std::time::Duration,
}

pub struct Pipeline {
    pub cfg: Config,
    pool: Arc<Pool>,
    pub registry: Option<Arc<Registry>>,
    cache: BTreeMap<String, Arc<Prepared>>,
}

impl Pipeline {
    pub fn new(cfg: Config) -> Pipeline {
        let pool = Arc::new(Pool::new(cfg.workers));
        // The registry is optional: without artifacts the coordinator
        // serves everything natively.
        let registry = if cfg.use_xla {
            match Registry::load(Path::new(&cfg.artifacts_dir)) {
                Ok(r) => Some(Arc::new(r)),
                Err(e) => {
                    eprintln!(
                        "warning: XLA registry unavailable ({e}); native backend only"
                    );
                    None
                }
            }
        } else {
            None
        };
        Pipeline {
            cfg,
            pool,
            registry,
            cache: BTreeMap::new(),
        }
    }

    pub fn xla_solver(&self) -> Option<XlaSolver> {
        self.registry.as_ref().map(|r| XlaSolver::new(Arc::clone(r)))
    }

    /// Preprocess and cache a matrix under `id` using the configured
    /// strategy (or `strategy_override`).
    pub fn prepare(
        &mut self,
        id: &str,
        m: Csr,
        strategy_override: Option<&str>,
    ) -> Result<Arc<Prepared>, Error> {
        if let Some(p) = self.cache.get(id) {
            return Ok(Arc::clone(p));
        }
        let start = Instant::now();
        m.validate_lower_triangular()?;
        let strat_name = strategy_override.unwrap_or(&self.cfg.strategy);
        let strategy = Strategy::parse(strat_name).map_err(Error::Invalid)?;
        let t = strategy.apply(&m);
        t.validate(&m).map_err(Error::Invalid)?;

        let m = Arc::new(m);
        let t = Arc::new(t);
        // Fit an XLA artifact if the registry is present, and stage the
        // system arrays on the device.
        let mut backend = Backend::Native;
        let mut padded = None;
        let mut staged = None;
        if let Some(reg) = &self.registry {
            let req = PaddedSystem::requirements(&m, &t);
            if let Some(meta) = reg.best_fit("solve", &req) {
                let p = PaddedSystem::build(&m, &t, meta.pad_shape())?;
                let solver = XlaSolver::new(Arc::clone(reg));
                staged = Some(solver.stage(&p)?);
                padded = Some(Arc::new(p));
                backend = Backend::Xla;
            }
        }
        let native = TransformedSolver::new(Arc::clone(&m), Arc::clone(&t), Arc::clone(&self.pool));
        let prepared = Arc::new(Prepared {
            id: id.to_string(),
            m,
            t,
            native,
            padded,
            staged,
            backend,
            prepare_time: start.elapsed(),
        });
        self.cache.insert(id.to_string(), Arc::clone(&prepared));
        Ok(prepared)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Prepared>> {
        self.cache.get(id).cloned()
    }

    pub fn evict(&mut self, id: &str) -> bool {
        self.cache.remove(id).is_some()
    }

    pub fn cached_ids(&self) -> Vec<String> {
        self.cache.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    fn cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            ..Default::default()
        }
    }

    #[test]
    fn prepare_caches_and_solves() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("lung2", m, None).unwrap();
        assert_eq!(p.backend, Backend::Native);
        assert!(p.t.stats.levels_after < p.t.stats.levels_before);
        // Cache hit returns the same Arc.
        let p2 = pl.prepare("lung2", generate::tridiagonal(5, &Default::default()), None);
        assert!(Arc::ptr_eq(&p, &p2.unwrap()));
        // And it solves.
        let b = vec![1.0; n];
        let x = p.native.solve(&b);
        assert!(p.m.residual_inf(&x, &b) < 1e-9);
    }

    #[test]
    fn strategy_override() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::tridiagonal(50, &Default::default());
        let p = pl.prepare("tri", m, Some("manual:5")).unwrap();
        assert_eq!(p.t.num_levels(), 10);
    }

    #[test]
    fn invalid_matrix_rejected() {
        let mut pl = Pipeline::new(cfg());
        let bad = Csr::new(2, 2, vec![0, 1, 3], vec![0, 0, 1], vec![0.0, 1.0, 1.0]).unwrap();
        assert!(pl.prepare("bad", bad, None).is_err());
    }

    #[test]
    fn evict_and_ids() {
        let mut pl = Pipeline::new(cfg());
        pl.prepare("a", generate::tridiagonal(10, &Default::default()), None)
            .unwrap();
        assert_eq!(pl.cached_ids(), vec!["a"]);
        assert!(pl.evict("a"));
        assert!(!pl.evict("a"));
    }
}
