//! Preprocessing pipeline: matrix -> [`Analysis`] (levels -> solve plan
//! -> transformed system -> execution backend) -> (optionally) padded XLA
//! system, cached per matrix id.
//!
//! Since the analyze/execute split, the pipeline *consumes analyses*
//! instead of re-deriving transforms: the expensive structural work lives
//! in [`crate::analysis`], the tuner's race donates its winning lane's
//! already-built artifacts, a same-pattern value update
//! ([`Pipeline::update_values`]) replays only the numerics, and — when
//! the `analysis_cache` config key names a directory — persisted analyses
//! let a known structure skip rewrite analysis, coarsening and ETF
//! placement entirely, even across restarts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::analysis::{Analysis, AnalysisCache, BuildCounters};
use crate::config::Config;
use crate::error::Error;
use crate::runtime::backend::StagedSystem;
use crate::runtime::{PaddedSystem, Registry, XlaSolver};
use crate::sched::SchedOptions;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::{Exec, PlanSpec, ResolvedPlan, SolvePlan};
use crate::tuner::{Fingerprint, PlanSource, Tuner, TunerOptions};

/// Which backend serves a prepared matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// rust execution backend over the transformed system (whichever the
    /// plan's exec axis picked)
    Native,
    /// AOT XLA executable (artifact shape fitted)
    Xla,
}

/// Where a preparation's structural work came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnalysisSource {
    /// full analysis ran in this process (rewrite and, for scheduled
    /// plans, coarsening + placement)
    Fresh,
    /// restored from the persistent analysis cache: zero rewrite /
    /// coarsening / placement passes, numerics replayed only
    DiskCache,
    /// a same-pattern value refresh of an existing preparation
    Refreshed,
    /// a same-id re-registration returned the memoized preparation
    Memoized,
}

impl AnalysisSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            AnalysisSource::Fresh => "fresh",
            AnalysisSource::DiskCache => "disk-cache",
            AnalysisSource::Refreshed => "refreshed",
            AnalysisSource::Memoized => "memoized",
        }
    }
}

/// How the tuner decided a prepared matrix's plan (None when the plan was
/// fixed by name).
#[derive(Debug, Clone)]
pub struct TunedInfo {
    /// plan the tuner picked, in `SolvePlan::parse` syntax
    pub plan: String,
    /// whether the fingerprint plan cache answered the decision
    pub cache_hit: bool,
    /// hex sparsity fingerprint
    pub fingerprint: String,
}

/// A matrix after preprocessing: everything the request path needs. The
/// structural heart is the shared [`Analysis`]; the pipeline adds the
/// XLA fit on top.
pub struct Prepared {
    pub id: String,
    /// the analysis artifact every registration of this id shares
    pub analysis: Arc<Analysis>,
    pub padded: Option<Arc<PaddedSystem>>,
    /// system arrays pre-uploaded to the PJRT device (§Perf: avoids
    /// re-transferring megabytes of structure per request)
    pub staged: Option<StagedSystem>,
    pub backend: Backend,
    /// tuner decision details when the plan was `auto`
    pub tuned: Option<TunedInfo>,
    /// where the structural work came from
    pub source: AnalysisSource,
    /// preprocessing wall-clock (the offline cost the paper discusses)
    pub prepare_time: std::time::Duration,
}

impl Prepared {
    pub fn m(&self) -> &Arc<Csr> {
        self.analysis.matrix()
    }

    pub fn plan(&self) -> &SolvePlan {
        self.analysis.plan()
    }

    pub fn plan_name(&self) -> &str {
        self.analysis.plan_name()
    }

    /// The native execution backend (always present; the XLA path falls
    /// back to it).
    pub fn native(&self) -> &crate::solver::dispatch::ExecSolver {
        self.analysis.solver()
    }
}

/// The config's scheduling knobs as the `SchedOptions` fallback every
/// schedule-building site shares (tuner race and serving executor alike).
fn sched_fallback(cfg: &Config) -> SchedOptions {
    SchedOptions {
        block_target: Some(cfg.sched_block_target),
        stale_window: Some(cfg.sched_stale_window),
    }
}

pub struct Pipeline {
    pub cfg: Config,
    pool: Arc<Pool>,
    pub registry: Option<Arc<Registry>>,
    cache: BTreeMap<String, Arc<Prepared>>,
    /// persistent plan autotuner consulted for `auto` registrations
    pub tuner: Tuner,
    /// persisted-analysis cache (`analysis_cache` config key)
    analysis_cache: Option<AnalysisCache>,
    /// cumulative structural passes paid by this pipeline's preparations
    counters: BuildCounters,
}

impl Pipeline {
    pub fn new(cfg: Config) -> Pipeline {
        let pool = Arc::new(Pool::new(cfg.workers));
        let tuner = Tuner::new(TunerOptions {
            top_k: cfg.tuner_top_k.max(1),
            race_solves: cfg.tuner_race_solves.max(1),
            workers: cfg.workers.max(1),
            cache_path: if cfg.tuner_cache.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.tuner_cache))
            },
            cache_ttl_secs: cfg.tuner_cache_ttl,
            // Race scheduled candidates with the same knobs serving will
            // build with — a plan decided at one block target must not be
            // served at another.
            sched: sched_fallback(&cfg),
            // Race on the serving pool: a cache miss must not pay (or be
            // skewed by) spawning a throwaway thread pool.
            pool: Some(Arc::clone(&pool)),
            // Iterative candidates may only enter the race when the
            // deployment states an accuracy budget they must certify.
            tolerance: (cfg.default_tolerance > 0.0).then_some(cfg.default_tolerance),
            // Race lanes time a batch_size-wide RHS block: candidates are
            // ranked under the load the batcher will actually present.
            batch: cfg.batch_size.max(1),
            ..Default::default()
        });
        // The registry is optional: without artifacts the coordinator
        // serves everything natively.
        let registry = if cfg.use_xla {
            match Registry::load(Path::new(&cfg.artifacts_dir)) {
                Ok(r) => Some(Arc::new(r)),
                Err(e) => {
                    eprintln!(
                        "warning: XLA registry unavailable ({e}); native backend only"
                    );
                    None
                }
            }
        } else {
            None
        };
        let analysis_cache = if cfg.analysis_cache.is_empty() {
            None
        } else {
            Some(
                AnalysisCache::with_limits(
                    Path::new(&cfg.analysis_cache),
                    cfg.analysis_cache_cap,
                    std::time::Duration::from_secs(cfg.analysis_cache_ttl),
                )
                .with_format(cfg.analysis_format),
            )
        };
        Pipeline {
            cfg,
            pool,
            registry,
            cache: BTreeMap::new(),
            tuner,
            analysis_cache,
            counters: BuildCounters::default(),
        }
    }

    pub fn xla_solver(&self) -> Option<XlaSolver> {
        self.registry.as_ref().map(|r| XlaSolver::new(Arc::clone(r)))
    }

    /// Cumulative structural passes (rewrite / coarsen / placement /
    /// renumeric) paid by every preparation this pipeline has built —
    /// surfaced through the metrics snapshot so "the warm cache really
    /// skipped the work" is observable, not asserted.
    pub fn rebuild_counters(&self) -> BuildCounters {
        self.counters
    }

    /// Whether a persistent analysis cache is configured.
    pub fn has_analysis_cache(&self) -> bool {
        self.analysis_cache.is_some()
    }

    /// Preprocess and cache a matrix under `id`. The plan arrives as an
    /// already-parsed [`PlanSpec`]: `Default` defers to the configured
    /// service-wide plan, `Auto` to the tuner — no plan-name string ever
    /// reaches this layer.
    pub fn prepare(
        &mut self,
        id: &str,
        m: Csr,
        spec: &PlanSpec,
    ) -> Result<Arc<Prepared>, Error> {
        if let Some(p) = self.cache.get(id) {
            return Ok(Arc::clone(p));
        }
        let start = Instant::now();
        m.validate_lower_triangular()?;
        // Arc the matrix up front: the tuner's race lanes and the solver
        // share it by reference count instead of copying.
        let m = Arc::new(m);
        let fingerprint = Fingerprint::of(&m);
        let resolved = spec.resolve(&self.cfg.plan);

        // When the plan is already known — fixed by name, or answered by
        // a (non-counting) peek at the tuner's fingerprint cache — a
        // persisted analysis can skip ALL structural work.
        let mut warm: Option<(Arc<Analysis>, Option<TunedInfo>)> = None;
        if let Some(cache) = &self.analysis_cache {
            let known: Option<(String, SolvePlan, bool)> = match &resolved {
                ResolvedPlan::Fixed(name, plan) => Some((name.clone(), plan.clone(), false)),
                ResolvedPlan::Auto => self
                    .tuner
                    .peek_cached_plan(fingerprint)
                    .and_then(|name| SolvePlan::parse(&name).ok().map(|p| (name, p, true))),
            };
            if let Some((name, plan, via_tuner)) = known {
                if let Some(analysis) = cache.load(
                    Arc::clone(&m),
                    fingerprint,
                    &plan,
                    &self.pool,
                    sched_fallback(&self.cfg),
                ) {
                    let tuned = via_tuner.then(|| TunedInfo {
                        plan: name,
                        cache_hit: true,
                        fingerprint: fingerprint.to_hex(),
                    });
                    warm = Some((Arc::new(analysis), tuned));
                }
            }
        }
        if let Some((analysis, tuned)) = warm {
            return self.finish(id, analysis, tuned, AnalysisSource::DiskCache, start);
        }

        // Full path: fixed plans build directly; `auto` consults the
        // tuner, whose race donates the winning lane's artifacts.
        let (analysis, tuned) = match resolved {
            ResolvedPlan::Auto => {
                let tp = self.tuner.choose_arc(&m)?;
                let tuned = TunedInfo {
                    plan: tp.plan_name.clone(),
                    cache_hit: tp.source == PlanSource::CacheHit,
                    fingerprint: tp.fingerprint.to_hex(),
                };
                let a = Analysis::from_tuned(
                    Arc::clone(&m),
                    tp,
                    Arc::clone(&self.pool),
                    sched_fallback(&self.cfg),
                    start,
                )?;
                (a, Some(tuned))
            }
            ResolvedPlan::Fixed(name, plan) => {
                let a = Analysis::build(
                    Arc::clone(&m),
                    fingerprint,
                    name,
                    plan,
                    Arc::clone(&self.pool),
                    sched_fallback(&self.cfg),
                    start,
                )?;
                (a, None)
            }
        };
        if let Some(cache) = &self.analysis_cache {
            if let Err(e) = cache.save(&analysis) {
                eprintln!("warning: analysis cache save failed: {e}");
            }
        }
        self.finish(id, Arc::new(analysis), tuned, AnalysisSource::Fresh, start)
    }

    /// Same-pattern value update for a registered matrix: the analysis is
    /// refreshed next to the old one (callers drain in-flight work
    /// against the old `Arc<Analysis>` first), the XLA fit is redone on
    /// the new values, and the cache entry is swapped.
    pub fn update_values(&mut self, id: &str, m: Csr) -> Result<Arc<Prepared>, Error> {
        let start = Instant::now();
        let Some(old) = self.cache.get(id).cloned() else {
            return Err(Error::Invalid(format!("matrix '{id}' is not registered")));
        };
        let analysis = Arc::new(old.analysis.refreshed(&m)?);
        // The refresh pays exactly one renumeric pass on top of whatever
        // the original build paid.
        self.counters.renumeric_passes += 1;
        self.cache.remove(id);
        self.finish(id, analysis, old.tuned.clone(), AnalysisSource::Refreshed, start)
    }

    /// Wrap an analysis into a served [`Prepared`]: account its build
    /// passes, fit an XLA artifact when possible, cache it under `id`.
    fn finish(
        &mut self,
        id: &str,
        analysis: Arc<Analysis>,
        tuned: Option<TunedInfo>,
        source: AnalysisSource,
        start: Instant,
    ) -> Result<Arc<Prepared>, Error> {
        if source != AnalysisSource::Refreshed {
            // Refresh accounts its single renumeric pass at the call
            // site; everything else contributes its full build record.
            self.counters = self.counters + analysis.rebuilds();
        }
        // Fit an XLA artifact if the registry is present, and stage the
        // system arrays on the device. Only level-set execution is
        // XLA-eligible: the padded level solve would silently discard the
        // schedule / sync-free counters / reordering other exec axes were
        // chosen for. The rewrite axis composes either way.
        let xla_eligible = matches!(analysis.plan().exec, Exec::Levelset);
        let mut backend = Backend::Native;
        let mut padded = None;
        let mut staged = None;
        if let (Some(reg), true) = (&self.registry, xla_eligible) {
            let m = analysis.matrix();
            let t = analysis.transform();
            let req = PaddedSystem::requirements(m, t);
            if let Some(meta) = reg.best_fit("solve", &req) {
                let p = PaddedSystem::build(m, t, meta.pad_shape())?;
                let solver = XlaSolver::new(Arc::clone(reg));
                staged = Some(solver.stage(&p)?);
                padded = Some(Arc::new(p));
                backend = Backend::Xla;
            }
        }
        let prepared = Arc::new(Prepared {
            id: id.to_string(),
            analysis,
            padded,
            staged,
            backend,
            tuned,
            source,
            prepare_time: start.elapsed(),
        });
        self.cache.insert(id.to_string(), Arc::clone(&prepared));
        Ok(prepared)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Prepared>> {
        self.cache.get(id).cloned()
    }

    pub fn evict(&mut self, id: &str) -> bool {
        self.cache.remove(id).is_some()
    }

    pub fn cached_ids(&self) -> Vec<String> {
        self.cache.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;
    use crate::util::rng::Rng;

    fn cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            ..Default::default()
        }
    }

    fn spec(s: &str) -> PlanSpec {
        PlanSpec::parse(s).unwrap()
    }

    #[test]
    fn prepare_caches_and_solves() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("lung2", m, &PlanSpec::Default).unwrap();
        assert_eq!(p.backend, Backend::Native);
        assert_eq!(p.source, AnalysisSource::Fresh);
        assert!(p.analysis.transform().stats.levels_after < p.analysis.transform().stats.levels_before);
        // Cache hit returns the same Arc.
        let p2 = pl.prepare(
            "lung2",
            generate::tridiagonal(5, &Default::default()),
            &PlanSpec::Default,
        );
        assert!(Arc::ptr_eq(&p, &p2.unwrap()));
        // And it solves.
        let b = vec![1.0; n];
        let x = p.native().solve(&b);
        assert!(p.m().residual_inf(&x, &b) < 1e-9);
    }

    #[test]
    fn auto_plan_consults_tuner_and_plan_cache() {
        let mut pl = Pipeline::new(cfg());
        // The tuner races on the pipeline's own worker pool instead of
        // spawning a throwaway one per cache miss.
        assert!(pl.tuner.opts.pool.is_some());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
        let n = m.nrows;
        let p1 = pl.prepare("a", m.clone(), &spec("auto")).unwrap();
        let t1 = p1.tuned.as_ref().expect("auto decision recorded");
        assert!(!t1.cache_hit);
        assert_eq!(t1.plan, p1.plan_name());
        assert_eq!(t1.fingerprint.len(), 16);
        // The tuned decision is a full two-axis plan.
        assert_eq!(&SolvePlan::parse(&t1.plan).unwrap(), p1.plan());
        // Same structure under a new id: the fingerprint cache answers.
        let p2 = pl.prepare("b", m.clone(), &spec("auto")).unwrap();
        let t2 = p2.tuned.as_ref().unwrap();
        assert!(t2.cache_hit);
        assert_eq!(t2.plan, t1.plan);
        assert_eq!(
            p2.analysis.transform().stats.levels_after,
            p1.analysis.transform().stats.levels_after
        );
        // And the plan solves correctly.
        let b = vec![1.0; n];
        let x = p2.native().solve(&b);
        assert!(p2.m().residual_inf(&x, &b) < 1e-9);
        // Fixed-name registrations carry no tuner decision.
        let p3 = pl.prepare("c", m, &spec("none")).unwrap();
        assert!(p3.tuned.is_none());
        assert_eq!(p3.plan_name(), "none");
    }

    #[test]
    fn plan_override() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::tridiagonal(50, &Default::default());
        let p = pl.prepare("tri", m, &spec("manual:5")).unwrap();
        assert_eq!(p.analysis.transform().num_levels(), 10);
    }

    #[test]
    fn scheduled_plan_builds_the_scheduled_backend() {
        let mut pl = Pipeline::new(Config {
            sched_block_target: 32,
            sched_stale_window: 2,
            ..cfg()
        });
        let m = generate::tridiagonal(120, &Default::default());
        let p = pl.prepare("tri", m, &spec("scheduled")).unwrap();
        assert_eq!(p.backend, Backend::Native);
        assert_eq!(p.native().mode(), "scheduled");
        let sched = p.native().scheduled().expect("scheduled solver");
        // A pure chain collapses into one block with no cross-worker
        // edges — the schedule-level win over 119 barriers.
        assert_eq!(sched.stats().num_blocks, 1);
        assert_eq!(sched.stats().cut_edges, 0);
        assert_eq!(sched.stats().levelset_barriers, 119);
        let b = vec![1.0; 120];
        let x = p.native().solve(&b);
        assert!(p.m().residual_inf(&x, &b) < 1e-10);
        // No rewriting happened: the legacy name pairs with `none`.
        assert_eq!(p.analysis.transform().stats.rows_rewritten, 0);
        assert_eq!(p.plan_name(), "scheduled");
        // The build paid one coarsening and one placement pass — visible
        // in the pipeline's cumulative counters.
        let c = pl.rebuild_counters();
        assert_eq!(c.coarsen_passes, 1);
        assert_eq!(c.placement_passes, 1);
    }

    #[test]
    fn composed_plan_prepares_rewrite_and_backend() {
        // The redesign's point: one registration carries BOTH axes.
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("c", m, &spec("avgcost+scheduled")).unwrap();
        assert_eq!(p.native().mode(), "scheduled");
        assert!(p.analysis.transform().stats.rows_rewritten > 0, "rewrite axis ran");
        assert!(p.analysis.transform().num_levels() < p.analysis.transform().stats.levels_before);
        // The schedule was built over the *transformed* levels.
        let sched = p.native().scheduled().unwrap();
        assert_eq!(sched.t.num_levels(), p.analysis.transform().num_levels());
        let b = vec![1.0; n];
        let x = p.native().solve(&b);
        assert!(p.m().residual_inf(&x, &b) < 1e-9);
    }

    #[test]
    fn execution_plans_prepare_and_solve() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        for (id, s, mode) in [
            ("sf", "syncfree", "syncfree"),
            ("ro", "reorder", "reordered"),
            ("sc", "scheduled:64:1", "scheduled"),
            ("c1", "avgcost+syncfree", "syncfree"),
            ("c2", "guarded:5+reorder", "reordered"),
        ] {
            let p = pl.prepare(id, m.clone(), &spec(s)).unwrap();
            assert_eq!(p.native().mode(), mode, "{s}");
            let b = vec![1.0; n];
            let x = p.native().solve(&b);
            assert!(p.m().residual_inf(&x, &b) < 1e-9, "{s}");
        }
    }

    #[test]
    fn update_values_refreshes_in_place_without_structural_work() {
        let mut pl = Pipeline::new(cfg());
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        let p = pl.prepare("m", m.clone(), &spec("avgcost+scheduled")).unwrap();
        let before = pl.rebuild_counters();
        let sched_ptr = Arc::as_ptr(p.analysis.schedule().unwrap());

        // Same pattern, new values (a refreshed factorization).
        let mut m2 = m.clone();
        let mut rng = Rng::new(3);
        for v in &mut m2.data {
            *v *= 1.0 + 0.1 * rng.uniform(-1.0, 1.0);
        }
        let p2 = pl.update_values("m", m2.clone()).unwrap();
        assert_eq!(p2.source, AnalysisSource::Refreshed);
        // No structural pass ran; one numeric replay did.
        let after = pl.rebuild_counters();
        assert_eq!(after.rewrite_passes, before.rewrite_passes);
        assert_eq!(after.coarsen_passes, before.coarsen_passes);
        assert_eq!(after.placement_passes, before.placement_passes);
        assert_eq!(after.renumeric_passes, before.renumeric_passes + 1);
        // The very schedule object survived the refresh.
        assert_eq!(Arc::as_ptr(p2.analysis.schedule().unwrap()), sched_ptr);
        // And the refreshed preparation solves the NEW system.
        let b = vec![1.0; n];
        let x = p2.native().solve(&b);
        assert!(m2.residual_inf(&x, &b) < 1e-9);
        // The old Arc still solves the OLD system (in-flight requests
        // taken before the swap drain against it).
        let x_old = p.native().solve(&b);
        assert!(m.residual_inf(&x_old, &b) < 1e-9);

        // Pattern changes are rejected, unknown ids are rejected.
        assert!(pl
            .update_values("m", generate::tridiagonal(7, &Default::default()))
            .is_err());
        assert!(pl.update_values("ghost", m).is_err());
    }

    #[test]
    fn analysis_cache_round_trips_across_pipelines() {
        let dir = std::env::temp_dir().join(format!("sptrsv_plcache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache_cfg = Config {
            analysis_cache: dir.to_str().unwrap().to_string(),
            ..cfg()
        };
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let n = m.nrows;
        {
            let mut pl = Pipeline::new(cache_cfg.clone());
            let p = pl.prepare("a", m.clone(), &spec("avgcost+scheduled")).unwrap();
            assert_eq!(p.source, AnalysisSource::Fresh);
            assert!(pl.rebuild_counters().coarsen_passes > 0);
        }
        // A fresh pipeline (fresh process) warm-loads the persisted
        // analysis: zero structural passes, correct solves.
        let mut pl2 = Pipeline::new(cache_cfg);
        let p = pl2.prepare("b", m.clone(), &spec("avgcost+scheduled")).unwrap();
        assert_eq!(p.source, AnalysisSource::DiskCache);
        let c = pl2.rebuild_counters();
        assert_eq!(c.rewrite_passes, 0);
        assert_eq!(c.coarsen_passes, 0);
        assert_eq!(c.placement_passes, 0);
        assert_eq!(c.renumeric_passes, 1);
        let b = vec![1.0; n];
        let x = p.native().solve(&b);
        assert!(m.residual_inf(&x, &b) < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn invalid_matrix_rejected() {
        let mut pl = Pipeline::new(cfg());
        let bad = Csr::new(2, 2, vec![0, 1, 3], vec![0, 0, 1], vec![0.0, 1.0, 1.0]).unwrap();
        assert!(pl.prepare("bad", bad, &PlanSpec::Default).is_err());
    }

    #[test]
    fn evict_and_ids() {
        let mut pl = Pipeline::new(cfg());
        pl.prepare(
            "a",
            generate::tridiagonal(10, &Default::default()),
            &PlanSpec::Default,
        )
        .unwrap();
        assert_eq!(pl.cached_ids(), vec!["a"]);
        assert!(pl.evict("a"));
        assert!(!pl.evict("a"));
    }
}
