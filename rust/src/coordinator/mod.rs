//! L3 coordinator: the serving layer around the preprocessing
//! contribution.
//!
//! The paper's contribution is a *preprocessing* transformation, so per
//! DESIGN.md the coordinator is a thin-but-real service: it owns the
//! preprocessing pipeline (levels → strategy → transformed system →
//! padded artifacts), caches prepared matrices, batches right-hand sides,
//! dispatches to the native or XLA backend, and reports metrics.
//!
//! * [`pipeline`] — prepare/caches matrices (the expensive offline step)
//! * [`batcher`]  — RHS batching queue with a deadline
//! * [`metrics`]  — counters + latency histogram
//! * [`service`]  — the request loop (std mpsc; tokio is not vendored)

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod service;

pub use pipeline::{Backend, Pipeline, Prepared};
pub use service::{Service, SolveHandle};
