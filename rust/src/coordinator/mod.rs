//! L3 coordinator: the serving layer around the preprocessing
//! contribution.
//!
//! The paper's contribution is a *preprocessing* transformation, so per
//! DESIGN.md the coordinator is a thin-but-real service: it owns the
//! preprocessing pipeline (levels → solve plan → transformed system →
//! execution backend / padded artifacts), caches prepared matrices,
//! batches right-hand sides,
//! dispatches to the native or XLA backend, and reports metrics.
//!
//! The client surface is fully typed: solve plans cross as
//! [`crate::transform::PlanSpec`] (the two-axis `rewrite+exec` grammar,
//! parsed once at the edge), registrations return a [`MatrixHandle`]
//! backed by the service-resident shared [`crate::analysis::Analysis`]
//! (with [`MatrixHandle::update_values`] refreshing numerics in place
//! behind the batcher), failures as
//! [`crate::error::ServiceError`], async solves as [`SolveTicket`]s with
//! deadline/priority [`SolveOptions`] (cancellation wakes the service
//! for an immediate queue sweep), multi-RHS blocks via
//! [`SolveHandle::solve_many`], and admission is bounded by the
//! `max_pending` config key plus per-matrix
//! [`RegisterOptions::max_pending`] overrides.
//!
//! * [`pipeline`] — prepare/caches matrices (the expensive offline step)
//! * [`batcher`]  — per-lane RHS batching queue with deadlines
//! * [`metrics`]  — counters + latency histogram + lane gauges
//! * [`service`]  — the request loop (std mpsc; tokio is not vendored)

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod service;

pub use batcher::Lane;
pub use metrics::{LaneLatency, Metrics, Snapshot};
pub use pipeline::{AnalysisSource, Backend, Pipeline, Prepared};
pub use service::{
    BlockTicket, MatrixHandle, RegisterInfo, RegisterOptions, Service, ShedPolicy,
    SolveHandle, SolveOptions, SolveTicket, Ticket,
};
