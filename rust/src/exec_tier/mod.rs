//! Executor tier: *where* a prepared analysis runs.
//!
//! The coordinator's service loop owns admission, batching, tickets and
//! metrics; everything below the batcher — preparing analyses, holding
//! them, and solving against them — is abstracted behind the [`Executor`]
//! trait so the same service loop can serve from two very different
//! placements:
//!
//! * [`InProcessExecutor`] — the original single-process pipeline: the
//!   analyses live in the service thread's address space and solves run
//!   on its worker pool (XLA staged batching included).
//! * [`ShardPoolExecutor`] — a pool of N child worker processes (the
//!   hidden `sptrsv shard-worker` subcommand), each running its own
//!   in-process executor behind a length-prefixed JSON-over-stdio
//!   protocol ([`protocol`]). Matrices are routed to shards by structural
//!   fingerprint with rendezvous hashing ([`rendezvous`]), so resizing
//!   the pool moves the minimal set of matrices, and each shard keeps a
//!   shared-nothing analysis/tuner cache. A worker that dies or hangs is
//!   detected by reply timeout, killed, respawned, and its roster
//!   re-registered — warm from the shard's analysis-cache subdirectory
//!   when one is configured, so a crash costs zero structural passes.
//!   In-flight requests on the dead shard resolve to
//!   [`ServiceError::Backend`] instead of hanging, and
//!   crash/respawn/re-register counts surface in the metrics snapshot.
//!
//! The `executor` config key selects the tier (`inprocess` or
//! `sharded:N`); [`make_executor`] is the single construction point the
//! service uses.

pub mod inprocess;
pub mod protocol;
pub mod rendezvous;
pub mod shard;
pub mod worker;

pub use inprocess::InProcessExecutor;
pub use shard::ShardPoolExecutor;

use crate::analysis::BuildCounters;
use crate::config::Config;
use crate::coordinator::RegisterInfo;
use crate::error::ServiceError;
use crate::sparse::Csr;
use crate::trace::{PhaseTimes, PhaseTotals};
use crate::transform::PlanSpec;

/// What a registration (or value refresh) reports back through the tier:
/// the client-facing [`RegisterInfo`] plus the bookkeeping the service
/// needs for validation, metrics and tracing.
#[derive(Debug, Clone)]
pub struct RegisterOutcome {
    pub info: RegisterInfo,
    /// row count, kept service-side so RHS validation never crosses the
    /// tier boundary
    pub nrows: usize,
    /// analyze-phase wall clocks for the tracer
    pub phase_times: PhaseTimes,
    /// `Some((plan, cache_hit))` when the tuner decided for this
    /// registration (fresh `auto` registrations only)
    pub tuned: Option<(String, bool)>,
    /// `Some(hit)` when a persistent analysis cache is configured and
    /// this was a fresh registration
    pub analysis_cache_hit: Option<bool>,
}

/// One dispatched batch's results.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// solutions, one per submitted right-hand side, in order
    pub xs: Vec<Vec<f64>>,
    /// whether the staged batched-XLA path served the whole batch
    pub batched: bool,
    /// elastic `(waits, ooo, steals)` deltas attributable to this call
    pub elastic: (u64, u64, u64),
    /// `Some(delta)` when the execution ran in a shard worker with its
    /// own tracer: the worker-measured [`PhaseTotals`] for exactly this
    /// call (Execute time + elastic counters), for the coordinator's
    /// tracer to fold. `None` for in-process execution, where the
    /// coordinator brackets the call itself.
    pub trace: Option<PhaseTotals>,
    /// worst achieved relative residual `‖Lx−b‖∞/‖b‖∞` across the batch,
    /// measured against the **original** system. `Some` only when the
    /// call carried a tolerance and residual checking is on.
    pub residual: Option<f64>,
    /// right-hand sides this call served via the exact fallback because
    /// the iterative backend could not certify the tolerance (or there
    /// was no tolerance to certify against)
    pub fallbacks_to_exact: u64,
    /// sweep-budget doublings the accuracy ladder paid during this call
    pub sweep_escalations: u64,
    /// wall-clock spent computing residuals (and ladder re-solves) for
    /// this call, for the [`crate::trace::Phase::Residual`] span
    pub residual_us: u64,
}

impl SolveOutcome {
    /// An outcome with no accuracy bookkeeping (exact path, no tolerance).
    pub fn plain(xs: Vec<Vec<f64>>, batched: bool, elastic: (u64, u64, u64)) -> SolveOutcome {
        SolveOutcome {
            xs,
            batched,
            elastic,
            trace: None,
            residual: None,
            fallbacks_to_exact: 0,
            sweep_escalations: 0,
            residual_us: 0,
        }
    }
}

/// One shard worker's health as the supervisor sees it, surfaced into
/// the metrics snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLiveness {
    /// shard index (stable across respawns)
    pub shard: usize,
    /// false = down and the respawn failed too
    pub up: bool,
    /// milliseconds since the last frame this worker generation answered
    pub last_frame_age_ms: u64,
    /// frames written to the worker that have not been answered yet
    pub inflight: u64,
}

/// Executor-side observability, polled at snapshot time.
#[derive(Debug, Clone, Default)]
pub struct ExecGauges {
    pub sched_blocks: u64,
    pub sched_cut: u64,
    /// cumulative elastic counters across every served matrix
    pub elastic_waits: u64,
    pub elastic_ooo: u64,
    pub elastic_steals: u64,
    /// cumulative structural passes paid by the tier (summed across
    /// shards, and across worker generations when a shard respawned)
    pub rebuilds: BuildCounters,
    pub shard_crashes: u64,
    pub shard_respawns: u64,
    pub shard_reregistered: u64,
    /// per-shard health (empty for the in-process tier)
    pub shard_liveness: Vec<ShardLiveness>,
    /// cumulative per-matrix worker-side trace totals (Execute time and
    /// elastic counters measured inside shard workers), monotone across
    /// respawns via the same retirement discipline as the counters
    /// above; empty for the in-process tier
    pub trace_totals: Vec<(String, PhaseTotals)>,
}

/// Where a prepared analysis runs. Implementations own the prepared-state
/// lifetime; the service loop above owns queues, tickets and policy.
pub trait Executor: Send {
    /// Prepare `m` under `id` (memoized per id, like the pipeline).
    fn register(
        &mut self,
        id: &str,
        m: Csr,
        spec: &PlanSpec,
    ) -> Result<RegisterOutcome, ServiceError>;

    /// Same-pattern numeric refresh of a registered matrix.
    fn update_values(&mut self, id: &str, m: Csr) -> Result<RegisterOutcome, ServiceError>;

    /// Solve one dispatched batch of right-hand sides against `id`'s
    /// prepared analysis. An error applies to the whole batch (the
    /// service replies it to every ticket — a dead shard must resolve
    /// tickets, never hang them).
    ///
    /// `tolerance` is the strictest relative-residual bound any request
    /// in the batch carries (`None` = the batch demands the exact path).
    /// An iterative backend must certify it — escalating its sweep
    /// budget and falling back to the exact solve when it cannot — and
    /// reports the achieved residual in the outcome; a batch whose
    /// tolerance not even the exact solve meets fails with
    /// [`ServiceError::AccuracyUnsatisfiable`].
    fn solve_block(
        &mut self,
        id: &str,
        rhs: &[Vec<f64>],
        tolerance: Option<f64>,
    ) -> Result<SolveOutcome, ServiceError>;

    /// Fold executor-side gauges (schedule stats, elastic counters,
    /// structural-pass totals, shard health) for the metrics snapshot.
    fn gauges(&mut self) -> ExecGauges;

    /// Release resources (child processes for the sharded tier).
    fn shutdown(&mut self);
}

/// Build the executor the `executor` config key names. A shard pool that
/// fails to start (missing worker binary, spawn failure) degrades to the
/// in-process tier with a warning instead of taking the service down.
pub fn make_executor(cfg: &Config) -> Box<dyn Executor> {
    match cfg.shard_count() {
        Some(n) => match ShardPoolExecutor::start(cfg.clone(), n) {
            Ok(p) => Box::new(p),
            Err(e) => {
                eprintln!("warning: sharded executor unavailable ({e}); serving in-process");
                Box::new(InProcessExecutor::new(cfg.clone()))
            }
        },
        None => Box::new(InProcessExecutor::new(cfg.clone())),
    }
}
