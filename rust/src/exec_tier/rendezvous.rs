//! Rendezvous (highest-random-weight) routing of matrices to shards.
//!
//! Each matrix's structural [`Fingerprint`] is scored against every shard
//! index; the shard with the highest score wins. Unlike `fp % n`, growing
//! or shrinking the pool by one shard only remaps the matrices that move
//! to (or lived on) the changed shard — everything else keeps its home,
//! which is what makes warm respawn and pool resizing cheap.

use crate::tuner::Fingerprint;

/// FNV-1a over the concatenated little-endian bytes of `(a, b)`. The
/// fingerprint module keeps its own FNV helper private, so the router
/// carries the (tiny) mix itself.
fn mix(a: u64, b: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in a.to_le_bytes().into_iter().chain(b.to_le_bytes()) {
        h ^= u64::from(byte);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Home shard for `fp` in a pool of `nshards` (>= 1). Ties break toward
/// the lower shard index, so routing is a pure function of the inputs.
pub fn route(fp: Fingerprint, nshards: usize) -> usize {
    let mut best = 0usize;
    let mut best_score = mix(fp.0, 0);
    for shard in 1..nshards {
        let score = mix(fp.0, shard as u64);
        if score > best_score {
            best = shard;
            best_score = score;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fps(n: u64) -> impl Iterator<Item = Fingerprint> {
        // Spread the probe keys; consecutive integers would share most
        // of their byte patterns.
        (0..n).map(|i| Fingerprint(i.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0x5bd1))
    }

    #[test]
    fn routes_are_stable_and_in_range() {
        for fp in fps(200) {
            for n in 1..6 {
                let k = route(fp, n);
                assert!(k < n);
                assert_eq!(k, route(fp, n), "pure function of (fp, n)");
            }
            assert_eq!(route(fp, 1), 0);
        }
    }

    #[test]
    fn spreads_load_across_shards() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for fp in fps(400) {
            counts[route(fp, n)] += 1;
        }
        for (shard, &c) in counts.iter().enumerate() {
            assert!(c > 0, "shard {shard} received nothing: {counts:?}");
        }
    }

    #[test]
    fn adding_a_shard_only_moves_keys_onto_it() {
        for fp in fps(300) {
            let before = route(fp, 3);
            let after = route(fp, 4);
            assert!(
                after == before || after == 3,
                "{fp:?} moved {before} -> {after} when shard 3 was added"
            );
        }
    }

    #[test]
    fn removing_a_shard_only_moves_its_own_keys() {
        for fp in fps(300) {
            let before = route(fp, 4);
            let after = route(fp, 3);
            if before < 3 {
                assert_eq!(after, before, "{fp:?} moved off a surviving shard");
            } else {
                assert!(after < 3);
            }
        }
    }
}
