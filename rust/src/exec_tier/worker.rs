//! The shard-worker side of the protocol: one [`InProcessExecutor`]
//! served over stdin/stdout frames. This is the entire body of the
//! hidden `sptrsv shard-worker` subcommand.
//!
//! The loop is generic over `Read`/`Write`, so a full worker session —
//! register, solve, error paths, gauges, shutdown — unit-tests over
//! in-memory buffers without spawning a process.
//!
//! When the parent's tracing is on (`--trace-enabled`, forwarded by the
//! supervisor), the worker runs its own [`Tracer`]: every solve records
//! an Execute span plus the elastic counters into it, the per-solve
//! delta rides the solve response, and the cumulative per-matrix totals
//! ride every gauges response — so the coordinator's `trace_report`
//! attributes worker-side execution correctly in `sharded:N` mode.
//!
//! Nothing here may print to stdout: that stream carries frames. All
//! diagnostics go to stderr (inherited from the supervisor).

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::time::Instant;

use crate::config::Config;
use crate::error::ServiceError;
use crate::trace::{Phase, PhaseTotals, Tracer, DEFAULT_RING_CAPACITY};
use crate::transform::PlanSpec;
use crate::util::json::Json;

use super::inprocess::InProcessExecutor;
use super::protocol;
use super::Executor;

/// Serve frames on this process's stdin/stdout until shutdown or EOF
/// (the supervisor closing our stdin is a normal exit).
pub fn serve(cfg: Config) -> io::Result<()> {
    let tracer = Tracer::new(cfg.trace_enabled, DEFAULT_RING_CAPACITY);
    let mut exec = InProcessExecutor::new(cfg);
    let stdin = io::stdin();
    let stdout = io::stdout();
    let mut r = BufReader::new(stdin.lock());
    let mut w = BufWriter::new(stdout.lock());
    run_loop(&mut exec, &tracer, &mut r, &mut w)
}

/// One worker session: read a frame, apply it to the executor, answer.
pub fn run_loop<R: Read, W: Write>(
    exec: &mut InProcessExecutor,
    tracer: &Tracer,
    r: &mut R,
    w: &mut W,
) -> io::Result<()> {
    loop {
        let Some(req) = protocol::read_frame(r)? else {
            return Ok(());
        };
        let op = req.get("op").and_then(Json::as_str).unwrap_or("");
        let resp = match op {
            "register" | "update" => handle_register(exec, &req, op),
            "solve" => handle_solve(exec, tracer, &req),
            "gauges" => {
                let mut g = exec.gauges();
                g.trace_totals = tracer.report().matrices;
                protocol::gauges_response(&g)
            }
            "shutdown" => {
                // The bye-ack is the last frame on the stream: everything
                // in flight was answered above, so the supervisor can
                // drain replies up to this marker and then wait() instead
                // of killing a worker that is still writing results.
                protocol::write_frame(w, &protocol::bye_response())?;
                return Ok(());
            }
            other => invalid(format!("unknown op '{other}'")),
        };
        protocol::write_frame(w, &resp)?;
    }
}

fn invalid(msg: String) -> Json {
    protocol::err_response(&ServiceError::InvalidRequest(msg))
}

fn handle_register(exec: &mut InProcessExecutor, req: &Json, op: &str) -> Json {
    let Some(id) = req.get("id").and_then(Json::as_str) else {
        return invalid(format!("{op} without id"));
    };
    let m = match req.get("matrix") {
        Some(j) => match protocol::csr_from_json(j) {
            Ok(m) => m,
            Err(e) => return invalid(format!("{op} '{id}': {e}")),
        },
        None => return invalid(format!("{op} '{id}' without matrix")),
    };
    let res = if op == "register" {
        let plan = req.get("plan").and_then(Json::as_str).unwrap_or("");
        match PlanSpec::parse(plan) {
            Ok(spec) => exec.register(id, m, &spec),
            Err(e) => return invalid(format!("register '{id}': {e}")),
        }
    } else {
        exec.update_values(id, m)
    };
    match res {
        Ok(out) => protocol::register_response(&out, exec.rebuild_counters()),
        Err(e) => protocol::err_response(&e),
    }
}

fn handle_solve(exec: &mut InProcessExecutor, tracer: &Tracer, req: &Json) -> Json {
    let Some(id) = req.get("id").and_then(Json::as_str) else {
        return invalid("solve without id".to_string());
    };
    let rhs: Option<Vec<Vec<f64>>> = req.get("rhs").and_then(Json::as_arr).and_then(|rows| {
        rows.iter()
            .map(|row| protocol::f64_vec(Some(row)))
            .collect::<Option<Vec<_>>>()
    });
    let Some(rhs) = rhs else {
        return invalid(format!("solve '{id}' with malformed rhs"));
    };
    let tol = req.get("tol").and_then(Json::as_f64);
    let start = Instant::now();
    match exec.solve_block(id, &rhs, tol) {
        Ok(mut out) => {
            if tracer.enabled() {
                let dur = start.elapsed();
                tracer.record(id, Phase::Execute, dur);
                if out.residual_us > 0 {
                    tracer.record(
                        id,
                        Phase::Residual,
                        std::time::Duration::from_micros(out.residual_us),
                    );
                }
                let (w, o, s) = out.elastic;
                tracer.record_elastic(id, w, o, s);
                out.trace = Some(PhaseTotals {
                    execute_us: dur.as_micros() as u64,
                    residual_us: out.residual_us,
                    spans: 1 + u64::from(out.residual_us > 0),
                    elastic_waits: w,
                    elastic_ooo: o,
                    elastic_steals: s,
                    ..Default::default()
                });
            }
            protocol::solve_response(&out)
        }
        Err(e) => protocol::err_response(&e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;
    use std::io::Cursor;

    #[test]
    fn worker_session_over_in_memory_buffers() {
        let m = generate::random_lower(60, 2, 0.8, &Default::default());
        let b = vec![1.0; 60];
        let mut reqs = Vec::new();
        for frame in [
            protocol::register_req("register", "a", &m, "avgcost"),
            protocol::solve_req("a", &[b.clone(), b.clone()], None),
            protocol::solve_req("a", &[b.clone()], Some(1e-8)),
            protocol::solve_req("ghost", &[b.clone()], None),
            Json::obj(vec![("op", Json::Str("launder".into()))]),
            protocol::gauges_req(),
            protocol::shutdown_req(),
        ] {
            protocol::write_frame(&mut reqs, &frame).unwrap();
        }

        let mut exec = InProcessExecutor::new(Config {
            workers: 1,
            use_xla: false,
            ..Default::default()
        });
        let tracer = Tracer::new(true, DEFAULT_RING_CAPACITY);
        let mut out = Vec::new();
        run_loop(&mut exec, &tracer, &mut Cursor::new(reqs), &mut out).unwrap();

        let mut r = Cursor::new(out);
        let mut next = || protocol::read_frame(&mut r).unwrap();

        let reg = next().expect("register response");
        assert!(protocol::is_ok(&reg));
        let (outc, rebuilds) = protocol::register_from_response(&reg).unwrap();
        assert_eq!(outc.nrows, 60);
        assert_eq!(outc.info.plan, "avgcost");
        assert_eq!(rebuilds.rewrite_passes, 1);

        let sol = next().expect("solve response");
        let sol = protocol::solve_from_response(&sol).unwrap();
        assert_eq!(sol.xs.len(), 2);
        assert!(m.residual_inf(&sol.xs[0], &b) < 1e-9);
        // With tracing on, the worker embeds its measured Execute delta.
        let delta = sol.trace.expect("traced worker sends a solve delta");
        assert_eq!(delta.spans, 1);
        assert_eq!(sol.residual, None, "no tolerance on the frame");

        // A toleranced frame certifies on the exact path and reports the
        // achieved residual plus the Residual span in its trace delta.
        let toleranced = protocol::solve_from_response(&next().expect("toleranced")).unwrap();
        let r = toleranced.residual.expect("tolerance measured");
        assert!(r <= 1e-8, "residual {r:.3e}");
        let delta = toleranced.trace.expect("trace delta");
        assert_eq!(delta.residual_us, toleranced.residual_us);

        let ghost = next().expect("error response");
        assert!(matches!(
            protocol::response_error(&ghost),
            ServiceError::NotRegistered(id) if id == "ghost"
        ));

        let laundered = next().expect("unknown-op response");
        assert!(matches!(
            protocol::response_error(&laundered),
            ServiceError::InvalidRequest(_)
        ));

        let gauges = next().expect("gauges response");
        let g = protocol::gauges_from_response(&gauges).unwrap();
        assert_eq!(g.rebuilds.rewrite_passes, 1);
        // The cumulative per-matrix totals cover both solves above.
        let (id, totals) = &g.trace_totals[0];
        assert_eq!(id, "a");
        assert!(totals.spans >= 2, "one Execute span per traced solve");

        let bye = next().expect("shutdown ack");
        assert!(protocol::is_ok(&bye));
        assert!(
            protocol::is_bye(&bye),
            "shutdown ack carries the bye marker so the supervisor's drain knows it is the final frame"
        );
        assert_eq!(next(), None, "loop ended at shutdown");
    }

    #[test]
    fn untraced_worker_sends_no_trace_payloads() {
        let m = generate::tridiagonal(30, &Default::default());
        let b = vec![1.0; 30];
        let mut reqs = Vec::new();
        for frame in [
            protocol::register_req("register", "t", &m, "none"),
            protocol::solve_req("t", &[b.clone()], None),
            protocol::gauges_req(),
        ] {
            protocol::write_frame(&mut reqs, &frame).unwrap();
        }
        let mut exec = InProcessExecutor::new(Config {
            workers: 1,
            use_xla: false,
            ..Default::default()
        });
        let tracer = Tracer::new(false, DEFAULT_RING_CAPACITY);
        let mut out = Vec::new();
        run_loop(&mut exec, &tracer, &mut Cursor::new(reqs), &mut out).unwrap();
        let mut r = Cursor::new(out);
        let mut next = || protocol::read_frame(&mut r).unwrap().unwrap();
        let _reg = next();
        let sol = protocol::solve_from_response(&next()).unwrap();
        assert_eq!(sol.trace, None, "tracing off: no delta on the wire");
        let g = protocol::gauges_from_response(&next()).unwrap();
        assert!(g.trace_totals.is_empty());
    }

    #[test]
    fn clean_eof_ends_the_loop() {
        let mut exec = InProcessExecutor::new(Config {
            workers: 1,
            use_xla: false,
            ..Default::default()
        });
        let tracer = Tracer::new(false, DEFAULT_RING_CAPACITY);
        let mut out = Vec::new();
        run_loop(&mut exec, &tracer, &mut Cursor::new(Vec::new()), &mut out).unwrap();
        assert!(out.is_empty());
    }
}
