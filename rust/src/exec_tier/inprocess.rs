//! The in-process executor: today's single-process pipeline behind the
//! [`Executor`] trait. Prepared analyses live in this struct, solves run
//! on the pipeline's worker pool, and the staged batched-XLA path is
//! taken when a dispatched block exactly matches the staged batch size —
//! byte-for-byte the behavior the service loop had before the tier
//! split. It is also the entire body of a `shard-worker` process, which
//! wraps one of these in the stdio protocol loop.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::analysis::BuildCounters;
use crate::config::Config;
use crate::coordinator::pipeline::{AnalysisSource, Backend, Pipeline, Prepared};
use crate::coordinator::RegisterInfo;
use crate::error::{Error, ServiceError};
use crate::runtime::XlaSolver;
use crate::sparse::Csr;
use crate::transform::PlanSpec;

use super::{ExecGauges, Executor, RegisterOutcome, SolveOutcome};

/// Accuracy bookkeeping one solve accrues (residual checks, ladder
/// escalations, exact fallbacks).
#[derive(Debug, Clone, Copy, Default)]
struct Accuracy {
    residual: Option<f64>,
    fallbacks: u64,
    escalations: u64,
    residual_us: u64,
}

pub struct InProcessExecutor {
    pipeline: Pipeline,
    xla: Option<XlaSolver>,
    prepared: BTreeMap<String, Arc<Prepared>>,
    /// sticky per-matrix sweep budgets: once the accuracy ladder had to
    /// escalate a matrix, future solves start at the certified budget
    /// instead of re-climbing from the plan's sweep count
    escalated: BTreeMap<String, usize>,
}

impl InProcessExecutor {
    pub fn new(cfg: Config) -> InProcessExecutor {
        let mut pipeline = Pipeline::new(cfg);
        let xla = pipeline.xla_solver();
        InProcessExecutor {
            pipeline,
            xla,
            prepared: BTreeMap::new(),
            escalated: BTreeMap::new(),
        }
    }

    /// Cumulative structural-pass counters (no calibration side effects,
    /// unlike [`Executor::gauges`]).
    pub fn rebuild_counters(&self) -> BuildCounters {
        self.pipeline.rebuild_counters()
    }

    fn outcome(&self, p: &Arc<Prepared>, fresh: bool, source: AnalysisSource) -> RegisterOutcome {
        RegisterOutcome {
            info: register_info(p, fresh, source),
            nrows: p.m().nrows,
            phase_times: p.analysis.phase_times(),
            tuned: if fresh {
                p.tuned.as_ref().map(|t| (t.plan.clone(), t.cache_hit))
            } else {
                None
            },
            analysis_cache_hit: (fresh && self.pipeline.has_analysis_cache())
                .then(|| p.source == AnalysisSource::DiskCache),
        }
    }
}

impl Executor for InProcessExecutor {
    fn register(
        &mut self,
        id: &str,
        m: Csr,
        spec: &PlanSpec,
    ) -> Result<RegisterOutcome, ServiceError> {
        // A same-id re-registration returns the memoized preparation;
        // only fresh preparations count as tuner decisions.
        let fresh = !self.prepared.contains_key(id);
        let p = self
            .pipeline
            .prepare(id, m, spec)
            .map_err(|e| ServiceError::Backend(e.to_string()))?;
        self.prepared.insert(id.to_string(), Arc::clone(&p));
        let source = if fresh { p.source } else { AnalysisSource::Memoized };
        Ok(self.outcome(&p, fresh, source))
    }

    fn update_values(&mut self, id: &str, m: Csr) -> Result<RegisterOutcome, ServiceError> {
        if !self.prepared.contains_key(id) {
            return Err(ServiceError::NotRegistered(id.to_string()));
        }
        let p = self.pipeline.update_values(id, m).map_err(|e| match e {
            // Pattern mismatch (and kin) is the caller's bug, not a
            // backend failure.
            Error::Invalid(msg) => ServiceError::InvalidRequest(msg),
            other => ServiceError::Backend(other.to_string()),
        })?;
        self.prepared.insert(id.to_string(), Arc::clone(&p));
        Ok(self.outcome(&p, false, AnalysisSource::Refreshed))
    }

    fn solve_block(
        &mut self,
        id: &str,
        rhs: &[Vec<f64>],
        tolerance: Option<f64>,
    ) -> Result<SolveOutcome, ServiceError> {
        let p = self
            .prepared
            .get(id)
            .map(Arc::clone)
            .ok_or_else(|| ServiceError::NotRegistered(id.to_string()))?;
        // Sample the elastic counters around the block so the stalls it
        // caused are attributable to this matrix.
        let elastic_before = p.native().scheduled().map(|s| s.elastic_counters());

        let total = rhs.len();
        let mut served = None;
        if total > 1 {
            if let (Backend::Xla, Some(solver), Some(padded), Some(staged)) =
                (p.backend, &self.xla, &p.padded, &p.staged)
            {
                if staged.batch_size() == Some(total) {
                    if let Ok(xs) = solver.solve_batched_staged(staged, padded, rhs) {
                        served = Some(xs);
                    }
                }
            }
        }
        let batched = served.is_some();
        let mut acc = Accuracy::default();
        let xs = match served {
            Some(xs) => xs,
            None if p.native().jacobi().is_some() => {
                let (xs, a) = solve_inexact(
                    &mut self.escalated,
                    &self.pipeline.cfg,
                    &p,
                    id,
                    rhs,
                    tolerance,
                )?;
                acc = a;
                xs
            }
            None => rhs.iter().map(|b| solve_rhs(&p, &self.xla, b)).collect(),
        };
        // Exact paths certify too when asked: the achieved residual is
        // reported, and a tolerance even the exact solve misses is a
        // typed failure, not a silently wrong answer.
        if acc.residual.is_none() && self.pipeline.cfg.residual_check {
            if let Some(tol) = tolerance {
                let t0 = Instant::now();
                let worst = worst_residual(p.m(), &xs, rhs);
                acc.residual_us += t0.elapsed().as_micros() as u64;
                acc.residual = Some(worst);
                if worst > tol {
                    return Err(ServiceError::AccuracyUnsatisfiable(format!(
                        "'{id}': requested tolerance {tol:.3e}, exact backend achieved {worst:.3e}"
                    )));
                }
            }
        }

        let elastic = match (p.native().scheduled(), elastic_before) {
            (Some(s), Some((w0, o0, s0))) => {
                let (w1, o1, s1) = s.elastic_counters();
                (
                    w1.saturating_sub(w0),
                    o1.saturating_sub(o0),
                    s1.saturating_sub(s0),
                )
            }
            _ => (0, 0, 0),
        };
        // The coordinator brackets in-process execution itself; only
        // shard workers attach a measured trace delta.
        Ok(SolveOutcome {
            xs,
            batched,
            elastic,
            trace: None,
            residual: acc.residual,
            fallbacks_to_exact: acc.fallbacks,
            sweep_escalations: acc.escalations,
            residual_us: acc.residual_us,
        })
    }

    fn gauges(&mut self) -> ExecGauges {
        // Blocks + static cut per schedule, cumulative elastic counters
        // per solver.
        let mut g = ExecGauges::default();
        for p in self.prepared.values() {
            if let Some(s) = p.native().scheduled() {
                let st = s.stats();
                g.sched_blocks += st.num_blocks as u64;
                g.sched_cut += st.cut_edges as u64;
                let (w, o, st) = s.elastic_counters();
                g.elastic_waits += w;
                g.elastic_ooo += o;
                g.elastic_steals += st;
            }
        }
        // Feed the observed stall counters back into the tuner's cost
        // model: future `auto` decisions price waits by what this machine
        // actually measured (the calibrate hook; EWMA + clamps inside).
        self.pipeline
            .tuner
            .model
            .calibrate_sched(g.elastic_waits, g.elastic_ooo, g.sched_blocks);
        g.rebuilds = self.pipeline.rebuild_counters();
        g
    }

    fn shutdown(&mut self) {}
}

/// Worst relative residual across a solved batch, against the original
/// system.
fn worst_residual(m: &Csr, xs: &[Vec<f64>], rhs: &[Vec<f64>]) -> f64 {
    xs.iter()
        .zip(rhs)
        .map(|(x, b)| crate::iterative::relative_residual(m, x, b))
        .fold(0.0, f64::max)
}

/// The accuracy ladder for an iterative backend: solve at the sticky
/// sweep budget, double it (capped at `jacobi_max_sweeps`) until the
/// tolerance certifies, and serve the batch via the exact serial solve
/// of the original system when it never does — or immediately, when
/// there is no tolerance (or no residual checking) to certify with. A
/// tolerance not even the exact fallback meets is
/// [`ServiceError::AccuracyUnsatisfiable`].
fn solve_inexact(
    escalated: &mut BTreeMap<String, usize>,
    cfg: &Config,
    p: &Prepared,
    id: &str,
    rhs: &[Vec<f64>],
    tolerance: Option<f64>,
) -> Result<(Vec<Vec<f64>>, Accuracy), ServiceError> {
    let j = p.native().jacobi().expect("iterative backend");
    let m = p.m();
    let mut acc = Accuracy::default();
    let (Some(tol), true) = (tolerance, cfg.residual_check) else {
        // An inexact answer nobody can certify is not servable: the
        // request gets the exact solve it implicitly asked for.
        acc.fallbacks = rhs.len() as u64;
        let xs = rhs.iter().map(|b| crate::solver::serial::solve(m, b)).collect();
        return Ok((xs, acc));
    };
    let max_sweeps = cfg.jacobi_max_sweeps.max(1);
    let mut sweeps = escalated
        .get(id)
        .copied()
        .unwrap_or_else(|| j.sweeps())
        .clamp(1, max_sweeps);
    let mut xs: Vec<Vec<f64>>;
    let mut worst: f64;
    loop {
        xs = rhs
            .iter()
            .map(|b| {
                let mut x = vec![0.0; m.nrows];
                j.solve_with_sweeps(b, sweeps, &mut x);
                x
            })
            .collect();
        let t0 = Instant::now();
        worst = worst_residual(m, &xs, rhs);
        acc.residual_us += t0.elapsed().as_micros() as u64;
        if worst <= tol || sweeps >= max_sweeps {
            break;
        }
        sweeps = (sweeps * 2).min(max_sweeps);
        acc.escalations += 1;
    }
    if worst <= tol {
        acc.residual = Some(worst);
        if sweeps > j.sweeps() {
            escalated.insert(id.to_string(), sweeps);
        }
        return Ok((xs, acc));
    }
    // The ladder topped out below the tolerance: serve exactly.
    acc.fallbacks = rhs.len() as u64;
    let xs: Vec<Vec<f64>> = rhs.iter().map(|b| crate::solver::serial::solve(m, b)).collect();
    let t0 = Instant::now();
    let worst = worst_residual(m, &xs, rhs);
    acc.residual_us += t0.elapsed().as_micros() as u64;
    if worst > tol {
        return Err(ServiceError::AccuracyUnsatisfiable(format!(
            "'{id}': requested tolerance {tol:.3e}, best residual {worst:.3e} after exact fallback"
        )));
    }
    acc.residual = Some(worst);
    Ok((xs, acc))
}

/// One right-hand side on the prepared backend (XLA staged with native
/// fallback, or native outright).
fn solve_rhs(p: &Prepared, xla: &Option<XlaSolver>, b: &[f64]) -> Vec<f64> {
    match (p.backend, xla, &p.padded, &p.staged) {
        (Backend::Xla, Some(solver), Some(padded), Some(staged)) => solver
            .solve_staged(staged, padded, b)
            .unwrap_or_else(|_| p.native().solve(b)),
        _ => p.native().solve(b),
    }
}

/// Build a [`RegisterInfo`] from a preparation.
fn register_info(p: &Prepared, fresh: bool, source: AnalysisSource) -> RegisterInfo {
    let stats = &p.analysis.transform().stats;
    RegisterInfo {
        levels_before: stats.levels_before,
        levels_after: stats.levels_after,
        rows_rewritten: stats.rows_rewritten,
        backend: match p.backend {
            Backend::Native => "native",
            Backend::Xla => "xla",
        },
        plan: p.plan_name().to_string(),
        tuner_cache_hit: if fresh {
            p.tuned.as_ref().map(|t| t.cache_hit)
        } else {
            None
        },
        source,
        prepare_ms: p.prepare_time.as_secs_f64() * 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};

    fn cfg() -> Config {
        Config {
            workers: 2,
            use_xla: false,
            ..Default::default()
        }
    }

    #[test]
    fn register_solve_update_through_the_trait() {
        let mut ex = InProcessExecutor::new(cfg());
        let m = generate::random_lower(120, 3, 0.8, &Default::default());
        let out = ex
            .register("m", m.clone(), &PlanSpec::parse("avgcost").unwrap())
            .unwrap();
        assert_eq!(out.nrows, 120);
        assert_eq!(out.info.source, AnalysisSource::Fresh);
        assert!(out.analysis_cache_hit.is_none(), "no cache configured");

        let b = vec![1.0; 120];
        let sol = ex.solve_block("m", &[b.clone(), b.clone()], None).unwrap();
        assert_eq!(sol.xs.len(), 2);
        assert!(!sol.batched, "native path");
        assert!(m.residual_inf(&sol.xs[0], &b) < 1e-9);
        assert_eq!(sol.residual, None, "no tolerance, no residual check");
        assert_eq!(sol.fallbacks_to_exact, 0);

        // Same-id re-registration is memoized, not a fresh tuner call.
        let again = ex
            .register("m", m.clone(), &PlanSpec::parse("avgcost").unwrap())
            .unwrap();
        assert_eq!(again.info.source, AnalysisSource::Memoized);
        assert!(again.tuned.is_none());

        // Value refresh pays exactly one more renumeric pass.
        let before = ex.rebuild_counters().renumeric_passes;
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v *= 2.0;
        }
        let up = ex.update_values("m", m2.clone()).unwrap();
        assert_eq!(up.info.source, AnalysisSource::Refreshed);
        assert_eq!(ex.rebuild_counters().renumeric_passes, before + 1);
        let sol = ex.solve_block("m", &[b.clone()], None).unwrap();
        assert!(m2.residual_inf(&sol.xs[0], &b) < 1e-9);

        assert!(matches!(
            ex.solve_block("nope", &[b], None),
            Err(ServiceError::NotRegistered(_))
        ));
        assert!(matches!(
            ex.update_values("nope", m),
            Err(ServiceError::NotRegistered(_))
        ));
    }

    #[test]
    fn gauges_fold_schedule_stats() {
        let mut ex = InProcessExecutor::new(cfg());
        let m = generate::lung2_like(&GenOptions::with_scale(0.05));
        ex.register("s", m.clone(), &PlanSpec::parse("avgcost+scheduled").unwrap())
            .unwrap();
        let b = vec![1.0; m.nrows];
        ex.solve_block("s", &[b], None).unwrap();
        let g = ex.gauges();
        assert!(g.sched_blocks > 0);
        assert_eq!(g.shard_respawns, 0);
        assert!(g.rebuilds.rewrite_passes >= 1);
    }

    #[test]
    fn accuracy_ladder_escalates_sticky_then_serves() {
        // 1 starting sweep on a 60-level chain: the ladder must climb to
        // certify, and the certified budget sticks for the next solve.
        let mut ex = InProcessExecutor::new(cfg());
        let m = generate::tridiagonal(120, &Default::default()); // 120-level chain
        ex.register("j", m.clone(), &PlanSpec::parse("none+jacobi:1").unwrap())
            .unwrap();
        let b = vec![1.0; 120];
        let sol = ex.solve_block("j", &[b.clone()], Some(1e-10)).unwrap();
        let r = sol.residual.expect("toleranced solve reports its residual");
        assert!(r <= 1e-10, "certified residual {r:.3e}");
        assert!(m.residual_inf(&sol.xs[0], &b) < 1e-8);
        assert!(sol.sweep_escalations > 0, "1 sweep cannot certify 120 levels");
        assert_eq!(sol.fallbacks_to_exact, 0, "the ladder certified in-budget");
        // Second solve starts at the sticky budget: zero new escalations.
        let again = ex.solve_block("j", &[b.clone()], Some(1e-10)).unwrap();
        assert_eq!(again.sweep_escalations, 0, "budget is sticky per matrix");
        assert!(again.residual.unwrap() <= 1e-10);
        // No tolerance = no certification = exact fallback, still correct.
        let exact = ex.solve_block("j", &[b.clone()], None).unwrap();
        assert_eq!(exact.fallbacks_to_exact, 1);
        assert_eq!(exact.residual, None);
        assert!(m.residual_inf(&exact.xs[0], &b) < 1e-12);
    }

    #[test]
    fn capped_ladder_falls_back_to_exact() {
        // Cap the budget below the nilpotency index: the ladder cannot
        // certify and must serve the batch via the exact fallback.
        let mut ex = InProcessExecutor::new(Config {
            jacobi_max_sweeps: 2,
            ..cfg()
        });
        let m = generate::tridiagonal(200, &Default::default());
        ex.register("j", m.clone(), &PlanSpec::parse("none+jacobi:1").unwrap())
            .unwrap();
        let b = vec![1.0; 200];
        let sol = ex
            .solve_block("j", &[b.clone(), b.clone()], Some(1e-12))
            .unwrap();
        assert_eq!(sol.fallbacks_to_exact, 2, "both right-hand sides fell back");
        assert!(sol.residual.unwrap() <= 1e-12, "exact fallback certifies");
        for x in &sol.xs {
            assert!(m.residual_inf(x, &b) < 1e-12);
        }
        // residual_check off: toleranced iterative solves skip straight
        // to the exact fallback instead of serving uncertified answers.
        let mut ex = InProcessExecutor::new(Config {
            residual_check: false,
            ..cfg()
        });
        ex.register("j", m.clone(), &PlanSpec::parse("none+jacobi:1").unwrap())
            .unwrap();
        let sol = ex.solve_block("j", &[b.clone()], Some(1e-8)).unwrap();
        assert_eq!(sol.fallbacks_to_exact, 1);
        assert_eq!(sol.residual, None, "nothing was measured");
        assert!(m.residual_inf(&sol.xs[0], &b) < 1e-12);
    }

    #[test]
    fn exact_backend_certifies_or_rejects_tolerance() {
        let mut ex = InProcessExecutor::new(cfg());
        let m = generate::random_lower(100, 3, 0.8, &Default::default());
        ex.register("e", m.clone(), &PlanSpec::parse("avgcost").unwrap())
            .unwrap();
        let b = vec![1.0; 100];
        let sol = ex.solve_block("e", &[b.clone()], Some(1e-8)).unwrap();
        assert!(sol.residual.unwrap() <= 1e-8, "exact path reports residual");
        assert_eq!(sol.fallbacks_to_exact, 0);
        assert_eq!(sol.sweep_escalations, 0);
        // A tolerance below what f64 arithmetic can deliver is a typed
        // failure, not a silently wrong answer.
        assert!(matches!(
            ex.solve_block("e", &[b.clone()], Some(1e-300)),
            Err(ServiceError::AccuracyUnsatisfiable(_))
        ));
    }
}
