//! Wire protocol between the shard supervisor and `shard-worker` child
//! processes: 4-byte big-endian length-prefixed JSON frames over the
//! child's stdin/stdout, reusing the crate's own [`Json`] reader/writer.
//!
//! Requests are objects tagged with an `"op"` key (`register`, `update`,
//! `solve`, `gauges`, `shutdown`); every response carries `"ok"` —
//! `false` responses map back to a typed [`ServiceError`] via a `"kind"`
//! discriminant so shard-side admission errors (not-registered, invalid
//! request) survive the hop instead of collapsing into `Backend`.
//!
//! Framing and the frame codec are generic over `Read`/`Write` so the
//! whole protocol — including the worker's serve loop — unit-tests over
//! in-memory buffers without spawning a process.

use std::io::{self, Read, Write};

use crate::analysis::BuildCounters;
use crate::coordinator::{AnalysisSource, RegisterInfo};
use crate::error::ServiceError;
use crate::sparse::Csr;
use crate::trace::{PhaseTimes, PhaseTotals};
use crate::util::json::Json;

use super::{ExecGauges, RegisterOutcome, SolveOutcome};

/// Upper bound on a single frame; a length prefix beyond this is treated
/// as stream corruption rather than an allocation request.
const MAX_FRAME: u32 = 1 << 30;

/// Write one length-prefixed frame and flush (the reader on the other
/// side blocks on the full frame, so every write must flush).
pub fn write_frame<W: Write>(w: &mut W, msg: &Json) -> io::Result<()> {
    let body = msg.to_string();
    let len = u32::try_from(body.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame too large"))?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Read one frame. `Ok(None)` is a clean EOF at a frame boundary (the
/// peer closed the stream); EOF mid-frame or an unparseable body is an
/// error.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Json>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_be_bytes(len);
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds limit"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    let text = String::from_utf8(body)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    Json::parse(&text)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

pub fn register_req(op: &str, id: &str, m: &Csr, plan: &str) -> Json {
    Json::obj(vec![
        ("op", Json::Str(op.to_string())),
        ("id", Json::Str(id.to_string())),
        ("plan", Json::Str(plan.to_string())),
        ("matrix", csr_to_json(m)),
    ])
}

pub fn solve_req(id: &str, rhs: &[Vec<f64>], tolerance: Option<f64>) -> Json {
    Json::obj(vec![
        ("op", Json::Str("solve".to_string())),
        ("id", Json::Str(id.to_string())),
        ("rhs", Json::Arr(rhs.iter().map(|b| num_arr(b)).collect())),
        (
            "tol",
            match tolerance {
                Some(t) => Json::Num(t),
                None => Json::Null,
            },
        ),
    ])
}

pub fn gauges_req() -> Json {
    Json::obj(vec![("op", Json::Str("gauges".to_string()))])
}

pub fn shutdown_req() -> Json {
    Json::obj(vec![("op", Json::Str("shutdown".to_string()))])
}

// ---------------------------------------------------------------------
// Matrix codec
// ---------------------------------------------------------------------

pub fn csr_to_json(m: &Csr) -> Json {
    Json::obj(vec![
        ("nrows", Json::Num(m.nrows as f64)),
        ("ncols", Json::Num(m.ncols as f64)),
        (
            "indptr",
            Json::Arr(m.indptr.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        (
            "indices",
            Json::Arr(m.indices.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        ("data", num_arr(&m.data)),
    ])
}

pub fn csr_from_json(j: &Json) -> Result<Csr, String> {
    let nrows = j
        .get("nrows")
        .and_then(Json::as_usize)
        .ok_or("matrix missing nrows")?;
    let ncols = j
        .get("ncols")
        .and_then(Json::as_usize)
        .ok_or("matrix missing ncols")?;
    let indptr: Vec<usize> = usize_vec(j.get("indptr")).ok_or("matrix missing indptr")?;
    let indices: Vec<u32> = usize_vec(j.get("indices"))
        .ok_or("matrix missing indices")?
        .into_iter()
        .map(|v| v as u32)
        .collect();
    let data = f64_vec(j.get("data")).ok_or("matrix missing data")?;
    Csr::new(nrows, ncols, indptr, indices, data).map_err(|e| e.to_string())
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

/// Encode a service error for the wire.
pub fn err_response(e: &ServiceError) -> Json {
    let kind = match e {
        ServiceError::NotRegistered(_) => "not_registered",
        ServiceError::InvalidRequest(_) => "invalid",
        ServiceError::AccuracyUnsatisfiable(_) => "accuracy",
        _ => "backend",
    };
    let msg = match e {
        ServiceError::NotRegistered(id) => id.clone(),
        ServiceError::AccuracyUnsatisfiable(m) => m.clone(),
        other => other.to_string(),
    };
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::Str(kind.to_string())),
        ("err", Json::Str(msg)),
    ])
}

/// Decode a `"ok":false` response back to the typed error.
pub fn response_error(j: &Json) -> ServiceError {
    let msg = j
        .get("err")
        .and_then(Json::as_str)
        .unwrap_or("malformed shard error")
        .to_string();
    match j.get("kind").and_then(Json::as_str) {
        Some("not_registered") => ServiceError::NotRegistered(msg),
        Some("invalid") => ServiceError::InvalidRequest(msg),
        Some("accuracy") => ServiceError::AccuracyUnsatisfiable(msg),
        _ => ServiceError::Backend(msg),
    }
}

/// Encode a registration outcome plus the worker's cumulative
/// structural-pass counters (the supervisor tracks them per generation
/// so totals stay monotone across respawns).
pub fn register_response(out: &RegisterOutcome, rebuilds: BuildCounters) -> Json {
    let info = &out.info;
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "info",
            Json::obj(vec![
                ("levels_before", Json::Num(info.levels_before as f64)),
                ("levels_after", Json::Num(info.levels_after as f64)),
                ("rows_rewritten", Json::Num(info.rows_rewritten as f64)),
                ("backend", Json::Str(info.backend.to_string())),
                ("plan", Json::Str(info.plan.clone())),
                ("tuner_cache_hit", opt_bool(info.tuner_cache_hit)),
                ("source", Json::Str(info.source.as_str().to_string())),
                ("prepare_ms", Json::Num(info.prepare_ms)),
            ]),
        ),
        ("nrows", Json::Num(out.nrows as f64)),
        (
            "phase_us",
            u64_arr(&[
                out.phase_times.rewrite_us,
                out.phase_times.coarsen_us,
                out.phase_times.placement_us,
                out.phase_times.renumeric_us,
            ]),
        ),
        (
            "tuned",
            match &out.tuned {
                Some((plan, hit)) => {
                    Json::Arr(vec![Json::Str(plan.clone()), Json::Bool(*hit)])
                }
                None => Json::Null,
            },
        ),
        (
            "acache_hit",
            match out.analysis_cache_hit {
                Some(h) => Json::Bool(h),
                None => Json::Null,
            },
        ),
        ("rebuilds", counters_arr(rebuilds)),
    ])
}

/// Decode a registration response. Returns the outcome plus the worker's
/// cumulative rebuild counters.
pub fn register_from_response(j: &Json) -> Result<(RegisterOutcome, BuildCounters), String> {
    let info = j.get("info").ok_or("response missing info")?;
    let backend: &'static str = match info.get("backend").and_then(Json::as_str) {
        Some("xla") => "xla",
        _ => "native",
    };
    let source = match info.get("source").and_then(Json::as_str) {
        Some("disk-cache") => AnalysisSource::DiskCache,
        Some("refreshed") => AnalysisSource::Refreshed,
        Some("memoized") => AnalysisSource::Memoized,
        _ => AnalysisSource::Fresh,
    };
    let phase = u64_vec(j.get("phase_us")).ok_or("response missing phase_us")?;
    if phase.len() != 4 {
        return Err("phase_us must have 4 entries".to_string());
    }
    let tuned = match j.get("tuned") {
        Some(Json::Arr(a)) if a.len() == 2 => {
            let plan = a[0].as_str().ok_or("tuned plan must be a string")?;
            let Json::Bool(hit) = a[1] else {
                return Err("tuned hit must be a bool".to_string());
            };
            Some((plan.to_string(), hit))
        }
        _ => None,
    };
    let acache_hit = match j.get("acache_hit") {
        Some(Json::Bool(h)) => Some(*h),
        _ => None,
    };
    let out = RegisterOutcome {
        info: RegisterInfo {
            levels_before: get_usize(info, "levels_before")?,
            levels_after: get_usize(info, "levels_after")?,
            rows_rewritten: get_usize(info, "rows_rewritten")?,
            backend,
            plan: info
                .get("plan")
                .and_then(Json::as_str)
                .ok_or("info missing plan")?
                .to_string(),
            tuner_cache_hit: match info.get("tuner_cache_hit") {
                Some(Json::Bool(h)) => Some(*h),
                _ => None,
            },
            source,
            prepare_ms: info
                .get("prepare_ms")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        },
        nrows: get_usize(j, "nrows")?,
        phase_times: PhaseTimes {
            rewrite_us: phase[0],
            coarsen_us: phase[1],
            placement_us: phase[2],
            renumeric_us: phase[3],
        },
        tuned,
        analysis_cache_hit: acache_hit,
    };
    let rebuilds = counters_from(j.get("rebuilds")).ok_or("response missing rebuilds")?;
    Ok((out, rebuilds))
}

pub fn solve_response(out: &SolveOutcome) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("xs", Json::Arr(out.xs.iter().map(|x| num_arr(x)).collect())),
        ("batched", Json::Bool(out.batched)),
        (
            "elastic",
            u64_arr(&[out.elastic.0, out.elastic.1, out.elastic.2]),
        ),
        ("trace", opt_totals(&out.trace)),
        (
            "residual",
            match out.residual {
                Some(r) => Json::Num(r),
                None => Json::Null,
            },
        ),
        (
            "accuracy",
            u64_arr(&[
                out.fallbacks_to_exact,
                out.sweep_escalations,
                out.residual_us,
            ]),
        ),
    ])
}

pub fn solve_from_response(j: &Json) -> Result<SolveOutcome, String> {
    let xs = j
        .get("xs")
        .and_then(Json::as_arr)
        .ok_or("response missing xs")?
        .iter()
        .map(|x| f64_vec(Some(x)).ok_or("xs row must be numeric"))
        .collect::<Result<Vec<_>, _>>()?;
    let batched = matches!(j.get("batched"), Some(Json::Bool(true)));
    let e = u64_vec(j.get("elastic")).ok_or("response missing elastic")?;
    if e.len() != 3 {
        return Err("elastic must have 3 entries".to_string());
    }
    // Accuracy fields default to "nothing measured" so frames from a
    // worker predating the inexact tier still decode.
    let acc = u64_vec(j.get("accuracy")).unwrap_or_default();
    let acc3 = |i: usize| acc.get(i).copied().unwrap_or(0);
    Ok(SolveOutcome {
        xs,
        batched,
        elastic: (e[0], e[1], e[2]),
        trace: totals_from(j.get("trace")),
        residual: j.get("residual").and_then(Json::as_f64),
        fallbacks_to_exact: acc3(0),
        sweep_escalations: acc3(1),
        residual_us: acc3(2),
    })
}

/// Encode the worker's gauges (the shard-health fields stay supervisor-
/// side and are always zero here).
pub fn gauges_response(g: &ExecGauges) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("sched_blocks", Json::Num(g.sched_blocks as f64)),
        ("sched_cut", Json::Num(g.sched_cut as f64)),
        (
            "elastic",
            u64_arr(&[g.elastic_waits, g.elastic_ooo, g.elastic_steals]),
        ),
        ("rebuilds", counters_arr(g.rebuilds)),
        (
            "trace",
            Json::Obj(
                g.trace_totals
                    .iter()
                    .map(|(id, t)| (id.clone(), u64_arr(&t.to_array())))
                    .collect(),
            ),
        ),
    ])
}

pub fn gauges_from_response(j: &Json) -> Result<ExecGauges, String> {
    let e = u64_vec(j.get("elastic")).ok_or("response missing elastic")?;
    if e.len() != 3 {
        return Err("elastic must have 3 entries".to_string());
    }
    let mut trace_totals = Vec::new();
    if let Some(Json::Obj(map)) = j.get("trace") {
        for (id, arr) in map {
            let t = totals_from(Some(arr))
                .ok_or_else(|| format!("gauges trace for '{id}' is malformed"))?;
            trace_totals.push((id.clone(), t));
        }
    }
    Ok(ExecGauges {
        sched_blocks: get_u64(j, "sched_blocks")?,
        sched_cut: get_u64(j, "sched_cut")?,
        elastic_waits: e[0],
        elastic_ooo: e[1],
        elastic_steals: e[2],
        rebuilds: counters_from(j.get("rebuilds")).ok_or("response missing rebuilds")?,
        trace_totals,
        ..ExecGauges::default()
    })
}

pub fn ok_response() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

/// The worker's final frame: acknowledges a `shutdown` request after all
/// earlier requests have been answered, right before the worker exits.
/// The `bye` marker distinguishes it from in-flight solve/gauges replies
/// so the supervisor can drain the reply stream up to exactly this frame.
pub fn bye_response() -> Json {
    Json::obj(vec![("ok", Json::Bool(true)), ("bye", Json::Bool(true))])
}

pub fn is_ok(j: &Json) -> bool {
    matches!(j.get("ok"), Some(Json::Bool(true)))
}

/// Is this frame the worker's shutdown bye-ack?
pub fn is_bye(j: &Json) -> bool {
    is_ok(j) && matches!(j.get("bye"), Some(Json::Bool(true)))
}

// ---------------------------------------------------------------------
// Scalar helpers
// ---------------------------------------------------------------------

fn num_arr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn u64_arr(v: &[u64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn counters_arr(c: BuildCounters) -> Json {
    u64_arr(&[
        c.rewrite_passes,
        c.coarsen_passes,
        c.placement_passes,
        c.renumeric_passes,
    ])
}

fn counters_from(j: Option<&Json>) -> Option<BuildCounters> {
    let v = u64_vec(j)?;
    (v.len() == 4).then(|| BuildCounters {
        rewrite_passes: v[0],
        coarsen_passes: v[1],
        placement_passes: v[2],
        renumeric_passes: v[3],
    })
}

fn opt_totals(t: &Option<PhaseTotals>) -> Json {
    match t {
        Some(t) => u64_arr(&t.to_array()),
        None => Json::Null,
    }
}

/// Decode a [`PhaseTotals`] wire array; absent/null/malformed = `None`
/// (older workers simply do not send trace payloads).
fn totals_from(j: Option<&Json>) -> Option<PhaseTotals> {
    let v = u64_vec(j)?;
    let arr: [u64; PhaseTotals::WIRE_LEN] = v.try_into().ok()?;
    Some(PhaseTotals::from_array(arr))
}

fn opt_bool(b: Option<bool>) -> Json {
    match b {
        Some(v) => Json::Bool(v),
        None => Json::Null,
    }
}

pub(super) fn f64_vec(j: Option<&Json>) -> Option<Vec<f64>> {
    j?.as_arr()?.iter().map(Json::as_f64).collect()
}

fn u64_vec(j: Option<&Json>) -> Option<Vec<u64>> {
    j?.as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|n| n as u64))
        .collect()
}

fn usize_vec(j: Option<&Json>) -> Option<Vec<usize>> {
    j?.as_arr()?.iter().map(Json::as_usize).collect()
}

fn get_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("response missing {key}"))
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|n| n as u64)
        .ok_or_else(|| format!("response missing {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_and_eof_is_clean() {
        let mut buf = Vec::new();
        let a = register_req("register", "m1", &tiny(), "auto");
        let b = solve_req("m1", &[vec![1.0, 2.5], vec![3.0, -4.0]], Some(1e-8));
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), Some(a));
        assert_eq!(read_frame(&mut r).unwrap(), Some(b));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF");
        // EOF mid-frame is corruption, not a clean close.
        let mut trunc = Vec::new();
        write_frame(&mut trunc, &gauges_req()).unwrap();
        trunc.truncate(trunc.len() - 2);
        let mut r = Cursor::new(trunc);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn matrix_codec_roundtrips() {
        let m = tiny();
        let back = csr_from_json(&csr_to_json(&m)).unwrap();
        assert_eq!(back.nrows, m.nrows);
        assert_eq!(back.indptr, m.indptr);
        assert_eq!(back.indices, m.indices);
        assert_eq!(back.data, m.data);
        assert!(csr_from_json(&Json::obj(vec![("nrows", Json::Num(1.0))])).is_err());
    }

    #[test]
    fn error_kinds_survive_the_wire() {
        for e in [
            ServiceError::NotRegistered("m9".to_string()),
            ServiceError::InvalidRequest("bad rhs".to_string()),
            ServiceError::AccuracyUnsatisfiable("tol 1e-12, got 3e-9".to_string()),
            ServiceError::Backend("boom".to_string()),
        ] {
            let j = err_response(&e);
            assert!(!is_ok(&j));
            assert_eq!(response_error(&j), e);
        }
        // Untyped errors collapse to Backend with their display text.
        let j = err_response(&ServiceError::Shutdown);
        assert!(matches!(response_error(&j), ServiceError::Backend(_)));
    }

    #[test]
    fn bye_ack_is_distinguishable_from_ordinary_replies() {
        assert!(is_ok(&bye_response()));
        assert!(is_bye(&bye_response()));
        // Ordinary ok replies — including a solve response — are not byes,
        // so the supervisor's drain loop skips past them.
        assert!(!is_bye(&ok_response()));
        let solve = solve_response(&SolveOutcome {
            xs: vec![vec![1.0]],
            batched: false,
            elastic: (0, 0, 0),
            trace: None,
            residual: None,
            fallbacks_to_exact: 0,
            sweep_escalations: 0,
            residual_us: 0,
        });
        assert!(is_ok(&solve) && !is_bye(&solve));
        assert!(!is_bye(&err_response(&ServiceError::Shutdown)));
    }

    #[test]
    fn solve_and_gauges_responses_roundtrip() {
        let out = SolveOutcome {
            xs: vec![vec![1.0, 2.0], vec![-0.5, 1e-9]],
            batched: true,
            elastic: (7, 3, 2),
            trace: Some(PhaseTotals {
                execute_us: 340,
                spans: 1,
                elastic_waits: 7,
                elastic_ooo: 3,
                elastic_steals: 2,
                ..Default::default()
            }),
            residual: Some(4.2e-11),
            fallbacks_to_exact: 1,
            sweep_escalations: 3,
            residual_us: 55,
        };
        let back = solve_from_response(&solve_response(&out)).unwrap();
        assert_eq!(back.xs, out.xs);
        assert!(back.batched);
        assert_eq!(back.elastic, (7, 3, 2));
        assert_eq!(back.trace, out.trace, "worker trace delta crosses the wire");
        assert_eq!(back.residual, Some(4.2e-11), "residual crosses the wire");
        assert_eq!(back.fallbacks_to_exact, 1);
        assert_eq!(back.sweep_escalations, 3);
        assert_eq!(back.residual_us, 55);
        // A trace-less solve (in-process, or tracing off) stays None.
        let plain = SolveOutcome {
            trace: None,
            residual: None,
            ..out.clone()
        };
        let back = solve_from_response(&solve_response(&plain)).unwrap();
        assert_eq!(back.trace, None);
        assert_eq!(back.residual, None);
        // Frames from a pre-inexact worker (no accuracy keys) decode to
        // "nothing measured" instead of erroring.
        let mut legacy = solve_response(&plain);
        if let Json::Obj(map) = &mut legacy {
            map.retain(|(k, _)| k != "accuracy" && k != "residual");
        }
        let back = solve_from_response(&legacy).unwrap();
        assert_eq!(back.residual, None);
        assert_eq!(back.fallbacks_to_exact, 0);
        assert_eq!(back.residual_us, 0);

        let g = ExecGauges {
            sched_blocks: 12,
            sched_cut: 5,
            elastic_waits: 9,
            elastic_ooo: 4,
            elastic_steals: 1,
            rebuilds: BuildCounters {
                rewrite_passes: 2,
                coarsen_passes: 1,
                placement_passes: 1,
                renumeric_passes: 3,
            },
            trace_totals: vec![(
                "m1".to_string(),
                PhaseTotals {
                    execute_us: 900,
                    spans: 4,
                    elastic_waits: 9,
                    ..Default::default()
                },
            )],
            ..ExecGauges::default()
        };
        let back = gauges_from_response(&gauges_response(&g)).unwrap();
        assert_eq!(back.sched_blocks, 12);
        assert_eq!(back.sched_cut, 5);
        assert_eq!(
            (back.elastic_waits, back.elastic_ooo, back.elastic_steals),
            (9, 4, 1)
        );
        assert_eq!(back.rebuilds.coarsen_passes, 1);
        assert_eq!(back.rebuilds.renumeric_passes, 3);
        assert_eq!(back.shard_crashes, 0, "shard health is supervisor-side");
        assert_eq!(back.trace_totals, g.trace_totals, "per-matrix totals survive");
    }

    fn tiny() -> Csr {
        Csr::new(
            2,
            2,
            vec![0, 1, 3],
            vec![0, 0, 1],
            vec![2.0, -1.0, 4.0],
        )
        .unwrap()
    }
}
