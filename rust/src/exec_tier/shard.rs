//! The sharded executor: N child worker processes, each a hidden
//! `sptrsv shard-worker` running an [`super::InProcessExecutor`] behind
//! the stdio frame protocol, supervised from the service thread.
//!
//! * **Routing** — a matrix's home shard is a pure function of its
//!   structural fingerprint ([`super::rendezvous`]); value refreshes and
//!   solves follow the registration's home. Each shard gets its own
//!   `shard-<k>` subdirectory of the analysis/tuner cache roots, so
//!   shards share nothing at runtime.
//! * **Fault containment** — every request is a write + reply with a
//!   `shard_timeout_ms` deadline. A timeout, stream error or EOF marks
//!   the worker dead: it is killed, counted, respawned, and every
//!   matrix homed on it is re-registered from the supervisor's roster —
//!   against the shard's analysis-cache subdirectory when one is
//!   configured, so the respawn pays zero structural passes. The failed
//!   in-flight request surfaces as [`ServiceError::Backend`]; nothing
//!   ever hangs on a dead shard.
//! * **Planned shutdown drains** — `shutdown()` sends each worker the
//!   shutdown frame and reads replies until its bye-ack (in-flight work
//!   finishes first), bounded by `shard_timeout_ms`; a drained worker
//!   gets a clean `wait()`, only a deadline overrun is killed.
//! * **Monotone counters** — structural-pass and elastic counters are
//!   cumulative *per worker generation*; the supervisor retires a dead
//!   generation's last-seen values into running totals so the metrics
//!   snapshot never moves backwards across a respawn.
//!
//! The `chaos_kill_shard_after` config key kills the routed shard right
//! before the Nth solve dispatch — the deterministic crash the failure
//! tests and the CI chaos rerun are built on.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::error::ServiceError;
use crate::sparse::Csr;
use crate::trace::PhaseTotals;
use crate::transform::PlanSpec;
use crate::tuner::Fingerprint;
use crate::util::json::Json;

use super::{
    protocol, rendezvous, ExecGauges, Executor, RegisterOutcome, ShardLiveness, SolveOutcome,
};

struct Shard {
    child: Child,
    stdin: ChildStdin,
    /// frames (or the stream error that ended them) pumped by the
    /// reader thread; a disconnect means the worker's stdout closed
    rx: Receiver<std::io::Result<Json>>,
    /// last-seen cumulative counters for this worker generation
    last_rebuilds: crate::analysis::BuildCounters,
    last_elastic: (u64, u64, u64),
    /// last-seen cumulative per-matrix trace totals for this generation
    last_trace: BTreeMap<String, PhaseTotals>,
    /// when this generation last answered a frame (spawn time until then)
    last_reply: Instant,
    /// frames written but not yet answered (sticks at 1 on a hang until
    /// the crash path retires the generation)
    inflight: u64,
}

struct RosterEntry {
    matrix: Arc<Csr>,
    plan: String,
    shard: usize,
}

pub struct ShardPoolExecutor {
    cfg: Config,
    nshards: usize,
    /// `None` = down and respawn failed; requests answer Backend
    shards: Vec<Option<Shard>>,
    /// everything registered, by id: enough to rebuild a shard from
    /// scratch (or warm, via its analysis-cache subdirectory)
    roster: BTreeMap<String, RosterEntry>,
    crashes: u64,
    respawns: u64,
    reregistered: u64,
    /// counters retired from dead worker generations
    retired_rebuilds: crate::analysis::BuildCounters,
    retired_elastic: (u64, u64, u64),
    /// per-matrix trace totals retired from dead worker generations, so
    /// the cumulative totals handed to the coordinator never move
    /// backwards across a respawn
    retired_trace: BTreeMap<String, PhaseTotals>,
    /// solves left before the chaos hook kills the routed shard
    chaos_countdown: Option<usize>,
}

impl ShardPoolExecutor {
    pub fn start(cfg: Config, nshards: usize) -> Result<ShardPoolExecutor, ServiceError> {
        let mut shards = Vec::with_capacity(nshards);
        for k in 0..nshards {
            match spawn_shard(&cfg, k) {
                Ok(s) => shards.push(Some(s)),
                Err(e) => {
                    for s in shards.iter_mut().flatten() {
                        let _ = s.child.kill();
                        let _ = s.child.wait();
                    }
                    return Err(ServiceError::Backend(format!(
                        "spawning shard worker {k}: {e}"
                    )));
                }
            }
        }
        let chaos_countdown = (cfg.chaos_kill_shard_after > 0).then_some(cfg.chaos_kill_shard_after);
        Ok(ShardPoolExecutor {
            cfg,
            nshards,
            shards,
            roster: BTreeMap::new(),
            crashes: 0,
            respawns: 0,
            reregistered: 0,
            retired_rebuilds: Default::default(),
            retired_elastic: (0, 0, 0),
            retired_trace: BTreeMap::new(),
            chaos_countdown,
        })
    }

    /// One request/reply round trip against shard `k`. Any failure —
    /// down shard, broken pipe, stream error, timeout — comes back as a
    /// description for the crash path.
    fn call(&mut self, k: usize, req: &Json) -> Result<Json, String> {
        let timeout = Duration::from_millis(self.cfg.shard_timeout_ms.max(1));
        let Some(shard) = self.shards[k].as_mut() else {
            return Err(format!("shard {k} is down"));
        };
        if let Err(e) = protocol::write_frame(&mut shard.stdin, req) {
            return Err(format!("shard {k} write failed: {e}"));
        }
        shard.inflight += 1;
        match shard.rx.recv_timeout(timeout) {
            Ok(Ok(frame)) => {
                shard.inflight = shard.inflight.saturating_sub(1);
                shard.last_reply = Instant::now();
                Ok(frame)
            }
            Ok(Err(e)) => Err(format!("shard {k} stream error: {e}")),
            Err(RecvTimeoutError::Timeout) => Err(format!(
                "shard {k} unresponsive after {}ms",
                timeout.as_millis()
            )),
            Err(RecvTimeoutError::Disconnected) => Err(format!("shard {k} exited")),
        }
    }

    /// Kill + retire the dead worker, respawn it, and re-register its
    /// share of the roster. Counts every step for the metrics snapshot.
    fn crash(&mut self, k: usize, why: &str) {
        eprintln!("warning: shard {k} failed ({why}); respawning");
        self.crashes += 1;
        self.retire(k);
        match spawn_shard(&self.cfg, k) {
            Ok(s) => {
                self.shards[k] = Some(s);
                self.respawns += 1;
                self.reregister(k);
            }
            Err(e) => eprintln!("warning: shard {k} respawn failed: {e}"),
        }
    }

    /// Fold the dead generation's last-seen counters into the running
    /// totals and drop the process.
    fn retire(&mut self, k: usize) {
        if let Some(mut s) = self.shards[k].take() {
            let _ = s.child.kill();
            let _ = s.child.wait();
            self.retired_rebuilds = self.retired_rebuilds + s.last_rebuilds;
            self.retired_elastic.0 += s.last_elastic.0;
            self.retired_elastic.1 += s.last_elastic.1;
            self.retired_elastic.2 += s.last_elastic.2;
            for (id, t) in s.last_trace {
                let agg = self.retired_trace.entry(id).or_default();
                *agg = *agg + t;
            }
        }
    }

    /// Replay shard `k`'s roster into a fresh worker. With a configured
    /// analysis cache the shard's subdirectory still holds the analyses,
    /// so this is a warm load — zero coarsening/placement passes.
    fn reregister(&mut self, k: usize) {
        let ids: Vec<String> = self
            .roster
            .iter()
            .filter(|(_, e)| e.shard == k)
            .map(|(id, _)| id.clone())
            .collect();
        for id in ids {
            let (m, plan) = {
                let e = &self.roster[&id];
                (Arc::clone(&e.matrix), e.plan.clone())
            };
            let req = protocol::register_req("register", &id, &m, &plan);
            match self.call(k, &req) {
                Ok(resp) if protocol::is_ok(&resp) => {
                    if let Ok((_, rebuilds)) = protocol::register_from_response(&resp) {
                        if let Some(s) = self.shards[k].as_mut() {
                            s.last_rebuilds = rebuilds;
                        }
                    }
                    self.reregistered += 1;
                }
                Ok(resp) => eprintln!(
                    "warning: re-registering '{id}' on shard {k}: {}",
                    protocol::response_error(&resp)
                ),
                Err(why) => {
                    // The freshly respawned worker died too; give up on
                    // this shard instead of recursing into crash().
                    eprintln!("warning: shard {k} died re-registering '{id}' ({why})");
                    self.crashes += 1;
                    self.retire(k);
                    return;
                }
            }
        }
    }

    /// Shared call path: round trip, decode the ok flag, run the crash
    /// path on transport failure.
    fn request(&mut self, k: usize, req: &Json, what: &str) -> Result<Json, ServiceError> {
        match self.call(k, req) {
            Ok(resp) if protocol::is_ok(&resp) => Ok(resp),
            Ok(resp) => Err(protocol::response_error(&resp)),
            Err(why) => {
                self.crash(k, &why);
                Err(ServiceError::Backend(format!("{what}: {why}")))
            }
        }
    }
}

impl Executor for ShardPoolExecutor {
    fn register(
        &mut self,
        id: &str,
        m: Csr,
        spec: &PlanSpec,
    ) -> Result<RegisterOutcome, ServiceError> {
        let k = rendezvous::route(Fingerprint::of(&m), self.nshards);
        let plan = spec.as_str().to_string();
        let m = Arc::new(m);
        let req = protocol::register_req("register", id, &m, &plan);
        let resp = self.request(k, &req, "register")?;
        let (out, rebuilds) =
            protocol::register_from_response(&resp).map_err(ServiceError::Backend)?;
        if let Some(s) = self.shards[k].as_mut() {
            s.last_rebuilds = rebuilds;
        }
        self.roster.insert(
            id.to_string(),
            RosterEntry {
                matrix: m,
                plan,
                shard: k,
            },
        );
        Ok(out)
    }

    fn update_values(&mut self, id: &str, m: Csr) -> Result<RegisterOutcome, ServiceError> {
        let Some(k) = self.roster.get(id).map(|e| e.shard) else {
            return Err(ServiceError::NotRegistered(id.to_string()));
        };
        let m = Arc::new(m);
        let req = protocol::register_req("update", id, &m, "");
        let resp = self.request(k, &req, "update_values")?;
        let (out, rebuilds) =
            protocol::register_from_response(&resp).map_err(ServiceError::Backend)?;
        if let Some(s) = self.shards[k].as_mut() {
            s.last_rebuilds = rebuilds;
        }
        // A later crash must re-register the *refreshed* numerics.
        if let Some(e) = self.roster.get_mut(id) {
            e.matrix = m;
        }
        Ok(out)
    }

    fn solve_block(
        &mut self,
        id: &str,
        rhs: &[Vec<f64>],
        tolerance: Option<f64>,
    ) -> Result<SolveOutcome, ServiceError> {
        let Some(k) = self.roster.get(id).map(|e| e.shard) else {
            return Err(ServiceError::NotRegistered(id.to_string()));
        };
        // Deterministic fault injection for tests and the CI chaos
        // rerun: kill the routed worker right before dispatch.
        if let Some(n) = self.chaos_countdown {
            if n <= 1 {
                self.chaos_countdown = None;
                eprintln!("warning: chaos hook killing shard {k}");
                if let Some(s) = self.shards[k].as_mut() {
                    let _ = s.child.kill();
                }
            } else {
                self.chaos_countdown = Some(n - 1);
            }
        }
        let req = protocol::solve_req(id, rhs, tolerance);
        let resp = self.request(k, &req, "solve")?;
        protocol::solve_from_response(&resp).map_err(ServiceError::Backend)
    }

    fn gauges(&mut self) -> ExecGauges {
        let mut g = ExecGauges::default();
        for k in 0..self.nshards {
            if self.shards[k].is_none() {
                continue;
            }
            match self.call(k, &protocol::gauges_req()) {
                Ok(resp) if protocol::is_ok(&resp) => {
                    match protocol::gauges_from_response(&resp) {
                        Ok(sg) => {
                            g.sched_blocks += sg.sched_blocks;
                            g.sched_cut += sg.sched_cut;
                            if let Some(s) = self.shards[k].as_mut() {
                                s.last_rebuilds = sg.rebuilds;
                                s.last_elastic =
                                    (sg.elastic_waits, sg.elastic_ooo, sg.elastic_steals);
                                s.last_trace = sg.trace_totals.into_iter().collect();
                            }
                        }
                        Err(e) => eprintln!("warning: shard {k} gauges: {e}"),
                    }
                }
                Ok(resp) => eprintln!(
                    "warning: shard {k} gauges: {}",
                    protocol::response_error(&resp)
                ),
                Err(why) => self.crash(k, &why),
            }
        }
        g.rebuilds = self.retired_rebuilds;
        let (mut w, mut o, mut st) = self.retired_elastic;
        let mut trace: BTreeMap<String, PhaseTotals> = self.retired_trace.clone();
        for s in self.shards.iter().flatten() {
            g.rebuilds = g.rebuilds + s.last_rebuilds;
            w += s.last_elastic.0;
            o += s.last_elastic.1;
            st += s.last_elastic.2;
            for (id, t) in &s.last_trace {
                let agg = trace.entry(id.clone()).or_default();
                *agg = *agg + *t;
            }
        }
        g.elastic_waits = w;
        g.elastic_ooo = o;
        g.elastic_steals = st;
        g.trace_totals = trace.into_iter().collect();
        g.shard_crashes = self.crashes;
        g.shard_respawns = self.respawns;
        g.shard_reregistered = self.reregistered;
        g.shard_liveness = (0..self.nshards)
            .map(|k| match &self.shards[k] {
                Some(s) => ShardLiveness {
                    shard: k,
                    up: true,
                    last_frame_age_ms: s.last_reply.elapsed().as_millis() as u64,
                    inflight: s.inflight,
                },
                None => ShardLiveness {
                    shard: k,
                    up: false,
                    last_frame_age_ms: 0,
                    inflight: 0,
                },
            })
            .collect();
        g
    }

    fn shutdown(&mut self) {
        // Planned shutdown drains instead of killing: write the shutdown
        // frame, then keep reading replies — in-flight solves answer
        // first on the same channel, the worker's bye-ack is the final
        // frame — bounded by the same `shard_timeout_ms` deadline as any
        // other round trip. A worker that acks (or closes its stream) is
        // reaped with a clean `wait()`; only a deadline overrun is killed.
        let timeout = Duration::from_millis(self.cfg.shard_timeout_ms.max(1));
        for k in 0..self.nshards {
            let Some(mut s) = self.shards[k].take() else {
                continue;
            };
            let asked = protocol::write_frame(&mut s.stdin, &protocol::shutdown_req()).is_ok();
            let deadline = Instant::now() + timeout;
            let mut drained = false;
            while asked && !drained {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match s.rx.recv_timeout(left) {
                    // In-flight replies drain past; the bye-ack ends it.
                    Ok(Ok(frame)) => drained = protocol::is_bye(&frame),
                    // EOF without a bye still means the worker is gone.
                    Ok(Err(_)) | Err(RecvTimeoutError::Disconnected) => drained = true,
                    Err(RecvTimeoutError::Timeout) => break,
                }
            }
            if !drained {
                eprintln!(
                    "warning: shard {k} did not drain within {}ms; killing",
                    timeout.as_millis()
                );
                let _ = s.child.kill();
            }
            let _ = s.child.wait();
        }
    }
}

impl Drop for ShardPoolExecutor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Launch the hidden worker subcommand with the slice of the parent's
/// configuration a shard needs, giving it per-shard cache directories so
/// shards share nothing at runtime.
fn spawn_shard(cfg: &Config, k: usize) -> std::io::Result<Shard> {
    let bin = if cfg.shard_worker_bin.is_empty() {
        std::env::current_exe()?
    } else {
        std::path::PathBuf::from(&cfg.shard_worker_bin)
    };
    let mut cmd = Command::new(bin);
    cmd.arg("shard-worker")
        .arg("--workers")
        .arg(cfg.workers.to_string())
        .arg("--plan")
        .arg(cfg.plan.as_str())
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--use-xla")
        .arg(if cfg.use_xla { "true" } else { "false" })
        // Tracing crosses the process boundary: a traced coordinator
        // needs traced workers or trace_report is blind under sharding.
        .arg("--trace-enabled")
        .arg(if cfg.trace_enabled { "true" } else { "false" })
        .arg("--sched-block-target")
        .arg(cfg.sched_block_target.to_string())
        .arg("--sched-stale-window")
        .arg(cfg.sched_stale_window.to_string())
        .arg("--tuner-top-k")
        .arg(cfg.tuner_top_k.to_string())
        .arg("--tuner-race-solves")
        .arg(cfg.tuner_race_solves.to_string())
        .arg("--tuner-cache-ttl")
        .arg(cfg.tuner_cache_ttl.to_string())
        // Accuracy policy crosses the process boundary too: the worker's
        // executor runs the sweep ladder, so it needs the same budget
        // caps and certification toggles the coordinator was given.
        .arg("--default-tolerance")
        .arg(cfg.default_tolerance.to_string())
        .arg("--residual-check")
        .arg(if cfg.residual_check { "true" } else { "false" })
        .arg("--jacobi-max-sweeps")
        .arg(cfg.jacobi_max_sweeps.to_string());
    if !cfg.artifacts_dir.is_empty() {
        cmd.arg("--artifacts-dir").arg(&cfg.artifacts_dir);
    }
    if !cfg.tuner_cache.is_empty() {
        cmd.arg("--tuner-cache")
            .arg(format!("{}/shard-{k}", cfg.tuner_cache));
    }
    if !cfg.analysis_cache.is_empty() {
        cmd.arg("--analysis-cache")
            .arg(format!("{}/shard-{k}", cfg.analysis_cache))
            .arg("--analysis-cache-cap")
            .arg(cfg.analysis_cache_cap.to_string())
            .arg("--analysis-cache-ttl")
            .arg(cfg.analysis_cache_ttl.to_string())
            // The artifact format crosses the boundary too, so every
            // shard's cache subdirectory writes the same format the
            // coordinator was configured with.
            .arg("--analysis-format")
            .arg(cfg.analysis_format.as_str());
    }
    let mut child = cmd
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    let (tx, rx) = mpsc::channel();
    std::thread::Builder::new()
        .name(format!("shard-{k}-reader"))
        .spawn(move || {
            let mut r = BufReader::new(stdout);
            loop {
                match protocol::read_frame(&mut r) {
                    Ok(Some(frame)) => {
                        if tx.send(Ok(frame)).is_err() {
                            return;
                        }
                    }
                    // Clean EOF: drop the sender so recv sees Disconnected.
                    Ok(None) => return,
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
        })?;
    Ok(Shard {
        child,
        stdin,
        rx,
        last_rebuilds: Default::default(),
        last_elastic: (0, 0, 0),
        last_trace: BTreeMap::new(),
        last_reply: Instant::now(),
        inflight: 0,
    })
}
