//! Filesystem helpers for observability artifacts.
//!
//! The serving loop and the bench harness both publish JSON files that
//! other processes tail concurrently (`--metrics-json` is re-written on
//! every snapshot while a dashboard polls it; CI reads `BENCH_*.json`
//! the moment the bench exits). A plain `fs::write` truncates first and
//! fills in later, so a reader can observe an empty or half-written
//! file. [`write_atomic`] closes that window: write to a temp file in
//! the same directory, then `rename` over the target — readers see
//! either the old complete file or the new complete file, never a torn
//! one.

use std::io::Write;
use std::path::Path;

/// Atomically replace `path` with `contents`.
///
/// Writes `<path>.tmp.<pid>` in the same directory (rename is only
/// atomic within a filesystem) and renames it over `path`. The temp
/// file is removed on any failure.
pub fn write_atomic(path: &Path, contents: &str) -> std::io::Result<()> {
    let Some(file_name) = path.file_name().and_then(|n| n.to_str()) else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("write_atomic: no file name in {}", path.display()),
        ));
    };
    let tmp = path.with_file_name(format!("{file_name}.tmp.{}", std::process::id()));
    let res = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if res.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_replaces_content_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("sptrsv_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("out.json");

        write_atomic(&target, "{\"v\":1}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&target).unwrap(), "{\"v\":1}\n");
        // Overwrite: readers polling this path never see a truncated file.
        write_atomic(&target, "{\"v\":2}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&target).unwrap(), "{\"v\":2}\n");

        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files must not survive");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_rejects_pathless_targets() {
        assert!(write_atomic(Path::new(".."), "x").is_err());
    }
}
