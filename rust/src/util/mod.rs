//! Self-contained utility substrate.
//!
//! The build is fully offline against the vendored registry, which does not
//! carry `rand`, `serde`/`serde_json`, `clap`, `rayon` or `criterion`; the
//! pieces of those we need are implemented here instead:
//!
//! * [`rng`]   — deterministic SplitMix64 RNG (matrix generators, tests)
//! * [`json`]  — minimal JSON reader/writer (artifact manifest, reports)
//! * [`timer`] — measurement harness used by `cargo bench` benches
//! * [`prop`]  — tiny property-based-testing runner (seeded case sweeps)
//! * [`cli`]   — flag/positional argument parser for the `sptrsv` binary
//! * [`fs`]    — atomic file publication for metrics/bench artifacts

pub mod cli;
pub mod fs;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
