//! Minimal JSON reader/writer.
//!
//! Handles the artifact `manifest.json` produced by `python/compile/aot.py`
//! and the structured reports the benches emit. Not a general-purpose
//! parser: it supports objects, arrays, strings (with the common escapes),
//! f64 numbers, booleans and null — which is the entire grammar those files
//! use.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helper: object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = start + len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"[
          {"name": "step_r8_k2_n8192", "file": "step_r8_k2_n8192.hlo.txt",
           "entry": "level_step", "r": 8, "k": 2, "n": 8192}
        ]"#;
        let v = Json::parse(src).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("entry").unwrap().as_str().unwrap(), "level_step");
        assert_eq!(arr[0].get("n").unwrap().as_usize().unwrap(), 8192);
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Str("x\"y\n".into())),
            ("c", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-2.5e3").unwrap().as_f64(), Some(-2500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn nested_objects() {
        let v = Json::parse(r#"{"a": {"b": [1, 2, {"c": "d"}]}}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(inner[2].get("c").unwrap().as_str().unwrap(), "d");
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = Json::parse("\"\\u00e9 caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{e9} caf\u{e9}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
