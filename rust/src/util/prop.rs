//! Tiny property-based-testing runner (proptest is not in the vendored
//! registry).
//!
//! A property is a closure taking a seeded [`crate::util::rng::Rng`]; the
//! runner sweeps `cases` seeds and reports the first failing seed, so a
//! failure is reproducible by re-running with that seed. No shrinking —
//! generators are expected to scale case size with the seed index so early
//! failures are small.

use crate::util::rng::Rng;

/// Run `prop` for seeds `0..cases`. Panics with the failing seed on error.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng, u64) -> Result<(), String>,
{
    for case in 0..cases {
        // Decorrelate consecutive seeds.
        let mut rng = Rng::new(0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15)));
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

/// Assert two f64 slices are element-wise close (abs or rel tolerance).
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "mismatch at index {i}: {x} vs {y} (diff {:.3e}, tol {:.3e})",
                (x - y).abs(),
                tol
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("u64-roundtrip", 50, |rng, _| {
            let v = rng.next_u64();
            if v == v {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failure() {
        check("always-false", 3, |_, _| Err("boom".into()));
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-9, 0.0).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-9, 0.0).is_err());
        assert!(assert_allclose(&[0.0], &[1e-13], 0.0, 1e-12).is_ok());
    }
}
