//! Deterministic pseudo-random numbers (SplitMix64).
//!
//! All synthetic matrix generation and property tests run off this RNG so
//! every experiment in EXPERIMENTS.md is exactly reproducible from a seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; most
/// importantly fully deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free is overkill; modulo bias is negligible
        // for our n << 2^64 but we keep the standard widening trick anyway.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// k distinct values sampled from [0, n), ascending. k <= n.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        if k == 0 {
            return Vec::new();
        }
        // Floyd's algorithm: O(k) expected, no O(n) allocation.
        let mut chosen = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn sample_distinct_is_distinct_sorted() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let k = r.range(1, 20);
            let s = r.sample_distinct(50, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&v| v < 50));
        }
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(8, 8);
        assert_eq!(s, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1_000 {
            let v = r.uniform(-2.5, 3.5);
            assert!((-2.5..3.5).contains(&v));
        }
    }
}
