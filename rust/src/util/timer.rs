//! Measurement harness for the `cargo bench` benches (criterion is not in
//! the vendored registry, so the benches use `harness = false` plus this).
//!
//! Reports min / median / mean / p95 over a fixed wall-clock budget with a
//! warmup phase, and offers a text-table printer used by the Table I /
//! figure harnesses.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Measurement {
    pub fn throughput_per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} iters={:<6} min={:>12?} median={:>12?} mean={:>12?} p95={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.p95
        )
    }
}

/// Benchmark `f`, first warming up for `warmup`, then sampling for at least
/// `budget` wall-clock time (at least 3 iterations regardless).
pub fn bench_with<F: FnMut()>(
    name: &str,
    warmup: Duration,
    budget: Duration,
    mut f: F,
) -> Measurement {
    // Warmup.
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        f();
    }
    // Sample.
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_unstable();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let p95_idx = ((iters as f64 * 0.95) as usize).min(iters - 1);
    Measurement {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[iters / 2],
        mean: total / iters as u32,
        p95: samples[p95_idx],
    }
}

/// Benchmark with default warmup (0.2 s) and budget (1 s), printing the
/// measurement as it completes.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Measurement {
    let m = bench_with(name, Duration::from_millis(200), Duration::from_secs(1), f);
    println!("{m}");
    m
}

/// Fixed-width text table used by the report harnesses.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        for (c, h) in self.headers.iter().enumerate() {
            out.push_str(&format!("| {:<width$} ", h, width = widths[c]));
        }
        out.push_str("|\n");
        sep(&mut out);
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                out.push_str(&format!("| {:<width$} ", cell, width = widths[c]));
            }
            out.push_str("|\n");
        }
        sep(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_stats() {
        let mut x = 0u64;
        let m = bench_with(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                x = x.wrapping_add(1);
                std::hint::black_box(x);
            },
        );
        assert!(m.iters >= 3);
        assert!(m.min <= m.median);
        assert!(m.median <= m.p95 || m.iters < 20);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["metric", "value"]);
        t.row(&["num. of levels".into(), "479".into()]);
        t.row(&["avg".into(), "914.054".into()]);
        let s = t.render();
        assert!(s.contains("| num. of levels | 479"));
        let first = s.lines().next().unwrap().len();
        assert!(s.lines().all(|l| l.len() == first));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
