//! Flag/positional argument parser for the `sptrsv` binary (clap is not in
//! the vendored registry).
//!
//! Grammar: `sptrsv <subcommand> [positionals] [--flag[=value] | --flag value]`.
//! Flags may appear anywhere after the subcommand; `--` ends flag parsing.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut it = raw.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut positionals = Vec::new();
        let mut flags = BTreeMap::new();
        let mut no_more_flags = false;
        while let Some(a) = it.next() {
            if no_more_flags || !a.starts_with("--") {
                positionals.push(a);
                continue;
            }
            if a == "--" {
                no_more_flags = true;
                continue;
            }
            let body = &a[2..];
            if let Some(eq) = body.find('=') {
                flags.insert(body[..eq].to_string(), body[eq + 1..].to_string());
            } else {
                // `--flag value` when the next token isn't itself a flag,
                // `--flag` (boolean) otherwise.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        flags.insert(body.to_string(), v);
                    }
                    _ => {
                        flags.insert(body.to_string(), "true".to_string());
                    }
                }
            }
        }
        Args {
            subcommand,
            positionals,
            flags,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["solve", "matrix.mtx", "out.txt"]);
        assert_eq!(a.subcommand, "solve");
        assert_eq!(a.positionals, vec!["matrix.mtx", "out.txt"]);
    }

    #[test]
    fn flag_forms() {
        let a = parse(&["gen", "--kind=lung2", "--n", "1000", "--verbose"]);
        assert_eq!(a.flag("kind"), Some("lung2"));
        assert_eq!(a.usize_flag("n", 0).unwrap(), 1000);
        assert!(a.bool_flag("verbose"));
        assert!(!a.bool_flag("quiet"));
    }

    #[test]
    fn double_dash_ends_flags() {
        let a = parse(&["x", "--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.flag("a"), Some("1"));
        assert_eq!(a.positionals, vec!["--not-a-flag"]);
    }

    #[test]
    fn numeric_flag_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_flag("n", 0).is_err());
        assert!(a.f64_flag("n", 0.0).is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse(&["x", "--fast", "--n", "3"]);
        assert!(a.bool_flag("fast"));
        assert_eq!(a.usize_flag("n", 0).unwrap(), 3);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.flag_or("mode", "auto"), "auto");
        assert_eq!(a.f64_flag("alpha", 2.5).unwrap(), 2.5);
    }
}
