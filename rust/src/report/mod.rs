//! Report harnesses regenerating the paper's evaluation artifacts:
//! Table I ([`table1`]) and the per-level cost series of Figs. 5/6
//! ([`figures`]). The criterion-style wall-clock benches live in
//! `rust/benches/`; these modules produce the *content* of the table and
//! figures so benches, examples and the CLI share one implementation.

pub mod figures;
pub mod table1;
