//! Figs. 5 and 6: per-level cost series for the three strategies, plus
//! the CSV emitter the plotting harness (`examples/figures.rs`,
//! `cargo bench --bench figures`) uses.

use crate::sparse::Csr;
use crate::transform::Rewrite;

#[derive(Debug, Clone)]
pub struct Series {
    pub strategy: String,
    pub level_costs: Vec<u64>,
    pub avg_level_cost: f64,
    pub max_level_cost: u64,
}

/// Compute the three series for one matrix.
pub fn series(m: &Csr) -> Vec<Series> {
    [
        ("no-rewriting", Rewrite::None),
        ("avgLevelCost", Rewrite::AvgLevelCost(Default::default())),
        ("manual", Rewrite::Manual(Default::default())),
    ]
    .iter()
    .map(|(name, s)| {
        let t = s.apply(m);
        let level_costs = t.level_costs();
        let max = level_costs.iter().copied().max().unwrap_or(0);
        Series {
            strategy: name.to_string(),
            avg_level_cost: t.stats.total_level_cost_after as f64
                / level_costs.len().max(1) as f64,
            max_level_cost: max,
            level_costs,
        }
    })
    .collect()
}

/// CSV: `strategy,level,cost` rows (long format, one file per figure).
pub fn to_csv(series: &[Series]) -> String {
    let mut out = String::from("strategy,level,cost\n");
    for s in series {
        for (l, &c) in s.level_costs.iter().enumerate() {
            out.push_str(&format!("{},{},{}\n", s.strategy, l, c));
        }
    }
    out
}

/// Terminal sparkline rendering of a series (log scale like Fig 5 when
/// `log` is set; linear clipped at `clip` like Fig 6 otherwise).
pub fn sparkline(costs: &[u64], width: usize, log: bool, clip: Option<u64>) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if costs.is_empty() {
        return String::new();
    }
    // Downsample to `width` buckets by max.
    let w = width.min(costs.len()).max(1);
    let mut buckets = vec![0u64; w];
    for (i, &c) in costs.iter().enumerate() {
        let b = i * w / costs.len();
        let c = clip.map_or(c, |cl| c.min(cl));
        buckets[b] = buckets[b].max(c);
    }
    let xform = |v: u64| -> f64 {
        if log {
            (v.max(1) as f64).ln()
        } else {
            v as f64
        }
    };
    let max = buckets.iter().map(|&v| xform(v)).fold(0.0, f64::max).max(1e-9);
    buckets
        .iter()
        .map(|&v| GLYPHS[((xform(v) / max) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    #[test]
    fn series_shapes_match_table() {
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.05));
        let ss = series(&m);
        assert_eq!(ss.len(), 3);
        assert!(ss[1].level_costs.len() < ss[0].level_costs.len());
        // Fat bumps survive every strategy (paper: "the bumps are the
        // same"): max level cost of the originals persists or grows.
        assert!(ss[1].max_level_cost >= ss[0].max_level_cost);
    }

    #[test]
    fn csv_format() {
        let m = generate::tridiagonal(20, &Default::default());
        let ss = series(&m);
        let csv = to_csv(&ss);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "strategy,level,cost");
        assert!(csv.contains("no-rewriting,0,"));
        let rows = csv.lines().count() - 1;
        let expect: usize = ss.iter().map(|s| s.level_costs.len()).sum();
        assert_eq!(rows, expect);
    }

    #[test]
    fn sparkline_renders() {
        let s = sparkline(&[1, 2, 4, 8, 1000, 2, 1], 7, true, None);
        assert_eq!(s.chars().count(), 7);
        let clipped = sparkline(&[10, 8000, 20000], 3, false, Some(8000));
        assert_eq!(clipped.chars().count(), 3);
        assert_eq!(sparkline(&[], 10, false, None), "");
    }
}
