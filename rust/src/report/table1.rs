//! Table I: comparison of strategies on lung2 / torso2 analogs.
//!
//! For each (matrix, strategy) cell we compute the paper's five metrics —
//! number of levels, average level cost, total level cost, generated-code
//! size, rows rewritten — and render them next to the published values.

use crate::codegen::{self, CodegenOptions};
use crate::sparse::Csr;
use crate::transform::{Rewrite, TransformResult};
use crate::util::timer::Table;

/// One Table I cell (a strategy applied to a matrix).
#[derive(Debug, Clone)]
pub struct Cell {
    pub strategy: String,
    pub num_levels: usize,
    pub avg_level_cost: f64,
    pub total_level_cost: u64,
    pub code_size_mb: f64,
    pub rows_rewritten: usize,
    pub nrows: usize,
}

/// Published Table I values for the shape comparison printed alongside.
#[derive(Debug, Clone, Copy)]
pub struct PaperCell {
    pub num_levels: usize,
    pub avg_level_cost: f64,
    pub total_level_cost: u64,
    pub code_size_mb: Option<f64>,
    pub rows_rewritten: Option<usize>,
}

pub const PAPER_LUNG2: [(&str, PaperCell); 3] = [
    ("no-rewriting", PaperCell { num_levels: 479, avg_level_cost: 914.054, total_level_cost: 437_834, code_size_mb: Some(9.7), rows_rewritten: None }),
    ("avgLevelCost", PaperCell { num_levels: 23, avg_level_cost: 18_938.06, total_level_cost: 435_588, code_size_mb: Some(8.6), rows_rewritten: Some(1304) }),
    ("manual", PaperCell { num_levels: 67, avg_level_cost: 6520.42, total_level_cost: 436_868, code_size_mb: Some(9.5), rows_rewritten: Some(898) }),
];

pub const PAPER_TORSO2: [(&str, PaperCell); 3] = [
    ("no-rewriting", PaperCell { num_levels: 513, avg_level_cost: 2014.559, total_level_cost: 1_035_484, code_size_mb: Some(21.0), rows_rewritten: None }),
    ("avgLevelCost", PaperCell { num_levels: 341, avg_level_cost: 3086.443, total_level_cost: 1_052_477, code_size_mb: Some(21.0), rows_rewritten: Some(14_655) }),
    ("manual", PaperCell { num_levels: 284, avg_level_cost: 5070.183, total_level_cost: 1_439_932, code_size_mb: None, rows_rewritten: Some(18_147) }),
];

/// Compute one cell. `with_codegen` controls whether the (expensive)
/// code-size metric is materialized.
pub fn cell(m: &Csr, strategy: &Rewrite, with_codegen: bool) -> (Cell, TransformResult) {
    let t = strategy.apply(m);
    let code_size_mb = if with_codegen {
        // The paper's testbed generates *specialized* code: the concrete
        // right-hand side is baked into literal constants (Fig 3). Use a
        // deterministic b so the metric is reproducible.
        let opts = CodegenOptions {
            bake_b: Some(vec![1.0; m.nrows]),
            ..Default::default()
        };
        codegen::generate(m, &t, &opts).size_mb()
    } else {
        0.0
    };
    (
        Cell {
            strategy: strategy.name().to_string(),
            num_levels: t.stats.levels_after,
            avg_level_cost: t.stats.total_level_cost_after as f64
                / t.stats.levels_after.max(1) as f64,
            total_level_cost: t.stats.total_level_cost_after,
            code_size_mb,
            rows_rewritten: t.stats.rows_rewritten,
            nrows: m.nrows,
        },
        t,
    )
}

/// Run all three strategies on a matrix.
pub fn run_matrix(m: &Csr, with_codegen: bool) -> Vec<Cell> {
    [
        Rewrite::None,
        Rewrite::AvgLevelCost(Default::default()),
        Rewrite::Manual(Default::default()),
    ]
    .iter()
    .map(|s| cell(m, s, with_codegen).0)
    .collect()
}

/// Render one matrix block of Table I, measured vs paper.
pub fn render(name: &str, cells: &[Cell], paper: &[(&str, PaperCell)]) -> String {
    let base = &cells[0];
    let mut t = Table::new(&[
        &format!("{name} metric"),
        "no rewriting",
        "avgLevelCost",
        "manual [12]",
        "paper (no/avg/manual)",
    ]);
    let fmt_lv = |c: &Cell| {
        if c.num_levels == base.num_levels {
            format!("{}", c.num_levels)
        } else {
            format!(
                "{} ({:.0}% -)",
                c.num_levels,
                100.0 * (1.0 - c.num_levels as f64 / base.num_levels as f64)
            )
        }
    };
    t.row(&[
        "num. of levels".into(),
        fmt_lv(&cells[0]),
        fmt_lv(&cells[1]),
        fmt_lv(&cells[2]),
        format!(
            "{} / {} / {}",
            paper[0].1.num_levels, paper[1].1.num_levels, paper[2].1.num_levels
        ),
    ]);
    let fmt_avg = |c: &Cell| {
        if (c.avg_level_cost - base.avg_level_cost).abs() < 1e-9 {
            format!("{:.3}", c.avg_level_cost)
        } else {
            format!(
                "{:.2} ({:.2}x)",
                c.avg_level_cost,
                c.avg_level_cost / base.avg_level_cost
            )
        }
    };
    t.row(&[
        "avg. level cost".into(),
        fmt_avg(&cells[0]),
        fmt_avg(&cells[1]),
        fmt_avg(&cells[2]),
        format!(
            "{:.1} / {:.1} / {:.1}",
            paper[0].1.avg_level_cost, paper[1].1.avg_level_cost, paper[2].1.avg_level_cost
        ),
    ]);
    let fmt_tot = |c: &Cell| {
        if c.total_level_cost == base.total_level_cost {
            format!("{}", c.total_level_cost)
        } else {
            format!(
                "{} ({:+.1}%)",
                c.total_level_cost,
                100.0 * (c.total_level_cost as f64 / base.total_level_cost as f64 - 1.0)
            )
        }
    };
    t.row(&[
        "total level cost".into(),
        fmt_tot(&cells[0]),
        fmt_tot(&cells[1]),
        fmt_tot(&cells[2]),
        format!(
            "{} / {} / {}",
            paper[0].1.total_level_cost, paper[1].1.total_level_cost, paper[2].1.total_level_cost
        ),
    ]);
    let fmt_sz = |c: &Cell| {
        if c.code_size_mb == 0.0 {
            "-".to_string()
        } else {
            format!("{:.2}", c.code_size_mb)
        }
    };
    let fmt_paper_sz = |p: &PaperCell| match p.code_size_mb {
        Some(v) => format!("{v}"),
        None => "-".into(),
    };
    t.row(&[
        "size of code (MB)".into(),
        fmt_sz(&cells[0]),
        fmt_sz(&cells[1]),
        fmt_sz(&cells[2]),
        format!(
            "{} / {} / {}",
            fmt_paper_sz(&paper[0].1),
            fmt_paper_sz(&paper[1].1),
            fmt_paper_sz(&paper[2].1)
        ),
    ]);
    let fmt_rr = |c: &Cell| {
        if c.rows_rewritten == 0 {
            "-".to_string()
        } else {
            format!(
                "{} ({:.1}%)",
                c.rows_rewritten,
                100.0 * c.rows_rewritten as f64 / c.nrows as f64
            )
        }
    };
    let fmt_paper_rr = |p: &PaperCell| match p.rows_rewritten {
        Some(v) => format!("{v}"),
        None => "-".into(),
    };
    t.row(&[
        "num. rows rewritten".into(),
        fmt_rr(&cells[0]),
        fmt_rr(&cells[1]),
        fmt_rr(&cells[2]),
        format!(
            "{} / {} / {}",
            fmt_paper_rr(&paper[0].1),
            fmt_paper_rr(&paper[1].1),
            fmt_paper_rr(&paper[2].1)
        ),
    ]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;

    #[test]
    fn cells_have_expected_shape() {
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.05));
        let cells = run_matrix(&m, false);
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[0].rows_rewritten, 0);
        assert!(cells[1].num_levels < cells[0].num_levels);
        assert!(cells[2].num_levels < cells[0].num_levels);
        // avgLevelCost compresses at least as much as manual (paper).
        assert!(cells[1].num_levels <= cells[2].num_levels);
    }

    #[test]
    fn render_includes_paper_columns() {
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.03));
        let cells = run_matrix(&m, true);
        let s = render("lung2-like", &cells, &PAPER_LUNG2);
        assert!(s.contains("num. of levels"));
        assert!(s.contains("479"));
        assert!(s.contains("paper"));
        assert!(cells[1].code_size_mb > 0.0);
    }
}
