//! `sptrsv` — CLI for the graph-transformation SpTRSV stack.
//!
//! Subcommands:
//!   gen        generate a synthetic matrix to a MatrixMarket file
//!   analyze    level-set statistics of a matrix
//!   transform  apply a rewriting strategy, print Table-I-style stats
//!   solve      solve Lx=b on a chosen backend, report residual + timing
//!   tune       run the strategy autotuner on a matrix, print the decision
//!   codegen    emit the specialized C code (Fig 3 / Fig 4)
//!   table1     reproduce Table I on the lung2/torso2 analogs
//!   figures    emit the Fig 5 / Fig 6 per-level cost CSVs
//!   artifact   inspect or verify a binary `.spa` analysis artifact
//!   xla        check the AOT artifact registry and run an XLA solve
//!   serve      start the coordinator and run a demo workload against it
//!   bench      replay a scenario manifest through the coordinator and
//!              emit a schema-stamped BENCH_*.json trajectory, or diff
//!              two trajectories with --compare (trend regression gate)
//!   replay     lift a captured traffic journal (journal_enabled) into a
//!              scenario and run it through the bench harness

use std::path::Path;

use anyhow::{bail, Context, Result};

use sptrsv_gt::bench;
use sptrsv_gt::config::Config;
use sptrsv_gt::coordinator::{Service, SolveOptions};
use sptrsv_gt::graph::{analyze::LevelStats, Levels};
use sptrsv_gt::report::{figures, table1};
use sptrsv_gt::runtime::{PaddedSystem, Registry, XlaSolver};
use sptrsv_gt::sparse::{generate, matrix_market, Csr};
use sptrsv_gt::transform::{Exec, PlanSpec, SolvePlan, DEFAULT_JACOBI_SWEEPS};
use sptrsv_gt::util::cli::Args;
use sptrsv_gt::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_str() {
        "gen" => cmd_gen(&args),
        "analyze" => cmd_analyze(&args),
        "transform" => cmd_transform(&args),
        "solve" => cmd_solve(&args),
        "tune" => cmd_tune(&args),
        "codegen" => cmd_codegen(&args),
        "table1" => cmd_table1(&args),
        "figures" => cmd_figures(&args),
        "artifact" => cmd_artifact(&args),
        "xla" => cmd_xla(&args),
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "replay" => cmd_replay(&args),
        // Hidden: the child process the sharded executor spawns. Speaks
        // length-prefixed JSON frames on stdin/stdout; not in HELP.
        "shard-worker" => cmd_shard_worker(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
sptrsv — graph-transformation SpTRSV (Yilmaz & Yildiz 2022 reproduction)

USAGE: sptrsv <subcommand> [flags]

  gen       --kind lung2|torso2|tridiagonal|banded|random [--scale F] [--n N]
            [--seed S] [--ill-scaled] --out FILE.mtx
  analyze   (--matrix FILE.mtx | --kind ... [--scale F])
            [--plan P --save FILE.spa]   # persist the full analysis
            # (plan + transform + schedule, placements for several worker
            # counts); `solve --analysis` reloads it
            [--analysis-format binary|json]   # binary (default) is the
            # mmap-able .spa container; json is the legacy text format
            # (kept one release; loads sniff the format either way)
  transform (--matrix|--kind...) [--plan P]   # rewrite axis of the plan
  solve     (--matrix|--kind...) [--plan P] [--backend serial|plan|
            transformed|levelset|syncfree|scheduled|reorder|xla|
            jacobi|jacobi-mixed] [--sweeps N]   # inexact backends report
            # the achieved residual; --check still demands exactness
            [--analysis FILE.spa]   # reuse a saved analysis (binary or
            # json, sniffed): skips rewrite analysis, coarsening and
            # placement entirely
            [--workers W] [--repeat R] [--check] [--sched-block-target T]
            [--sched-stale-window W]
  tune      (--matrix|--kind...) [--top-k K] [--race-solves N] [--workers W]
            [--cache FILE.json]   # portfolio autotuner decision for a matrix
  codegen   (--matrix|--kind...) [--plan P] [--no-rearrange] [--bake]
            [--head N] [--out FILE.c]
  table1    [--scale F] [--no-codegen]
  figures   [--scale F] [--out-dir DIR]
  artifact  inspect FILE.spa   # header, section table, per-section CRCs
            # and the worker count of every stored placement
  artifact  verify FILE.spa   # full validation (magic, version, bounds,
            # alignment, every checksum); exit 1 with the typed error on
            # any corruption
  xla       [--artifacts-dir DIR]   # registry check + XLA-vs-native solve
  serve     [--requests N] [--batch-size B] [--max-pending P] [--use-xla]
            [--executor inprocess|sharded:N]   # process-per-shard serving
            # with rendezvous routing, per-shard caches and fault
            # containment (--tenant-max-pending caps each tenant's queue)
            [--analysis-cache DIR]   # persisted analyses: re-registering
            # a known structure skips coarsening + placement
            [--analysis-format binary|json]   # what the cache writes
            # (binary .spa by default; loads sniff both formats)
            [--metrics-json FILE]   # also dump the final metrics snapshot
            [--journal-enabled true --journal-path FILE.jsonl]   # append
            # live traffic (register/solve/update/cancel shape, matrix
            # payload digests) to a replayable JSONL journal; `sptrsv
            # replay` consumes it
            [--default-tolerance F]   # relative-residual bound requests
            # inherit when they state none (0 = exact solves only);
            # toleranced requests may be served by jacobi plans that
            # certify the bound, escalating sweeps or falling back to
            # the exact tier when they cannot
            [--residual-check true|false] [--jacobi-max-sweeps N]
            # demo workload: mixed interactive/batch lanes, one multi-RHS
            # block, and a value refresh through the coordinator, then
            # the metrics snapshot
  bench     --scenario FILE.json [--bench-out-dir DIR] [--bench-requests N]
            [--metrics-json FILE] [--config FILE] [--workers W] [--use-xla]
            # replay the manifest (matrix mix, lanes, deadlines, arrival
            # pattern, value refreshes) through the coordinator with phase
            # tracing forced on; emits DIR/BENCH_<name>.json stamped with
            # the schema version pinned in scenarios/BENCH_SCHEMA
  bench     --compare BASE.json NEW.json [--p95-tolerance PCT]
            # trend gate: diff two BENCH trajectories (throughput, per-lane
            # p50/p95/p99, deadline misses, elastic counters) and exit
            # nonzero when a lane's p95 regressed beyond PCT (default 50)
  replay    --journal FILE.jsonl [--name NAME] [--bench-out-dir DIR]
            [--metrics-json FILE] [--config FILE] [--workers W]
            # rebuild a scenario from a traffic journal (captured with
            # journal_enabled) and run it through the bench harness;
            # emits a standard BENCH_<NAME>.json trajectory

PLANS (-P): REWRITE+EXEC, e.g. avgcost+scheduled, guarded:5+syncfree,
  manual:4+reorder, none+jacobi:4 — REWRITE in none|avgcost|manual[:d]|
  guarded[:d[:m]], EXEC in levelset|scheduled[:t[:w]]|syncfree|reorder|
  jacobi[:s]|jacobi-mixed[:s] (jacobi execs are INEXACT: s sweeps of the
  iteration, exact only once s reaches the level count — pair them with a
  solve tolerance so the service certifies the residual). Legacy single
  names still parse (avgcost = avgcost+levelset, scheduled =
  none+scheduled, ...) and `auto` asks the tuner. --strategy stays as an
  alias for --plan; `solve --backend levelset|syncfree|scheduled|reorder|
  jacobi|jacobi-mixed` overrides only the exec axis (the --plan rewrite
  still applies; --plan none for raw runs).
";

/// Scheduling knobs from the CLI: unset flags stay `None` so the crate
/// (or config) defaults apply.
fn sched_flags(args: &Args) -> Result<sptrsv_gt::sched::SchedOptions> {
    let parse = |name: &str| -> Result<Option<usize>> {
        match args.flag(name) {
            Some(v) => Ok(Some(
                v.parse::<usize>()
                    .with_context(|| format!("bad --{name} '{v}'"))?,
            )),
            None => Ok(None),
        }
    };
    Ok(sptrsv_gt::sched::SchedOptions {
        block_target: parse("sched-block-target")?,
        stale_window: parse("sched-stale-window")?,
    })
}

/// The plan spec from the CLI: `--plan`, with `--strategy` kept as a
/// pre-split alias. `default_plan` is the subcommand's fallback.
fn plan_flag(args: &Args, default_plan: &str) -> Result<PlanSpec> {
    let text = args
        .flag("plan")
        .or_else(|| args.flag("strategy"))
        .unwrap_or(default_plan);
    PlanSpec::parse(text).map_err(anyhow::Error::msg)
}

/// Resolve a CLI plan spec to a concrete (label, plan, transform) for
/// `m`. `auto` consults a tuner — the lazily initialized process-wide
/// one by default (repeated resolutions reuse its plan cache instead of
/// re-racing), or a dedicated tuner when the subcommand knows the worker
/// count the solve will run at — falling back to the paper's automatic
/// strategy with a warning if tuning cannot decide. The tuner's
/// already-built transform is returned as-is, never re-applied.
fn resolve_plan(
    spec: &PlanSpec,
    m: &Csr,
    workers: Option<usize>,
) -> (String, SolvePlan, std::sync::Arc<sptrsv_gt::transform::TransformResult>) {
    match spec.resolve(&PlanSpec::Default) {
        sptrsv_gt::transform::ResolvedPlan::Fixed(name, plan) => {
            let t = std::sync::Arc::new(plan.apply(m));
            (name, plan, t)
        }
        sptrsv_gt::transform::ResolvedPlan::Auto => {
            let chosen = match workers {
                Some(w) => sptrsv_gt::tuner::Tuner::new(sptrsv_gt::tuner::TunerOptions {
                    workers: w,
                    ..Default::default()
                })
                .choose(m),
                None => sptrsv_gt::tuner::process_choose(m),
            };
            match chosen {
                Ok(tp) => (format!("auto -> {}", tp.plan_name), tp.plan, tp.transform),
                Err(e) => {
                    eprintln!("warning: tuner could not decide ({e}); using avgcost");
                    let plan = SolvePlan::parse("avgcost").unwrap();
                    let t = std::sync::Arc::new(plan.apply(m));
                    ("avgcost".to_string(), plan, t)
                }
            }
        }
    }
}

/// Shared matrix loading: --matrix FILE or --kind generator.
fn load_matrix(args: &Args) -> Result<(String, Csr)> {
    if let Some(path) = args.flag("matrix") {
        let m = matrix_market::read_path(Path::new(path))?;
        let m = m.lower_triangular_part()?;
        m.validate_lower_triangular()?;
        return Ok((path.to_string(), m));
    }
    let kind = args.flag_or("kind", "lung2");
    let opts = generate::GenOptions {
        seed: args.u64_flag("seed", 0x5EED)?,
        scale: args.f64_flag("scale", 0.1)?,
        ill_scaled: args.bool_flag("ill-scaled"),
    };
    let n = args.usize_flag("n", 1000)?;
    let m = match kind.as_str() {
        "lung2" => generate::lung2_like(&opts),
        "torso2" => generate::torso2_like(&opts),
        "tridiagonal" => generate::tridiagonal(n, &opts),
        "banded" => generate::banded(n, args.usize_flag("bandwidth", 8)?, 0.5, &opts),
        "random" => generate::random_lower(n, args.usize_flag("max-deps", 4)?, 0.8, &opts),
        "poisson" => {
            let nx = args.usize_flag("nx", 128)?;
            generate::poisson2d_ilu(nx, args.usize_flag("ny", nx)?, &opts)
        }
        other => bail!("unknown --kind '{other}'"),
    };
    Ok((format!("{kind}(scale={})", opts.scale), m))
}

fn cmd_gen(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let out = args
        .flag("out")
        .context("gen requires --out FILE.mtx")?;
    matrix_market::write_path(&m, Path::new(out))?;
    println!(
        "wrote {name}: {} rows, {} nnz -> {out}",
        m.nrows,
        m.nnz()
    );
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    // Two-phase lifecycle: with --save, run the FULL analysis phase
    // (plan resolution, rewrite, schedule) and persist the structural
    // artifacts; `solve --analysis FILE` then skips all of it.
    if let Some(out) = args.flag("save") {
        let spec = plan_flag(args, "avgcost")?;
        let format = match args.flag("analysis-format") {
            Some(f) => sptrsv_gt::analysis::AnalysisFormat::parse(f).map_err(anyhow::Error::msg)?,
            None => sptrsv_gt::analysis::AnalysisFormat::default(),
        };
        let opts = sptrsv_gt::analysis::AnalyzeOptions {
            workers: args.usize_flag("workers", 4)?,
            sched: sched_flags(args)?,
            ..Default::default()
        };
        let start = std::time::Instant::now();
        let a = sptrsv_gt::analysis::analyze(&m, &spec, &opts)?;
        let dt = start.elapsed();
        a.save_format(Path::new(out), format)?;
        let st = &a.transform().stats;
        println!(
            "analyzed {name}: plan={} levels {} -> {}, {} rows rewritten, analysis {dt:?}",
            a.plan_name(),
            st.levels_before,
            st.levels_after,
            st.rows_rewritten
        );
        if let Some(s) = a.schedule() {
            println!(
                "schedule: {} blocks, cut {} vs {} barriers",
                s.stats.num_blocks, s.stats.cut_edges, s.stats.levelset_barriers
            );
        }
        println!(
            "saved {format} analysis (fingerprint {}) -> {out}",
            a.fingerprint()
        );
        return Ok(());
    }
    let lv = Levels::build(&m);
    let st = LevelStats::from_csr(&m, &lv);
    println!("matrix {name}: {} rows, {} nnz", m.nrows, m.nnz());
    println!(
        "levels: {} ({} barriers), max width {}, avg width {:.1}",
        st.num_levels,
        lv.num_barriers(),
        lv.max_width(),
        st.avg_width()
    );
    println!(
        "cost: total {}, avg/level {:.3}, max/level {}",
        st.total_cost,
        st.avg_level_cost,
        st.max_level_cost()
    );
    let thin = st.thin_levels();
    println!(
        "thin levels (< avg): {} of {} ({:.0}%)",
        thin.len(),
        st.num_levels,
        100.0 * st.thin_fraction()
    );
    println!(
        "level-cost profile: {}",
        figures::sparkline(&st.level_costs, 100, true, None)
    );
    Ok(())
}

fn cmd_transform(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let spec = plan_flag(args, "avgcost")?;
    // Under `auto` the clock covers the tuner's decision too — that IS
    // the offline analysis cost the paper discusses.
    let start = std::time::Instant::now();
    let (plan_name, plan, t) = resolve_plan(&spec, &m, None);
    let dt = start.elapsed();
    t.validate(&m).map_err(anyhow::Error::msg)?;
    let s = &t.stats;
    println!("matrix {name}, plan {plan_name} (rewrite {})", plan.rewrite);
    println!(
        "levels: {} -> {} ({:.1}% reduction), barriers {} -> {}",
        s.levels_before,
        s.levels_after,
        s.levels_reduction_pct(),
        s.levels_before.saturating_sub(1),
        s.levels_after.saturating_sub(1)
    );
    println!(
        "avg level cost: {:.3} -> {:.3} ({:.2}x)",
        s.avg_level_cost_before,
        s.avg_level_cost_after,
        s.avg_cost_ratio()
    );
    println!(
        "total level cost: {} -> {} ({:+.2}%)",
        s.total_level_cost_before,
        s.total_level_cost_after,
        s.total_cost_change_pct()
    );
    println!(
        "rows rewritten: {} ({:.2}%), substitutions {}, max |const| {:.3e}",
        s.rows_rewritten,
        s.rows_rewritten_pct(),
        s.substitutions_total,
        s.max_bcoeff_magnitude
    );
    println!("transform time: {dt:?}");
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let n = m.nrows;
    let workers = args.usize_flag("workers", 4)?;
    let repeat = args.usize_flag("repeat", 1)?.max(1);
    let backend = args.flag_or("backend", "plan");
    let spec = plan_flag(args, "avgcost")?;
    let mut rng = Rng::new(args.u64_flag("seed", 1)?);
    let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();

    let mut x = vec![0.0; n];

    // A saved analysis sidesteps the whole analysis phase: the plan, the
    // rewritten system and the schedule come from the file (values are
    // re-numeric'd against THIS matrix), and only execution remains.
    if let Some(path) = args.flag("analysis") {
        let opts = sptrsv_gt::analysis::AnalyzeOptions {
            workers,
            sched: sched_flags(args)?,
            ..Default::default()
        };
        let load_start = std::time::Instant::now();
        let a = sptrsv_gt::analysis::Analysis::load(Path::new(path), &m, &opts)?;
        let load_dt = load_start.elapsed();
        let c = a.rebuilds();
        let start = std::time::Instant::now();
        for _ in 0..repeat {
            a.solve_into(&b, &mut x);
        }
        let dt = start.elapsed() / repeat as u32;
        let residual = m.residual_inf(&x, &b);
        println!(
            "{name}: analysis={path} plan={} load={load_dt:?} (rewrite/coarsen/place \
             passes {}/{}/{}) n={n} time/solve={dt:?} residual={residual:.3e}",
            a.plan_name(),
            c.rewrite_passes,
            c.coarsen_passes,
            c.placement_passes
        );
        if args.bool_flag("check") {
            let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
            sptrsv_gt::util::prop::assert_allclose(&x, &x_ref, 1e-9, 1e-11)
                .map_err(anyhow::Error::msg)
                .context("--check: solution does not match the serial reference")?;
            anyhow::ensure!(residual < 1e-9, "--check: residual {residual:.3e} too large");
            anyhow::ensure!(
                c.coarsen_passes == 0 && c.placement_passes == 0 && c.rewrite_passes == 0,
                "--check: loading the analysis re-ran structural work"
            );
            println!("check OK (matches serial; zero structural passes on load)");
        }
        return Ok(());
    }

    let mut plan_label = spec.to_string();
    let start = std::time::Instant::now();
    match backend.as_str() {
        "serial" => {
            plan_label = "serial".to_string();
            for _ in 0..repeat {
                sptrsv_gt::solver::serial::solve_into(&m, &b, &mut x);
            }
        }
        // The composed path: resolve the plan (tuning `auto` at the
        // worker count the solve will run with), apply the rewrite axis,
        // and build whatever backend the exec axis names. `transformed`
        // is the pre-split alias; the backend names override only the
        // exec axis, composing with the plan's rewrite — e.g.
        // `solve --plan avgcost --backend scheduled` schedules the
        // rewritten system and `--backend levelset` runs the rewritten
        // system on level-set barriers (use `--plan none` for the raw
        // baseline).
        "plan" | "transformed" | "levelset" | "syncfree" | "scheduled" | "reorder" | "jacobi"
        | "jacobi-mixed" => {
            let (resolved_name, mut plan, t) = resolve_plan(&spec, &m, Some(workers));
            let sweeps = args.usize_flag("sweeps", DEFAULT_JACOBI_SWEEPS)?;
            match backend.as_str() {
                "levelset" => plan.exec = Exec::Levelset,
                "syncfree" => plan.exec = Exec::Syncfree,
                "reorder" => plan.exec = Exec::Reorder,
                "scheduled" => plan.exec = Exec::Scheduled(sched_flags(args)?),
                // Inexact overrides: the reported residual shows what
                // the chosen sweep count actually achieved (--check
                // still demands exact-tier agreement and will fail a
                // sweep count that has not converged).
                "jacobi" => plan.exec = Exec::Jacobi { sweeps },
                "jacobi-mixed" => plan.exec = Exec::JacobiMixed { sweeps },
                _ => {}
            }
            plan_label = format!("{resolved_name} [{}]", plan.exec);
            let s = sptrsv_gt::solver::ExecSolver::build(
                std::sync::Arc::new(m.clone()),
                t,
                &plan.exec,
                std::sync::Arc::new(sptrsv_gt::solver::pool::Pool::new(workers)),
                sched_flags(args)?,
            )?;
            if let Some(sched) = s.scheduled() {
                let st = sched.stats();
                println!(
                    "schedule: {} blocks ({} chains), cut {} vs {} barriers, max load {}",
                    st.num_blocks, st.chain_blocks, st.cut_edges, st.levelset_barriers,
                    st.max_worker_load
                );
            }
            for _ in 0..repeat {
                s.solve_into(&b, &mut x);
            }
        }
        "xla" => {
            let dir = args.flag_or("artifacts-dir", "artifacts");
            let reg = std::sync::Arc::new(Registry::load(Path::new(&dir))?);
            let (resolved_name, _plan, t) = resolve_plan(&spec, &m, Some(workers));
            plan_label = resolved_name;
            let req = PaddedSystem::requirements(&m, &t);
            let meta = reg
                .best_fit("solve", &req)
                .with_context(|| format!("no artifact fits {req:?}"))?;
            let p = PaddedSystem::build(&m, &t, meta.pad_shape())?;
            let solver = XlaSolver::new(reg);
            // Stage once (system arrays to device), then solve per-RHS.
            let staged = solver.stage(&p)?;
            for _ in 0..repeat {
                x = solver.solve_staged(&staged, &p, &b)?;
            }
        }
        other => bail!("unknown --backend '{other}'"),
    }
    let dt = start.elapsed() / repeat as u32;
    let residual = m.residual_inf(&x, &b);
    println!(
        "{name}: backend={backend} plan={plan_label} n={n} time/solve={dt:?} residual={residual:.3e}"
    );
    if args.bool_flag("check") {
        // CI smoke gate: the solve must match the serial reference.
        let x_ref = sptrsv_gt::solver::serial::solve(&m, &b);
        sptrsv_gt::util::prop::assert_allclose(&x, &x_ref, 1e-9, 1e-11)
            .map_err(anyhow::Error::msg)
            .context("--check: solution does not match the serial reference")?;
        anyhow::ensure!(residual < 1e-9, "--check: residual {residual:.3e} too large");
        println!("check OK (matches serial within 1e-9/1e-11)");
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let defaults = sptrsv_gt::tuner::TunerOptions::default();
    let opts = sptrsv_gt::tuner::TunerOptions {
        top_k: args.usize_flag("top-k", defaults.top_k)?,
        race_solves: args.usize_flag("race-solves", defaults.race_solves)?,
        workers: args.usize_flag("workers", defaults.workers)?,
        cache_path: args.flag("cache").map(std::path::PathBuf::from),
        sched: sched_flags(args)?,
        ..defaults
    };
    let mut tuner = sptrsv_gt::tuner::Tuner::new(opts);
    let plan = tuner.choose(&m)?;
    println!("matrix {name}: {} rows, {} nnz", m.nrows, m.nnz());
    if let Some(f) = &plan.features {
        println!(
            "features: levels={} (thin {:.0}%), width mean={:.1} p95={} max={}, \
             avg indegree={:.2}, total cost={}",
            f.num_levels,
            100.0 * f.thin_cost_fraction(),
            f.mean_level_width,
            f.p95_level_width,
            f.max_level_width,
            f.avg_indegree,
            f.total_cost
        );
    }
    println!("fingerprint: {}", plan.fingerprint);
    if !plan.predictions.is_empty() {
        println!("cost-model predictions over the rewrite x exec cross product \
                  (lower is better):");
        for (s, c) in &plan.predictions {
            println!("  {s:<24} {c:>14.1}");
        }
    }
    if let Some(race) = &plan.race {
        println!("race results:");
        for lane in &race.lanes {
            println!(
                "  {:<24} transform={:>8.2}ms solve={:>10.1}us levels={:<6} cost={}",
                lane.plan,
                lane.transform_ms,
                lane.solve_us,
                lane.levels_after,
                lane.total_cost_after
            );
        }
    }
    let how = match plan.source {
        sptrsv_gt::tuner::PlanSource::CacheHit => "plan cache hit",
        sptrsv_gt::tuner::PlanSource::Raced => "cost model + race",
    };
    println!(
        "chosen: {} via {how} -> {} levels ({} barriers)",
        plan.plan_name,
        plan.transform.num_levels(),
        plan.transform.num_levels().saturating_sub(1)
    );
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<()> {
    let (_, m) = load_matrix(args)?;
    let spec = plan_flag(args, "avgcost")?;
    let (_, _plan, t) = resolve_plan(&spec, &m, None);
    let bake = if args.bool_flag("bake") {
        let mut rng = Rng::new(7);
        Some((0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect())
    } else {
        None
    };
    let g = sptrsv_gt::codegen::generate(
        &m,
        &t,
        &sptrsv_gt::codegen::CodegenOptions {
            rearrange: !args.bool_flag("no-rearrange"),
            bake_b: bake,
            ..Default::default()
        },
    );
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &g.source)?;
            println!(
                "wrote {path}: {:.2} MB, {} functions",
                g.size_mb(),
                g.num_functions
            );
        }
        None => {
            let head = args.usize_flag("head", 30)?;
            for line in g.source.lines().take(head) {
                println!("{line}");
            }
            println!(
                "... ({:.2} MB total, {} functions)",
                g.size_mb(),
                g.num_functions
            );
        }
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let scale = args.f64_flag("scale", 1.0)?;
    let with_codegen = !args.bool_flag("no-codegen");
    let opts = generate::GenOptions::with_scale(scale);
    for (name, m, paper) in [
        ("lung2-like", generate::lung2_like(&opts), &table1::PAPER_LUNG2),
        ("torso2-like", generate::torso2_like(&opts), &table1::PAPER_TORSO2),
    ] {
        println!(
            "\n== {name} (scale {scale}): {} rows, {} nnz ==",
            m.nrows,
            m.nnz()
        );
        let cells = table1::run_matrix(&m, with_codegen);
        print!("{}", table1::render(name, &cells, paper));
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let scale = args.f64_flag("scale", 1.0)?;
    let dir = args.flag_or("out-dir", "target/figures");
    std::fs::create_dir_all(&dir)?;
    let opts = generate::GenOptions::with_scale(scale);
    for (fig, name, m, log, clip) in [
        ("fig5", "lung2-like", generate::lung2_like(&opts), true, None),
        ("fig6", "torso2-like", generate::torso2_like(&opts), false, Some(8000u64)),
    ] {
        let ss = figures::series(&m);
        let path = format!("{dir}/{fig}_{name}.csv");
        std::fs::write(&path, figures::to_csv(&ss))?;
        println!("\n{fig} ({name}) -> {path}");
        for s in &ss {
            println!(
                "  {:<14} levels={:<5} avg={:<10.2} {}",
                s.strategy,
                s.level_costs.len(),
                s.avg_level_cost,
                figures::sparkline(&s.level_costs, 80, log, clip)
            );
        }
    }
    Ok(())
}

fn cmd_artifact(args: &Args) -> Result<()> {
    use sptrsv_gt::artifact::{container, ArtifactReader};
    let usage = "usage: sptrsv artifact inspect|verify FILE.spa";
    let action = args.positionals.first().map(String::as_str).unwrap_or("");
    let file = args
        .positionals
        .get(1)
        .map(String::as_str)
        .or_else(|| args.flag("file"))
        .with_context(|| usage.to_string())?;
    match action {
        "inspect" => {
            let r = ArtifactReader::open(Path::new(file))?;
            println!(
                "{file}: format v{}, fingerprint {:016x}, {} rows, {} sections, {} bytes",
                r.version(),
                r.fingerprint(),
                r.nrows(),
                r.sections().len(),
                r.total_len()
            );
            println!("  idx kind      offset      len        crc32     detail");
            // SCHEDULE payloads lead with their worker count (raw
            // little-endian u64) — surface it so an inspect shows which
            // pool sizes warm-start without re-placing. sections_of
            // yields payloads in file order, matching the table walk.
            let mut placements = r.sections_of(container::SEC_SCHEDULE);
            for (i, s) in r.sections().iter().enumerate() {
                let detail = if s.kind == container::SEC_SCHEDULE {
                    match placements.next().and_then(|p| p.get(..8)) {
                        Some(head) => format!(
                            "placement for {} workers",
                            u64::from_le_bytes(head.try_into().unwrap())
                        ),
                        None => "placement (short payload)".to_string(),
                    }
                } else {
                    String::new()
                };
                println!(
                    "  [{i}] {:<9} {:>10} {:>10} {:#010x} {detail}",
                    container::section_kind_name(s.kind),
                    s.offset,
                    s.len,
                    s.crc
                );
            }
        }
        "verify" => {
            // open() already validates everything the format guards:
            // magic, version, the truncation guard, section bounds and
            // alignment, and every section's CRC-32.
            match ArtifactReader::open(Path::new(file)) {
                Ok(r) => println!(
                    "{file}: OK ({} sections, {} bytes, fingerprint {:016x})",
                    r.sections().len(),
                    r.total_len(),
                    r.fingerprint()
                ),
                Err(e) => {
                    eprintln!("{file}: FAILED: {e}");
                    std::process::exit(1);
                }
            }
        }
        other => bail!("unknown artifact action '{other}'\n{usage}"),
    }
    Ok(())
}

fn cmd_xla(args: &Args) -> Result<()> {
    let dir = args.flag_or("artifacts-dir", "artifacts");
    let reg = std::sync::Arc::new(Registry::load(Path::new(&dir))?);
    println!(
        "registry: {} artifacts on {} ({} devices)",
        reg.metas.len(),
        reg.client.platform_name(),
        reg.client.device_count()
    );
    for m in &reg.metas {
        println!(
            "  {:<32} entry={:<13} l={:?} r={} k={} n={} b={:?}",
            m.name, m.entry, m.l, m.r, m.k, m.n, m.b
        );
    }
    // Smoke: solve a generated system on XLA and compare to native.
    let m = generate::lung2_like(&generate::GenOptions::with_scale(0.02));
    let plan = SolvePlan::parse("avgcost").map_err(anyhow::Error::msg)?;
    let t = plan.apply(&m);
    let req = PaddedSystem::requirements(&m, &t);
    let meta = reg
        .best_fit("solve", &req)
        .with_context(|| format!("no artifact fits {req:?}"))?;
    println!("\nsmoke solve: fitting {:?} into '{}'", req, meta.name);
    let p = PaddedSystem::build(&m, &t, meta.pad_shape())?;
    let mut rng = Rng::new(3);
    let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let solver = XlaSolver::new(std::sync::Arc::clone(&reg));
    let x = solver.solve(&p, &b)?;
    let resid = m.residual_inf(&x, &b);
    let resid_xla = solver.residual(&p, &b, &x)?;
    println!("native residual check: {resid:.3e}, xla residual graph: {resid_xla:.3e}");
    anyhow::ensure!(resid < 1e-9, "XLA solve inaccurate: {resid:.3e}");
    println!("xla OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    if let Some(path) = args.flag("config") {
        cfg = Config::from_file(Path::new(path))?;
    }
    cfg.merge_args(args)?;
    let requests = args.usize_flag("requests", 64)?;
    println!(
        "starting coordinator: workers={} plan={} use_xla={} batch={}/{}us \
         max_pending={} analysis_cache={} executor={}",
        cfg.workers, cfg.plan, cfg.use_xla, cfg.batch_size, cfg.batch_deadline_us,
        cfg.max_pending,
        if cfg.analysis_cache.is_empty() { "off" } else { &cfg.analysis_cache },
        cfg.executor
    );
    let batch_size = cfg.batch_size;
    let svc = Service::start(cfg);
    let h = svc.handle();
    let m = generate::lung2_like(&generate::GenOptions::with_scale(0.05));
    let n = m.nrows;
    let handle = h.register("lung2", m.clone(), PlanSpec::Default)?;
    println!(
        "registered lung2-like: plan={}, levels {} -> {}, {} rows rewritten, \
         backend={}, analysis={}, prepare={:.1}ms",
        handle.plan,
        handle.levels_before,
        handle.levels_after,
        handle.rows_rewritten,
        handle.backend,
        handle.source.as_str(),
        handle.prepare_ms
    );
    let start = std::time::Instant::now();
    let mut rng = Rng::new(11);
    // Mixed-lane async workload: every fourth request rides the
    // interactive lane, the rest fill batches.
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let opts = if i % 4 == 0 {
                SolveOptions::interactive()
            } else {
                SolveOptions::default()
            };
            (b.clone(), h.solve_async("lung2", b, opts).unwrap())
        })
        .collect();
    let mut worst = 0.0f64;
    for (b, t) in tickets {
        let x = t.wait()?;
        worst = worst.max(m.residual_inf(&x, &b));
    }
    // One multi-RHS block sized to the batcher: lands as a single batch.
    let bs: Vec<Vec<f64>> = (0..batch_size)
        .map(|_| (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    let xs = h.solve_many("lung2", bs.clone(), SolveOptions::default())?.wait()?;
    for (b, x) in bs.iter().zip(&xs) {
        worst = worst.max(m.residual_inf(x, b));
    }
    // A same-pattern value refresh (the preconditioned-iterative-solve
    // scenario: new factorization, same sparsity): numerics replayed in
    // place, no structural work re-run.
    let mut m2 = m.clone();
    for v in &mut m2.data {
        *v *= 1.1;
    }
    let refreshed = handle.update_values(m2.clone())?;
    println!(
        "refreshed values in {:.1}ms (analysis={})",
        refreshed.prepare_ms,
        refreshed.source.as_str()
    );
    let b2: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let x2 = handle.solve(b2.clone())?;
    worst = worst.max(m2.residual_inf(&x2, &b2));
    let dt = start.elapsed();
    let total = requests + batch_size + 1;
    println!(
        "{total} solves in {dt:?} ({:.1} solves/s), worst residual {worst:.3e}",
        total as f64 / dt.as_secs_f64()
    );
    let snap = h.metrics()?;
    println!("metrics: {snap}");
    if let Some(path) = args.flag("metrics-json") {
        sptrsv_gt::util::fs::write_atomic(Path::new(path), &format!("{}\n", snap.to_json()))
            .with_context(|| format!("writing --metrics-json {path}"))?;
        println!("metrics snapshot written to {path}");
    }
    svc.shutdown();
    Ok(())
}

fn cmd_shard_worker(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    if let Some(path) = args.flag("config") {
        cfg = Config::from_file(Path::new(path))?;
    }
    cfg.merge_args(args)?;
    // stdout belongs to the frame protocol from here on; the supervisor
    // inherits stderr for diagnostics.
    sptrsv_gt::exec_tier::worker::serve(cfg).context("shard-worker protocol loop")?;
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    // `--compare BASE NEW` is the trend gate: no replay, just a diff of
    // two trajectories. (The parser makes BASE the flag's value and NEW
    // the first positional.)
    if let Some(base_path) = args.flag("compare") {
        let new_path = args
            .positionals
            .first()
            .context("bench --compare needs two files: BASE.json NEW.json")?;
        let load = |p: &str| -> Result<sptrsv_gt::util::json::Json> {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading trajectory {p}"))?;
            sptrsv_gt::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing trajectory {p}: {e}"))
        };
        let (base, new) = (load(base_path)?, load(new_path)?);
        let tolerance = args.f64_flag("p95-tolerance", 50.0)?;
        let report = sptrsv_gt::telemetry::trend::compare(&base, &new, tolerance)?;
        print!("{report}");
        if report.regressed {
            std::process::exit(1);
        }
        return Ok(());
    }
    let mut cfg = Config::default();
    if let Some(path) = args.flag("config") {
        cfg = Config::from_file(Path::new(path))?;
    }
    cfg.merge_args(args)?;
    let path = args
        .flag("scenario")
        .context("bench needs --scenario FILE.json (see scenarios/smoke.json)")?;
    let sc = bench::Scenario::load(Path::new(path))?;
    let requests = if cfg.bench_requests > 0 {
        cfg.bench_requests
    } else {
        sc.requests
    };
    println!(
        "replaying scenario '{}': {} requests over {} matrices \
         (interactive {:.0}%, deadlines {:.0}%, refresh every {}), workers={}",
        sc.name,
        requests,
        sc.matrices.len(),
        100.0 * sc.interactive_fraction,
        100.0 * sc.deadline_fraction,
        sc.refresh_every,
        cfg.workers,
    );
    let out = bench::run(&sc, &cfg)?;
    let snap = &out.snapshot;
    println!("bench metrics: {snap}");
    println!(
        "deadline misses {} / rejections {} / interactive p99 {}us / batch p99 {}us",
        snap.deadline_misses, snap.rejections, snap.interactive.p99_us, snap.batch.p99_us
    );
    if let Some(mpath) = args.flag("metrics-json") {
        sptrsv_gt::util::fs::write_atomic(Path::new(mpath), &format!("{}\n", snap.to_json()))
            .with_context(|| format!("writing --metrics-json {mpath}"))?;
    }
    println!("BENCH trajectory written to {}", out.path.display());
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    if let Some(path) = args.flag("config") {
        cfg = Config::from_file(Path::new(path))?;
    }
    cfg.merge_args(args)?;
    let jpath = args
        .flag("journal")
        .context("replay needs --journal FILE.jsonl (capture one with journal_enabled)")?;
    let name = args.flag_or("name", "replay");
    let sc = sptrsv_gt::telemetry::scenario_from_journal(Path::new(jpath), &name)?;
    println!(
        "replaying journal {jpath} as '{}': {} requests over {} matrices \
         (interactive {:.0}%, deadlines {:.0}%, block {}, refresh every {}), workers={}",
        sc.name,
        sc.requests,
        sc.matrices.len(),
        100.0 * sc.interactive_fraction,
        100.0 * sc.deadline_fraction,
        sc.block_size,
        sc.refresh_every,
        cfg.workers,
    );
    let out = bench::run(&sc, &cfg)?;
    println!("bench metrics: {}", out.snapshot);
    if let Some(mpath) = args.flag("metrics-json") {
        sptrsv_gt::util::fs::write_atomic(
            Path::new(mpath),
            &format!("{}\n", out.snapshot.to_json()),
        )
        .with_context(|| format!("writing --metrics-json {mpath}"))?;
    }
    println!("BENCH trajectory written to {}", out.path.display());
    Ok(())
}
