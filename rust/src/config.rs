//! Configuration system: a flat key = value file (TOML subset — strings,
//! numbers, booleans; `#` comments) merged with CLI `--key value`
//! overrides. Used by the coordinator/service and the bench harnesses.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::Error;
use crate::util::cli::Args;

#[derive(Debug, Clone)]
pub struct Config {
    /// worker threads for the parallel solvers
    pub workers: usize,
    /// transformation strategy name (see `Strategy::parse`)
    pub strategy: String,
    /// directory with AOT artifacts + manifest.json
    pub artifacts_dir: String,
    /// batch size target for the RHS batcher
    pub batch_size: usize,
    /// max microseconds a request may wait for a batch to fill
    pub batch_deadline_us: u64,
    /// prefer the XLA backend when an artifact shape fits
    pub use_xla: bool,
    /// default RNG seed for generators
    pub seed: u64,
    /// tuner plan-cache spill file for the `auto` strategy ("" = memory
    /// only)
    pub tuner_cache: String,
    /// how many cost-model favourites the tuner races empirically
    pub tuner_top_k: usize,
    /// timed solves per raced candidate
    pub tuner_race_solves: usize,
    /// any further key=value pairs (kept for extensions/ablations)
    pub extra: BTreeMap<String, String>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            strategy: "avgcost".to_string(),
            artifacts_dir: "artifacts".to_string(),
            batch_size: 8,
            batch_deadline_us: 2_000,
            use_xla: false,
            seed: 0x5EED,
            tuner_cache: String::new(),
            tuner_top_k: 2,
            tuner_race_solves: 3,
            extra: BTreeMap::new(),
        }
    }
}

impl Config {
    /// Parse the flat TOML-subset file.
    pub fn from_file(path: &Path) -> Result<Config, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
        let mut cfg = Config::default();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue; // section headers tolerated, ignored
            }
            let Some(eq) = line.find('=') else {
                return Err(Error::Invalid(format!(
                    "{}:{}: expected key = value",
                    path.display(),
                    ln + 1
                )));
            };
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim().trim_matches('"');
            cfg.set(key, val)?;
        }
        Ok(cfg)
    }

    /// Apply CLI flags on top (flags win over file values).
    pub fn merge_args(&mut self, args: &Args) -> Result<(), Error> {
        for (k, v) in &args.flags {
            // Only consume known config keys; other flags belong to the
            // subcommands.
            if matches!(
                k.as_str(),
                "workers" | "strategy" | "artifacts-dir" | "batch-size"
                    | "batch-deadline-us" | "use-xla" | "seed" | "tuner-cache"
                    | "tuner-top-k" | "tuner-race-solves"
            ) {
                self.set(&k.replace('-', "_"), v)?;
            }
        }
        Ok(())
    }

    fn set(&mut self, key: &str, val: &str) -> Result<(), Error> {
        let bad = |k: &str, v: &str| Error::Invalid(format!("config {k}: bad value '{v}'"));
        match key {
            "workers" => self.workers = val.parse().map_err(|_| bad(key, val))?,
            "strategy" => self.strategy = val.to_string(),
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "batch_size" => self.batch_size = val.parse().map_err(|_| bad(key, val))?,
            "batch_deadline_us" => {
                self.batch_deadline_us = val.parse().map_err(|_| bad(key, val))?
            }
            "use_xla" => self.use_xla = matches!(val, "true" | "1" | "yes"),
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "tuner_cache" => self.tuner_cache = val.to_string(),
            "tuner_top_k" => self.tuner_top_k = val.parse().map_err(|_| bad(key, val))?,
            "tuner_race_solves" => {
                self.tuner_race_solves = val.parse().map_err(|_| bad(key, val))?
            }
            other => {
                self.extra.insert(other.to_string(), val.to_string());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert!(c.workers >= 1);
        assert_eq!(c.strategy, "avgcost");
        assert!(c.tuner_cache.is_empty());
        assert!(c.tuner_top_k >= 1);
    }

    #[test]
    fn tuner_keys_parse() {
        let mut c = Config::default();
        c.set("tuner_cache", "/tmp/plans.json").unwrap();
        c.set("tuner_top_k", "3").unwrap();
        c.set("tuner_race_solves", "5").unwrap();
        assert_eq!(c.tuner_cache, "/tmp/plans.json");
        assert_eq!(c.tuner_top_k, 3);
        assert_eq!(c.tuner_race_solves, 5);
        assert!(c.set("tuner_top_k", "lots").is_err());
        let args = Args::parse(
            ["serve", "--tuner-cache", "p.json", "--tuner-top-k", "4"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.tuner_cache, "p.json");
        assert_eq!(c.tuner_top_k, 4);
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("sptrsv_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &p,
            "# comment\n[coordinator]\nworkers = 3\nstrategy = \"manual:5\"\nuse_xla = true\ncustom_knob = 7\n",
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(c.workers, 3);
        assert_eq!(c.strategy, "manual:5");
        assert!(c.use_xla);
        assert_eq!(c.extra.get("custom_knob").unwrap(), "7");
    }

    #[test]
    fn args_override() {
        let mut c = Config::default();
        let args = Args::parse(
            ["x", "--workers", "7", "--strategy", "none", "--other", "z"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.workers, 7);
        assert_eq!(c.strategy, "none");
        assert!(!c.extra.contains_key("other")); // unknown flags left alone
    }

    #[test]
    fn bad_values_error() {
        let mut c = Config::default();
        assert!(c.set("workers", "many").is_err());
        let p = std::env::temp_dir().join(format!("sptrsv_cfg_bad_{}.toml", std::process::id()));
        std::fs::write(&p, "workers\n").unwrap();
        assert!(Config::from_file(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
