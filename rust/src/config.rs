//! Configuration system: a flat key = value file (TOML subset — strings,
//! numbers, booleans; `#` comments outside quotes) merged with CLI
//! `--key value` overrides. Used by the coordinator/service and the bench
//! harnesses.

use std::collections::BTreeMap;
use std::path::Path;

use crate::analysis::AnalysisFormat;
use crate::error::Error;
use crate::transform::PlanSpec;
use crate::util::cli::Args;

#[derive(Debug, Clone)]
pub struct Config {
    /// worker threads for the parallel solvers
    pub workers: usize,
    /// default solve plan, parsed once at config time (see
    /// `SolvePlan::parse` for the `rewrite+exec` grammar and the accepted
    /// legacy single names; `auto` defers to the tuner). Set by the
    /// `plan` config key, with `strategy` kept as an alias.
    pub plan: PlanSpec,
    /// directory with AOT artifacts + manifest.json
    pub artifacts_dir: String,
    /// batch size target for the RHS batcher (counted in right-hand sides)
    pub batch_size: usize,
    /// max microseconds a request may wait for a batch to fill
    pub batch_deadline_us: u64,
    /// admission control: max queued right-hand sides before new requests
    /// are rejected `Overloaded` (0 = unbounded)
    pub max_pending: usize,
    /// prefer the XLA backend when an artifact shape fits
    pub use_xla: bool,
    /// default RNG seed for generators
    pub seed: u64,
    /// tuner plan-cache spill file for the `auto` strategy ("" = memory
    /// only)
    pub tuner_cache: String,
    /// directory for persisted analyses (plan + transform skeleton +
    /// schedule, keyed by structural fingerprint; typically a sibling of
    /// `tuner_cache`): re-registering a known structure skips rewrite
    /// analysis, coarsening and ETF placement ("" = disabled)
    pub analysis_cache: String,
    /// how many cost-model favourites the tuner races empirically
    pub tuner_top_k: usize,
    /// timed solves per raced candidate
    pub tuner_race_solves: usize,
    /// seconds before a spilled plan-cache entry expires and is dropped
    /// on load (0 = never expire by age)
    pub tuner_cache_ttl: u64,
    /// work-units target per coarsened block for `--strategy scheduled`
    pub sched_block_target: usize,
    /// elastic lookahead window in blocks for `--strategy scheduled`
    /// (0 = strict in-order point-to-point waits)
    pub sched_stale_window: usize,
    /// analysis-cache directory bound: max entries kept after a save
    /// (0 = unbounded)
    pub analysis_cache_cap: usize,
    /// analysis-cache entry TTL in seconds; older entries are dropped at
    /// the next save (0 = never expire by age)
    pub analysis_cache_ttl: u64,
    /// on-disk format for persisted analyses: `binary` (mmap-able `.spa`
    /// artifacts, the default) or `json` (the legacy schema, kept one
    /// release for migration). Governs writes only — loads sniff the
    /// file content and accept either.
    pub analysis_format: AnalysisFormat,
    /// which executor tier serves prepared analyses: `inprocess` (the
    /// default single-process pipeline) or `sharded:N` (N child worker
    /// processes, matrices routed by structural fingerprint)
    pub executor: String,
    /// per-tenant admission quota: max queued right-hand sides charged to
    /// one tenant before its requests are rejected `Overloaded`
    /// (0 = no tenant quotas)
    pub tenant_max_pending: usize,
    /// binary spawned as `shard-worker` by the sharded executor
    /// ("" = this executable)
    pub shard_worker_bin: String,
    /// milliseconds the supervisor waits on a shard reply before declaring
    /// the worker hung and respawning it
    pub shard_timeout_ms: u64,
    /// fault-injection knob for tests/CI: SIGKILL the routed shard's
    /// worker right before the Nth solve dispatch (0 = disabled)
    pub chaos_kill_shard_after: usize,
    /// record per-solve phase spans in the service's tracer (off by
    /// default; `sptrsv bench` forces it on for its report)
    pub trace_enabled: bool,
    /// append every shaping-relevant request the service sees (register /
    /// solve / solve_many / update_values / cancel sweeps) to the
    /// `journal_path` JSONL traffic journal, replayable with
    /// `sptrsv replay --journal FILE`
    pub journal_enabled: bool,
    /// where the traffic journal is appended when `journal_enabled`
    pub journal_path: String,
    /// directory `sptrsv bench` writes its `BENCH_*.json` files into
    pub bench_out_dir: String,
    /// override the scenario's request count (0 = use the scenario value)
    pub bench_requests: usize,
    /// service-wide default relative-residual tolerance applied to solves
    /// that specify none (0.0 = unset: no tolerance unless the request or
    /// the matrix registration carries one)
    pub default_tolerance: f64,
    /// compute the achieved relative residual after every toleranced solve
    /// and run the accuracy fallback ladder on a miss (default on; when
    /// off, toleranced requests on iterative plans go straight to the
    /// exact fallback because nothing can certify them)
    pub residual_check: bool,
    /// cap for per-matrix Jacobi sweep auto-escalation: sweeps double on a
    /// tolerance miss until they reach this bound, then the exact fallback
    /// takes over
    pub jacobi_max_sweeps: usize,
    /// any further key=value pairs (kept for extensions/ablations)
    pub extra: BTreeMap<String, String>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            plan: PlanSpec::parse("avgcost").expect("builtin plan"),
            artifacts_dir: "artifacts".to_string(),
            batch_size: 8,
            batch_deadline_us: 2_000,
            max_pending: 4_096,
            use_xla: false,
            seed: 0x5EED,
            tuner_cache: String::new(),
            analysis_cache: String::new(),
            tuner_top_k: 2,
            tuner_race_solves: 3,
            tuner_cache_ttl: 0,
            sched_block_target: crate::sched::DEFAULT_BLOCK_TARGET,
            sched_stale_window: crate::sched::DEFAULT_STALE_WINDOW,
            analysis_cache_cap: 0,
            analysis_cache_ttl: 0,
            analysis_format: AnalysisFormat::default(),
            executor: "inprocess".to_string(),
            tenant_max_pending: 0,
            shard_worker_bin: String::new(),
            shard_timeout_ms: 30_000,
            chaos_kill_shard_after: 0,
            trace_enabled: false,
            journal_enabled: false,
            journal_path: "sptrsv-journal.jsonl".to_string(),
            bench_out_dir: "bench-out".to_string(),
            bench_requests: 0,
            default_tolerance: 0.0,
            residual_check: true,
            jacobi_max_sweeps: crate::iterative::DEFAULT_MAX_SWEEPS,
            extra: BTreeMap::new(),
        }
    }
}

/// Strip a `#` comment, ignoring `#` inside a double-quoted value (the
/// old `split('#')` truncated quoted strings like `"plans#v2.json"`).
fn strip_comment(line: &str) -> &str {
    let mut in_quotes = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Remove exactly one pair of surrounding double quotes. `trim_matches('"')`
/// would also eat quotes that belong to the value itself.
fn unquote(val: &str) -> &str {
    let v = val.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

impl Config {
    /// Parse the flat TOML-subset file.
    pub fn from_file(path: &Path) -> Result<Config, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
        let mut cfg = Config::default();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() || line.starts_with('[') {
                continue; // section headers tolerated, ignored
            }
            let Some(eq) = line.find('=') else {
                return Err(Error::Invalid(format!(
                    "{}:{}: expected key = value",
                    path.display(),
                    ln + 1
                )));
            };
            let key = line[..eq].trim();
            let val = unquote(&line[eq + 1..]);
            cfg.set(key, val)?;
        }
        Ok(cfg)
    }

    /// Apply CLI flags on top (flags win over file values).
    pub fn merge_args(&mut self, args: &Args) -> Result<(), Error> {
        for (k, v) in &args.flags {
            // Only consume known config keys; other flags belong to the
            // subcommands.
            if matches!(
                k.as_str(),
                "workers" | "plan" | "strategy" | "artifacts-dir" | "batch-size"
                    | "batch-deadline-us" | "max-pending" | "use-xla" | "seed"
                    | "tuner-cache" | "analysis-cache" | "tuner-top-k"
                    | "tuner-race-solves" | "tuner-cache-ttl" | "sched-block-target"
                    | "sched-stale-window" | "analysis-cache-cap"
                    | "analysis-cache-ttl" | "analysis-format" | "executor"
                    | "tenant-max-pending"
                    | "shard-worker-bin" | "shard-timeout-ms"
                    | "chaos-kill-shard-after" | "trace-enabled" | "journal-enabled"
                    | "journal-path" | "bench-out-dir" | "bench-requests"
                    | "default-tolerance" | "residual-check" | "jacobi-max-sweeps"
            ) {
                self.set(&k.replace('-', "_"), v)?;
            }
        }
        Ok(())
    }

    /// Shard count requested by the `executor` key (`None` = in-process).
    pub fn shard_count(&self) -> Option<usize> {
        self.executor
            .strip_prefix("sharded:")
            .and_then(|n| n.parse().ok())
    }

    fn set(&mut self, key: &str, val: &str) -> Result<(), Error> {
        let bad = |k: &str, v: &str| Error::Invalid(format!("config {k}: bad value '{v}'"));
        match key {
            "workers" => self.workers = val.parse().map_err(|_| bad(key, val))?,
            // `strategy` predates the solve-plan split and stays as an
            // alias for `plan`.
            "plan" | "strategy" => {
                self.plan = PlanSpec::parse(val).map_err(Error::Invalid)?
            }
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "batch_size" => self.batch_size = val.parse().map_err(|_| bad(key, val))?,
            "batch_deadline_us" => {
                self.batch_deadline_us = val.parse().map_err(|_| bad(key, val))?
            }
            "max_pending" => self.max_pending = val.parse().map_err(|_| bad(key, val))?,
            "use_xla" => self.use_xla = matches!(val, "true" | "1" | "yes"),
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "tuner_cache" => self.tuner_cache = val.to_string(),
            "analysis_cache" => self.analysis_cache = val.to_string(),
            "tuner_top_k" => self.tuner_top_k = val.parse().map_err(|_| bad(key, val))?,
            "tuner_race_solves" => {
                self.tuner_race_solves = val.parse().map_err(|_| bad(key, val))?
            }
            "tuner_cache_ttl" => {
                self.tuner_cache_ttl = val.parse().map_err(|_| bad(key, val))?
            }
            "sched_block_target" => {
                self.sched_block_target = val.parse().map_err(|_| bad(key, val))?
            }
            "sched_stale_window" => {
                self.sched_stale_window = val.parse().map_err(|_| bad(key, val))?
            }
            "analysis_cache_cap" => {
                self.analysis_cache_cap = val.parse().map_err(|_| bad(key, val))?
            }
            "analysis_cache_ttl" => {
                self.analysis_cache_ttl = val.parse().map_err(|_| bad(key, val))?
            }
            // Validated at config time like `plan`: a typo must fail
            // here, not when the first analysis is persisted.
            "analysis_format" => {
                self.analysis_format =
                    AnalysisFormat::parse(val).map_err(Error::Invalid)?
            }
            "executor" => {
                // Validate at config time like `plan`: a typo must fail
                // here, not inside the service thread.
                let ok = val == "inprocess"
                    || val
                        .strip_prefix("sharded:")
                        .and_then(|n| n.parse::<usize>().ok())
                        .is_some_and(|n| n >= 1);
                if !ok {
                    return Err(Error::Invalid(format!(
                        "config executor: '{val}' (expected inprocess or sharded:N)"
                    )));
                }
                self.executor = val.to_string();
            }
            "tenant_max_pending" => {
                self.tenant_max_pending = val.parse().map_err(|_| bad(key, val))?
            }
            "shard_worker_bin" => self.shard_worker_bin = val.to_string(),
            "shard_timeout_ms" => {
                self.shard_timeout_ms = val.parse().map_err(|_| bad(key, val))?
            }
            "chaos_kill_shard_after" => {
                self.chaos_kill_shard_after = val.parse().map_err(|_| bad(key, val))?
            }
            "trace_enabled" => self.trace_enabled = matches!(val, "true" | "1" | "yes"),
            "journal_enabled" => {
                self.journal_enabled = matches!(val, "true" | "1" | "yes")
            }
            "journal_path" => self.journal_path = val.to_string(),
            "bench_out_dir" => self.bench_out_dir = val.to_string(),
            "bench_requests" => {
                self.bench_requests = val.parse().map_err(|_| bad(key, val))?
            }
            "default_tolerance" => {
                let t: f64 = val.parse().map_err(|_| bad(key, val))?;
                if !t.is_finite() || t < 0.0 {
                    return Err(bad(key, val));
                }
                self.default_tolerance = t;
            }
            "residual_check" => self.residual_check = matches!(val, "true" | "1" | "yes"),
            "jacobi_max_sweeps" => {
                let s: usize = val.parse().map_err(|_| bad(key, val))?;
                if s == 0 {
                    return Err(bad(key, val));
                }
                self.jacobi_max_sweeps = s;
            }
            other => {
                self.extra.insert(other.to_string(), val.to_string());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert!(c.workers >= 1);
        assert_eq!(c.plan.as_str(), "avgcost");
        assert!(c.tuner_cache.is_empty());
        assert!(c.tuner_top_k >= 1);
        assert!(c.max_pending > 0);
    }

    #[test]
    fn analysis_cache_key_parses_and_merges() {
        let mut c = Config::default();
        assert!(c.analysis_cache.is_empty(), "disabled by default");
        c.set("analysis_cache", "/tmp/analyses").unwrap();
        assert_eq!(c.analysis_cache, "/tmp/analyses");
        let args = Args::parse(
            ["serve", "--analysis-cache", "cache/dir"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.analysis_cache, "cache/dir");
    }

    #[test]
    fn tuner_keys_parse() {
        let mut c = Config::default();
        c.set("tuner_cache", "/tmp/plans.json").unwrap();
        c.set("tuner_top_k", "3").unwrap();
        c.set("tuner_race_solves", "5").unwrap();
        c.set("tuner_cache_ttl", "86400").unwrap();
        assert_eq!(c.tuner_cache, "/tmp/plans.json");
        assert_eq!(c.tuner_top_k, 3);
        assert_eq!(c.tuner_race_solves, 5);
        assert_eq!(c.tuner_cache_ttl, 86_400);
        assert!(c.set("tuner_top_k", "lots").is_err());
        assert!(c.set("tuner_cache_ttl", "soon").is_err());
        let args = Args::parse(
            ["serve", "--tuner-cache", "p.json", "--tuner-top-k", "4"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.tuner_cache, "p.json");
        assert_eq!(c.tuner_top_k, 4);
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("sptrsv_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &p,
            "# comment\n[coordinator]\nworkers = 3\nstrategy = \"manual:5\"\nuse_xla = true\nmax_pending = 64\ncustom_knob = 7\n",
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(c.workers, 3);
        assert_eq!(c.plan.as_str(), "manual:5");
        assert!(c.use_xla);
        assert_eq!(c.max_pending, 64);
        assert_eq!(c.extra.get("custom_knob").unwrap(), "7");
    }

    #[test]
    fn hash_inside_quoted_value_is_not_a_comment() {
        // Regression: split('#') used to truncate the value at the hash.
        let p = std::env::temp_dir().join(format!(
            "sptrsv_cfg_hash_{}.toml",
            std::process::id()
        ));
        std::fs::write(
            &p,
            "tuner_cache = \"/tmp/plans#v2.json\"  # real comment\nworkers = 2 # also real\n",
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(c.tuner_cache, "/tmp/plans#v2.json");
        assert_eq!(c.workers, 2);
    }

    #[test]
    fn interior_quotes_survive_unquoting() {
        // Regression: trim_matches('"') mangled values containing quotes.
        let p = std::env::temp_dir().join(format!(
            "sptrsv_cfg_quote_{}.toml",
            std::process::id()
        ));
        std::fs::write(&p, "label = \"he said \"hi\"\"\n").unwrap();
        let c = Config::from_file(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(c.extra.get("label").unwrap(), "he said \"hi\"");
        // And a bare unquoted value is left alone entirely.
        assert_eq!(unquote("plain"), "plain");
        assert_eq!(unquote("\""), "\"");
    }

    #[test]
    fn plan_is_validated_at_config_time() {
        let mut c = Config::default();
        assert!(c.set("plan", "nonsense").is_err());
        assert!(c.set("strategy", "avgcost+bogus").is_err());
        c.set("plan", "auto").unwrap();
        assert_eq!(c.plan.as_str(), "auto");
        c.set("plan", "avgcost+scheduled").unwrap();
        assert_eq!(c.plan.as_str(), "avgcost+scheduled");
        // The legacy `strategy` key stays an alias for `plan`.
        c.set("strategy", "scheduled").unwrap();
        assert_eq!(c.plan.as_str(), "scheduled");
        // And the --plan CLI flag carries composed plans.
        let args = Args::parse(
            ["serve", "--plan", "guarded:5+syncfree"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.plan.as_str(), "guarded:5+syncfree");
    }

    #[test]
    fn sched_keys_parse_and_merge() {
        let mut c = Config::default();
        assert_eq!(c.sched_block_target, crate::sched::DEFAULT_BLOCK_TARGET);
        assert_eq!(c.sched_stale_window, crate::sched::DEFAULT_STALE_WINDOW);
        c.set("sched_block_target", "128").unwrap();
        c.set("sched_stale_window", "0").unwrap();
        assert_eq!(c.sched_block_target, 128);
        assert_eq!(c.sched_stale_window, 0);
        assert!(c.set("sched_block_target", "big").is_err());
        let args = Args::parse(
            [
                "serve", "--strategy", "scheduled", "--sched-block-target", "512",
                "--sched-stale-window", "8", "--tuner-cache-ttl", "60",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.plan.as_str(), "scheduled");
        assert_eq!(c.sched_block_target, 512);
        assert_eq!(c.sched_stale_window, 8);
        assert_eq!(c.tuner_cache_ttl, 60);
    }

    #[test]
    fn trace_and_bench_keys_parse_and_merge() {
        let mut c = Config::default();
        assert!(!c.trace_enabled, "tracing is off by default");
        assert_eq!(c.bench_out_dir, "bench-out");
        assert_eq!(c.bench_requests, 0);
        c.set("trace_enabled", "true").unwrap();
        c.set("bench_out_dir", "/tmp/bench").unwrap();
        c.set("bench_requests", "64").unwrap();
        assert!(c.trace_enabled);
        assert_eq!(c.bench_out_dir, "/tmp/bench");
        assert_eq!(c.bench_requests, 64);
        assert!(c.set("bench_requests", "lots").is_err());
        let args = Args::parse(
            [
                "bench", "--trace-enabled", "false", "--bench-out-dir", "out",
                "--bench-requests", "8",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert!(!c.trace_enabled);
        assert_eq!(c.bench_out_dir, "out");
        assert_eq!(c.bench_requests, 8);
    }

    #[test]
    fn journal_keys_parse_and_merge() {
        let mut c = Config::default();
        assert!(!c.journal_enabled, "journaling is off by default");
        assert_eq!(c.journal_path, "sptrsv-journal.jsonl");
        c.set("journal_enabled", "true").unwrap();
        c.set("journal_path", "/tmp/traffic.jsonl").unwrap();
        assert!(c.journal_enabled);
        assert_eq!(c.journal_path, "/tmp/traffic.jsonl");
        let args = Args::parse(
            [
                "serve", "--journal-enabled", "false", "--journal-path", "j.jsonl",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert!(!c.journal_enabled);
        assert_eq!(c.journal_path, "j.jsonl");
    }

    #[test]
    fn executor_and_quota_keys_parse_and_merge() {
        let mut c = Config::default();
        assert_eq!(c.executor, "inprocess");
        assert_eq!(c.shard_count(), None);
        assert_eq!(c.tenant_max_pending, 0);
        assert_eq!(c.shard_timeout_ms, 30_000);
        assert_eq!(c.chaos_kill_shard_after, 0);
        c.set("executor", "sharded:3").unwrap();
        assert_eq!(c.shard_count(), Some(3));
        // Typos fail at config time, like a bad plan.
        assert!(c.set("executor", "distributed").is_err());
        assert!(c.set("executor", "sharded:0").is_err());
        assert!(c.set("executor", "sharded:two").is_err());
        c.set("tenant_max_pending", "16").unwrap();
        c.set("shard_worker_bin", "/usr/bin/sptrsv").unwrap();
        c.set("shard_timeout_ms", "5000").unwrap();
        c.set("chaos_kill_shard_after", "7").unwrap();
        assert_eq!(c.tenant_max_pending, 16);
        assert_eq!(c.shard_worker_bin, "/usr/bin/sptrsv");
        assert_eq!(c.shard_timeout_ms, 5_000);
        assert_eq!(c.chaos_kill_shard_after, 7);
        let args = Args::parse(
            [
                "serve", "--executor", "sharded:2", "--tenant-max-pending", "8",
                "--shard-timeout-ms", "1000", "--chaos-kill-shard-after", "2",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.executor, "sharded:2");
        assert_eq!(c.shard_count(), Some(2));
        assert_eq!(c.tenant_max_pending, 8);
        assert_eq!(c.shard_timeout_ms, 1_000);
        assert_eq!(c.chaos_kill_shard_after, 2);
    }

    #[test]
    fn analysis_cache_bounds_parse_and_merge() {
        let mut c = Config::default();
        assert_eq!(c.analysis_cache_cap, 0);
        assert_eq!(c.analysis_cache_ttl, 0);
        c.set("analysis_cache_cap", "32").unwrap();
        c.set("analysis_cache_ttl", "3600").unwrap();
        assert_eq!(c.analysis_cache_cap, 32);
        assert_eq!(c.analysis_cache_ttl, 3_600);
        assert!(c.set("analysis_cache_cap", "big").is_err());
        let args = Args::parse(
            ["serve", "--analysis-cache-cap", "4", "--analysis-cache-ttl", "60"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.analysis_cache_cap, 4);
        assert_eq!(c.analysis_cache_ttl, 60);
    }

    #[test]
    fn analysis_format_parses_and_merges() {
        let mut c = Config::default();
        assert_eq!(c.analysis_format, AnalysisFormat::Binary, "binary by default");
        c.set("analysis_format", "json").unwrap();
        assert_eq!(c.analysis_format, AnalysisFormat::Json);
        c.set("analysis_format", "binary").unwrap();
        assert_eq!(c.analysis_format, AnalysisFormat::Binary);
        // Typos fail at config time, like a bad plan.
        assert!(c.set("analysis_format", "yaml").is_err());
        let args = Args::parse(
            ["serve", "--analysis-format", "json"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.analysis_format, AnalysisFormat::Json);
    }

    #[test]
    fn accuracy_keys_parse_and_merge() {
        let mut c = Config::default();
        assert_eq!(c.default_tolerance, 0.0, "no tolerance unless asked");
        assert!(c.residual_check, "residual checking is on by default");
        assert_eq!(c.jacobi_max_sweeps, crate::iterative::DEFAULT_MAX_SWEEPS);
        c.set("default_tolerance", "1e-8").unwrap();
        c.set("residual_check", "false").unwrap();
        c.set("jacobi_max_sweeps", "64").unwrap();
        assert_eq!(c.default_tolerance, 1e-8);
        assert!(!c.residual_check);
        assert_eq!(c.jacobi_max_sweeps, 64);
        assert!(c.set("default_tolerance", "-1e-8").is_err());
        assert!(c.set("default_tolerance", "NaN").is_err());
        assert!(c.set("jacobi_max_sweeps", "0").is_err());
        let args = Args::parse(
            [
                "serve", "--default-tolerance", "1e-6", "--residual-check", "true",
                "--jacobi-max-sweeps", "32",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.default_tolerance, 1e-6);
        assert!(c.residual_check);
        assert_eq!(c.jacobi_max_sweeps, 32);
    }

    #[test]
    fn args_override() {
        let mut c = Config::default();
        let args = Args::parse(
            [
                "x", "--workers", "7", "--strategy", "none", "--max-pending", "9",
                "--other", "z",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.merge_args(&args).unwrap();
        assert_eq!(c.workers, 7);
        assert_eq!(c.plan.as_str(), "none");
        assert_eq!(c.max_pending, 9);
        assert!(!c.extra.contains_key("other")); // unknown flags left alone
    }

    #[test]
    fn bad_values_error() {
        let mut c = Config::default();
        assert!(c.set("workers", "many").is_err());
        let p = std::env::temp_dir().join(format!("sptrsv_cfg_bad_{}.toml", std::process::id()));
        std::fs::write(&p, "workers\n").unwrap();
        assert!(Config::from_file(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
