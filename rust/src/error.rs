//! Crate-wide error type.

#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("invalid input: {0}")]
    Invalid(String),
    #[error("io error: {0}")]
    Io(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("no artifact shape fits: {0}")]
    NoFit(String),
}
