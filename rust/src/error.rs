//! Crate-wide error type.

#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("invalid input: {0}")]
    Invalid(String),
    #[error("io error: {0}")]
    Io(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("no artifact shape fits: {0}")]
    NoFit(String),
    /// Matrix Market file did not parse; `line` is the 1-based line number
    /// of the offending content so operators can fix the file directly.
    #[error("matrix market parse error at line {line}: {msg}")]
    MatrixMarket { line: usize, msg: String },
}
