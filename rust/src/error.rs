//! Crate-wide error type, plus the typed error surface of the serving
//! coordinator.

#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("invalid input: {0}")]
    Invalid(String),
    #[error("io error: {0}")]
    Io(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("no artifact shape fits: {0}")]
    NoFit(String),
    /// Matrix Market file did not parse; `line` is the 1-based line number
    /// of the offending content so operators can fix the file directly.
    #[error("matrix market parse error at line {line}: {msg}")]
    MatrixMarket { line: usize, msg: String },
    /// Typed failure from the serving coordinator (see [`ServiceError`]).
    #[error("service error: {0}")]
    Service(#[from] ServiceError),
    /// A binary analysis artifact failed to validate (truncated, bad
    /// magic/version, checksum or alignment violation — see
    /// [`crate::artifact::ArtifactError`]). Cache loaders treat this as
    /// a miss and fall back to a fresh analysis.
    #[error("artifact error: {0}")]
    Artifact(#[from] crate::artifact::ArtifactError),
}

/// Everything that can go wrong between a `SolveHandle` and the service
/// thread. This replaces the stringly `Result<_, String>` that used to
/// cross the request channel: callers can now match on the failure class
/// (shed load on `Overloaded`, retry elsewhere on `Shutdown`, account for
/// `DeadlineExceeded`) instead of substring-probing a message.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ServiceError {
    /// a solve was requested for a matrix id that was never registered
    #[error("matrix '{0}' is not registered")]
    NotRegistered(String),
    /// the request is malformed (e.g. a right-hand side whose length does
    /// not match the registered matrix); rejected before it can reach a
    /// backend
    #[error("invalid request: {0}")]
    InvalidRequest(String),
    /// admission control rejected the request: the batcher already holds
    /// `pending` right-hand sides against a `max_pending` cap
    #[error("service overloaded: {pending} pending right-hand sides (max_pending = {max_pending})")]
    Overloaded { pending: usize, max_pending: usize },
    /// the request's deadline expired before it was dispatched; the solve
    /// was dropped instead of being served late
    #[error("deadline exceeded before dispatch")]
    DeadlineExceeded,
    /// the ticket was cancelled (explicitly, or by dropping it) before
    /// dispatch
    #[error("request cancelled")]
    Cancelled,
    /// the backend failed to prepare or solve
    #[error("backend failure: {0}")]
    Backend(String),
    /// the requested relative-residual tolerance could not be certified
    /// by any backend on the fallback ladder (iterative sweeps at the
    /// escalation cap, then the exact fallback) — the request states an
    /// accuracy no solve can deliver. The message carries the matrix id,
    /// the requested tolerance and the best residual achieved.
    #[error("accuracy unsatisfiable: {0}")]
    AccuracyUnsatisfiable(String),
    /// the service thread has stopped
    #[error("service stopped")]
    Shutdown,
}
