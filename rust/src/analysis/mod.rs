//! The first-class analysis artifact: the paper's one-time
//! graph-transformation cost made **explicit, reusable and persistable**.
//!
//! Production SpTRSV APIs split an *analysis* phase (inspect the
//! structure, build whatever the executor needs) from an *execution*
//! phase precisely so the analysis cost amortizes over repeated solves
//! (Li, cuSPARSE's `csrsv2_analysis`; Böhnlein et al. persist schedules
//! across runs). This crate's pipeline used to fuse the two: every
//! registration re-ran rewrite + coarsening + placement, and a numeric
//! value update — the dominant scenario in preconditioned iterative
//! solves, where the sparsity pattern is fixed across refactorizations —
//! threw all structure-derived work away. An [`Analysis`] owns:
//!
//! * the resolved [`SolvePlan`] (and the label it was requested under),
//! * the applied [`TransformResult`] (the rewrite axis's output),
//! * the built [`Schedule`] when the exec axis is `scheduled`,
//! * the structural [`Fingerprint`] guarding same-pattern reuse,
//! * the ready-to-run [`ExecSolver`].
//!
//! Lifecycle:
//!
//! * [`analyze`] — pay the full analysis once (tuner consulted for
//!   `auto`; its race donates the winning lane's already-built transform
//!   and backend instead of discarding them).
//! * [`Analysis::solve`] / [`Analysis::solve_many`] — execute, any
//!   number of times.
//! * [`Analysis::refresh_values`] — same-pattern value update: verifies
//!   the fingerprint, re-derives the folded equations by the
//!   [`renumeric`] replay and rebuilds the numeric solver **without**
//!   re-running rewrite analysis, coarsening or ETF placement (the
//!   [`BuildCounters`] expose exactly which passes ran).
//! * [`Analysis::save`] / [`Analysis::load`] — persistence of the
//!   structural artifacts (plan + transform skeleton + schedule
//!   placements). The default format is the binary mmap-able `.spa`
//!   container ([`crate::artifact`]); loads sniff the format and
//!   re-numeric against the given matrix, so a known structure skips
//!   coarsening and placement entirely — even across processes, and
//!   even on a pool smaller than the one the analysis was placed for
//!   (the binary artifact stores placements for several worker counts).

pub mod binary;
pub mod cache;
pub mod persist;
pub mod renumeric;

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::sched::{SchedOptions, Schedule, ScheduledSolver};
use crate::solver::dispatch::ExecSolver;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::trace::PhaseTimes;
use crate::transform::{Exec, PlanSpec, ResolvedPlan, Rewrite, SolvePlan, TransformResult};
use crate::tuner::{Fingerprint, TunedPlan, Tuner, TunerOptions};

pub use cache::AnalysisCache;
pub use renumeric::StructuralTransform;

/// On-disk representation for persisted analyses. Binary (the `.spa`
/// container, `crate::artifact`) is the default: it loads by mmap +
/// validate instead of a JSON parse + rebuild, and stores placements for
/// several worker counts. JSON remains readable for migration — loads
/// sniff the file content, so the knob only governs what `save` writes —
/// and its write path is kept one release behind the `analysis_format`
/// config key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AnalysisFormat {
    /// schema-stamped JSON (`analysis/persist.rs`), the legacy format
    Json,
    /// binary section container with per-worker-count placements
    #[default]
    Binary,
}

impl AnalysisFormat {
    pub fn parse(s: &str) -> Result<AnalysisFormat, String> {
        match s {
            "json" => Ok(AnalysisFormat::Json),
            "binary" | "spa" => Ok(AnalysisFormat::Binary),
            other => Err(format!(
                "unknown analysis format '{other}' (expected json or binary)"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            AnalysisFormat::Json => "json",
            AnalysisFormat::Binary => "binary",
        }
    }

    /// Filename suffix the analysis cache uses for this format.
    pub fn suffix(self) -> &'static str {
        match self {
            AnalysisFormat::Json => "analysis.json",
            AnalysisFormat::Binary => "spa",
        }
    }
}

impl std::fmt::Display for AnalysisFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Knobs for [`analyze`]: the parallel substrate and the scheduling
/// fallbacks. Callers embedded in the coordinator pass the serving pool
/// and config defaults; standalone callers can rely on the defaults.
#[derive(Debug, Clone, Default)]
pub struct AnalyzeOptions {
    /// worker threads when no pool is lent (0 = one per available core,
    /// capped at 8 — the tuner's convention)
    pub workers: usize,
    /// run on this shared pool instead of spawning one
    pub pool: Option<Arc<Pool>>,
    /// fallback scheduling knobs for plans that leave them unset
    pub sched: SchedOptions,
}

impl AnalyzeOptions {
    fn resolve_pool(&self) -> Arc<Pool> {
        match &self.pool {
            Some(p) => Arc::clone(p),
            None => {
                let w = if self.workers > 0 {
                    self.workers
                } else {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(4)
                        .min(8)
                };
                Arc::new(Pool::new(w))
            }
        }
    }
}

/// How many structural passes an [`Analysis`] has paid for, cumulatively.
/// `refresh_values` must leave `rewrite`/`coarsen`/`placement` flat (it
/// only bumps `renumeric`), and an analysis loaded from disk starts with
/// zero coarsening and placement — these counters are the proof.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BuildCounters {
    /// full rewrite-analysis passes (costMap projection + commits)
    pub rewrite_passes: u64,
    /// chain-collapsing / level-grouping coarsening passes
    pub coarsen_passes: u64,
    /// greedy ETF block-placement passes
    pub placement_passes: u64,
    /// value-only numeric replays ([`renumeric`])
    pub renumeric_passes: u64,
}

impl std::ops::Add for BuildCounters {
    type Output = BuildCounters;

    fn add(self, o: BuildCounters) -> BuildCounters {
        BuildCounters {
            rewrite_passes: self.rewrite_passes + o.rewrite_passes,
            coarsen_passes: self.coarsen_passes + o.coarsen_passes,
            placement_passes: self.placement_passes + o.placement_passes,
            renumeric_passes: self.renumeric_passes + o.renumeric_passes,
        }
    }
}

/// A fully prepared `(matrix, plan)` ready to solve — see the module docs
/// for the lifecycle.
pub struct Analysis {
    m: Arc<Csr>,
    plan: SolvePlan,
    plan_name: String,
    fingerprint: Fingerprint,
    t: Arc<TransformResult>,
    /// the static schedule, when the exec axis is `scheduled` (shared
    /// with the solver; survives value refreshes untouched)
    schedule: Option<Arc<Schedule>>,
    solver: ExecSolver,
    pool: Arc<Pool>,
    sched: SchedOptions,
    counters: BuildCounters,
    prepare_time: Duration,
    /// wall-clock split of the passes behind `counters`, for the same
    /// build/refresh window as `prepare_time` (zeros when the work was
    /// donated by a tuner race, whose lanes are timed competitively, not
    /// per phase)
    phase_times: PhaseTimes,
}

/// A guarded rewrite caps the folded b-coefficient magnitude (the §IV
/// numerical-stability guard). The structural *decisions* are value-free,
/// but the cap is about the VALUES — so every value-only replay (a
/// refresh, or a load against a new refactorization) must re-check it: a
/// refactorization whose diagonals shrank can push the replayed folds
/// past a cap a fresh analysis would have rejected. Violations demand a
/// re-analysis, not a silently less-stable serve.
pub(crate) fn check_guard_cap(plan: &SolvePlan, t: &TransformResult) -> Result<(), Error> {
    if let Rewrite::AvgLevelCost(o) = &plan.rewrite {
        if let Some(cap) = o.constraints.max_bcoeff_magnitude {
            let got = t.stats.max_bcoeff_magnitude;
            if got > cap {
                return Err(Error::Invalid(format!(
                    "value replay violates the guarded magnitude cap \
                     (|b-coefficient| {got:.3e} > {cap:.3e}); the new values \
                     need a fresh analysis"
                )));
            }
        }
    }
    Ok(())
}

/// Run the analysis phase for `m` under `spec`. `auto` consults a tuner
/// configured on the same pool and scheduling knobs; the race's winning
/// lane donates its already-built transform and execution backend to the
/// returned analysis instead of discarding them.
pub fn analyze(m: &Csr, spec: &PlanSpec, opts: &AnalyzeOptions) -> Result<Analysis, Error> {
    analyze_arc(Arc::new(m.clone()), spec, opts)
}

/// [`analyze`] without the defensive copy for callers already holding an
/// `Arc<Csr>`.
pub fn analyze_arc(
    m: Arc<Csr>,
    spec: &PlanSpec,
    opts: &AnalyzeOptions,
) -> Result<Analysis, Error> {
    let start = Instant::now();
    m.validate_lower_triangular()?;
    let pool = opts.resolve_pool();
    match spec.resolve(&PlanSpec::Default) {
        ResolvedPlan::Auto => {
            // Fully default options route through the lazily-initialized
            // process-wide tuner: repeated `analyze(auto)` calls on the
            // same structure answer from its plan cache instead of
            // re-racing per call (its default worker count matches
            // `resolve_pool`'s, so donated schedules always fit the
            // pool). Custom pools/knobs get a dedicated tuner configured
            // to match them exactly.
            let default_opts =
                opts.workers == 0 && opts.pool.is_none() && opts.sched == SchedOptions::default();
            let tp = if default_opts {
                crate::tuner::process_choose(&m)?
            } else {
                let mut tuner = Tuner::new(TunerOptions {
                    workers: pool.len(),
                    sched: opts.sched,
                    pool: Some(Arc::clone(&pool)),
                    ..Default::default()
                });
                tuner.choose_arc(&m)?
            };
            Analysis::from_tuned(m, tp, pool, opts.sched, start)
        }
        ResolvedPlan::Fixed(name, plan) => {
            let fp = Fingerprint::of(&m);
            Analysis::build(m, fp, name, plan, pool, opts.sched, start)
        }
    }
}

impl Analysis {
    /// Full fresh build: apply the rewrite, build the schedule when the
    /// plan calls for one, wrap the backend. The caller passes the
    /// already-computed `fingerprint` — the O(nnz) structural hash is
    /// paid once per registration, not once per layer.
    pub(crate) fn build(
        m: Arc<Csr>,
        fingerprint: Fingerprint,
        plan_name: String,
        plan: SolvePlan,
        pool: Arc<Pool>,
        sched: SchedOptions,
        start: Instant,
    ) -> Result<Analysis, Error> {
        let t0 = Instant::now();
        let t = Arc::new(plan.apply(&m));
        let mut phase_times = PhaseTimes {
            rewrite_us: t0.elapsed().as_micros() as u64,
            ..Default::default()
        };
        t.validate(&m).map_err(Error::Invalid)?;
        let mut counters = BuildCounters {
            rewrite_passes: u64::from(plan.rewrite != Rewrite::None),
            ..Default::default()
        };
        let schedule = match &plan.exec {
            Exec::Scheduled(o) => {
                let o = o.or(sched);
                counters.coarsen_passes += 1;
                counters.placement_passes += 1;
                let (s, coarsen, placement) =
                    Schedule::build_timed(&m, &t, pool.len(), o.block_target());
                phase_times.coarsen_us = coarsen.as_micros() as u64;
                phase_times.placement_us = placement.as_micros() as u64;
                Some(Arc::new(s))
            }
            _ => None,
        };
        let solver = ExecSolver::build_with(
            Arc::clone(&m),
            Arc::clone(&t),
            &plan.exec,
            Arc::clone(&pool),
            sched,
            schedule.clone(),
        )?;
        Ok(Analysis {
            m,
            plan,
            plan_name,
            fingerprint,
            t,
            schedule,
            solver,
            pool,
            sched,
            counters,
            prepare_time: start.elapsed(),
            phase_times,
        })
    }

    /// Adopt a tuner decision: the race already applied the winning
    /// rewrite and built the winning backend on the caller's pool — reuse
    /// both rather than re-deriving them.
    pub(crate) fn from_tuned(
        m: Arc<Csr>,
        tp: TunedPlan,
        pool: Arc<Pool>,
        sched: SchedOptions,
        start: Instant,
    ) -> Result<Analysis, Error> {
        let TunedPlan {
            fingerprint,
            plan_name,
            plan,
            transform: t,
            solver,
            ..
        } = tp;
        t.validate(&m).map_err(Error::Invalid)?;
        let mut counters = BuildCounters {
            rewrite_passes: u64::from(plan.rewrite != Rewrite::None),
            ..Default::default()
        };
        let mut phase_times = PhaseTimes::default();
        let (solver, schedule) = match solver {
            Some(s) => {
                // Donated by the race: the passes ran inside the winning
                // lane, timed competitively rather than per phase — the
                // counters still record them, the phase clocks stay zero.
                let schedule = s.scheduled().map(|ss| Arc::clone(&ss.schedule));
                if schedule.is_some() {
                    counters.coarsen_passes += 1;
                    counters.placement_passes += 1;
                }
                (s, schedule)
            }
            None => {
                // Plan-cache hit: the tuner applied the cached plan but
                // built no backend — do it here.
                let schedule = match &plan.exec {
                    Exec::Scheduled(o) => {
                        let o = o.or(sched);
                        counters.coarsen_passes += 1;
                        counters.placement_passes += 1;
                        let (s, coarsen, placement) =
                            Schedule::build_timed(&m, &t, pool.len(), o.block_target());
                        phase_times.coarsen_us = coarsen.as_micros() as u64;
                        phase_times.placement_us = placement.as_micros() as u64;
                        Some(Arc::new(s))
                    }
                    _ => None,
                };
                let s = ExecSolver::build_with(
                    Arc::clone(&m),
                    Arc::clone(&t),
                    &plan.exec,
                    Arc::clone(&pool),
                    sched,
                    schedule.clone(),
                )?;
                (s, schedule)
            }
        };
        Ok(Analysis {
            m,
            plan,
            plan_name,
            fingerprint,
            t,
            schedule,
            solver,
            pool,
            sched,
            counters,
            prepare_time: start.elapsed(),
            phase_times,
        })
    }

    pub fn matrix(&self) -> &Arc<Csr> {
        &self.m
    }

    pub fn plan(&self) -> &SolvePlan {
        &self.plan
    }

    /// Label the analysis was requested under (source text for named
    /// plans, the canonical winner name under `auto`).
    pub fn plan_name(&self) -> &str {
        &self.plan_name
    }

    pub fn fingerprint(&self) -> Fingerprint {
        self.fingerprint
    }

    pub fn transform(&self) -> &Arc<TransformResult> {
        &self.t
    }

    /// The static schedule, when the plan's exec axis is `scheduled`.
    pub fn schedule(&self) -> Option<&Arc<Schedule>> {
        self.schedule.as_ref()
    }

    pub fn solver(&self) -> &ExecSolver {
        &self.solver
    }

    /// The scheduled backend, when that is what this analysis runs on.
    pub fn scheduled(&self) -> Option<&ScheduledSolver> {
        self.solver.scheduled()
    }

    /// Structural passes this analysis has paid for so far (see
    /// [`BuildCounters`]).
    pub fn rebuilds(&self) -> BuildCounters {
        self.counters
    }

    /// Wall-clock split of the most recent build/refresh across the
    /// analysis phases (rewrite / coarsen / placement / renumeric). All
    /// zeros when the artifacts were donated by a tuner race, whose lanes
    /// are timed competitively rather than per phase.
    pub fn phase_times(&self) -> PhaseTimes {
        self.phase_times
    }

    /// Wall-clock of the most recent build/refresh (the offline cost the
    /// paper discusses).
    pub fn prepare_time(&self) -> Duration {
        self.prepare_time
    }

    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solver.solve(b)
    }

    pub fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        self.solver.solve_into(b, x)
    }

    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        bs.iter().map(|b| self.solver.solve(b)).collect()
    }

    /// Same-pattern value update, in place: checks the structural
    /// fingerprint, replays the numerics ([`renumeric`]) and rebuilds the
    /// numeric solver. The schedule, the rewrite decisions and the level
    /// structure are all reused — `rebuilds()` shows only the
    /// `renumeric_passes` counter moving.
    pub fn refresh_values(&mut self, m: &Csr) -> Result<(), Error> {
        *self = self.refreshed(m)?;
        Ok(())
    }

    /// [`Analysis::refresh_values`] as a pure function: build the
    /// refreshed analysis next to this one (the coordinator uses this to
    /// swap a shared `Arc<Analysis>` while in-flight solves drain against
    /// the old one).
    pub fn refreshed(&self, m: &Csr) -> Result<Analysis, Error> {
        let start = Instant::now();
        let fp = Fingerprint::of(m);
        if fp != self.fingerprint {
            return Err(Error::Invalid(format!(
                "refresh_values: sparsity pattern changed (fingerprint {fp}, analysis has {})",
                self.fingerprint
            )));
        }
        let m = Arc::new(m.clone());
        let t0 = Instant::now();
        let t = Arc::new(
            renumeric::renumeric(&m, &StructuralTransform::of(&self.t))
                .map_err(Error::Invalid)?,
        );
        let renumeric_us = t0.elapsed().as_micros() as u64;
        check_guard_cap(&self.plan, &t)?;
        let solver = ExecSolver::build_with(
            Arc::clone(&m),
            Arc::clone(&t),
            &self.plan.exec,
            Arc::clone(&self.pool),
            self.sched,
            self.schedule.clone(),
        )?;
        Ok(Analysis {
            m,
            plan: self.plan.clone(),
            plan_name: self.plan_name.clone(),
            fingerprint: self.fingerprint,
            t,
            schedule: self.schedule.clone(),
            solver,
            pool: Arc::clone(&self.pool),
            sched: self.sched,
            counters: BuildCounters {
                renumeric_passes: self.counters.renumeric_passes + 1,
                ..self.counters
            },
            prepare_time: start.elapsed(),
            // per-window clocks: a refresh pays only the value replay
            phase_times: PhaseTimes {
                renumeric_us,
                ..Default::default()
            },
        })
    }

    /// Persist the structural artifacts (plan + transform skeleton +
    /// schedule placements) in the default format — the binary `.spa`
    /// container (see [`AnalysisFormat`]). Values are **not** stored — a
    /// load re-numerics against whatever same-pattern matrix it is
    /// given, so one file serves every refactorization of the structure.
    pub fn save(&self, path: &Path) -> Result<(), Error> {
        self.save_format(path, AnalysisFormat::default())
    }

    /// [`Analysis::save`] with an explicit format (the `analysis_format`
    /// config key / `--analysis-format` flag; JSON is kept for one
    /// release as a migration path).
    pub fn save_format(&self, path: &Path, format: AnalysisFormat) -> Result<(), Error> {
        match format {
            AnalysisFormat::Json => persist::save(self, path),
            AnalysisFormat::Binary => binary::save(self, path),
        }
    }

    /// Restore an analysis from [`Analysis::save`] output for `m`, which
    /// must have the same sparsity structure (fingerprint-checked). The
    /// rewrite analysis, coarsening and ETF placement are all skipped;
    /// only the [`renumeric`] value replay runs. The format is sniffed
    /// from the file itself (binary magic vs JSON), so both formats stay
    /// loadable regardless of the configured write format.
    pub fn load(path: &Path, m: &Csr, opts: &AnalyzeOptions) -> Result<Analysis, Error> {
        Self::load_arc(path, Arc::new(m.clone()), opts)
    }

    /// [`Analysis::load`] without the matrix copy.
    pub fn load_arc(path: &Path, m: Arc<Csr>, opts: &AnalyzeOptions) -> Result<Analysis, Error> {
        if sniff_binary(path) {
            binary::load(path, m, opts)
        } else {
            persist::load(path, m, opts)
        }
    }
}

/// True when `path` starts with the binary artifact magic. Unreadable
/// files report false; the JSON loader then produces the actual error.
fn sniff_binary(path: &Path) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut head = [0u8; 8];
    f.read_exact(&mut head).is_ok() && head == crate::artifact::MAGIC
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    fn perturb(m: &Csr, seed: u64) -> Csr {
        let mut m2 = m.clone();
        let mut rng = Rng::new(seed);
        for v in &mut m2.data {
            *v *= 1.0 + 0.1 * rng.uniform(-1.0, 1.0);
        }
        m2
    }

    fn opts() -> AnalyzeOptions {
        AnalyzeOptions {
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn save_formats_sniffed_on_load_and_agree() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = analyze(&m, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts()).unwrap();
        let dir = std::env::temp_dir();
        let pj = dir.join(format!("sptrsv_fmt_{}.json", std::process::id()));
        let pb = dir.join(format!("sptrsv_fmt_{}.spa", std::process::id()));
        a.save_format(&pj, AnalysisFormat::Json).unwrap();
        a.save_format(&pb, AnalysisFormat::Binary).unwrap();
        // The JSON file is text, the binary one leads with the magic.
        let jb = std::fs::read(&pj).unwrap();
        assert_eq!(jb.first(), Some(&b'{'));
        let bb = std::fs::read(&pb).unwrap();
        assert_eq!(&bb[..8], &crate::artifact::MAGIC);
        assert!(!sniff_binary(&pj));
        assert!(sniff_binary(&pb));
        // Both load through the same sniffing entry point, both pay zero
        // structural passes, and both solve identically.
        let from_json = Analysis::load(&pj, &m, &opts()).unwrap();
        let from_bin = Analysis::load(&pb, &m, &opts()).unwrap();
        for l in [&from_json, &from_bin] {
            assert_eq!(l.rebuilds().coarsen_passes, 0);
            assert_eq!(l.rebuilds().placement_passes, 0);
            assert_eq!(l.rebuilds().renumeric_passes, 1);
        }
        let b = vec![1.0; m.nrows];
        assert_eq!(from_json.solve(&b), from_bin.solve(&b));
        std::fs::remove_file(&pj).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn analysis_format_parses() {
        assert_eq!(AnalysisFormat::parse("json"), Ok(AnalysisFormat::Json));
        assert_eq!(AnalysisFormat::parse("binary"), Ok(AnalysisFormat::Binary));
        assert_eq!(AnalysisFormat::default(), AnalysisFormat::Binary);
        assert!(AnalysisFormat::parse("yaml").is_err());
        assert_eq!(AnalysisFormat::Binary.suffix(), "spa");
        assert_eq!(AnalysisFormat::Json.suffix(), "analysis.json");
    }

    #[test]
    fn analyze_then_solve() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = analyze(&m, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts()).unwrap();
        assert_eq!(a.plan_name(), "avgcost+scheduled");
        assert!(a.schedule().is_some());
        assert!(a.transform().stats.rows_rewritten > 0);
        let c = a.rebuilds();
        assert_eq!(c.rewrite_passes, 1);
        assert_eq!(c.coarsen_passes, 1);
        assert_eq!(c.placement_passes, 1);
        assert_eq!(c.renumeric_passes, 0);
        let b = vec![1.0; m.nrows];
        let x = a.solve(&b);
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let xs = a.solve_many(&[b.clone(), b.clone()]);
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[0], x);
    }

    #[test]
    fn refresh_values_skips_structural_work() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let mut a =
            analyze(&m, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts()).unwrap();
        let before = a.rebuilds();
        let sched_before = Arc::as_ptr(a.schedule().unwrap());
        let m2 = perturb(&m, 7);
        a.refresh_values(&m2).unwrap();
        let after = a.rebuilds();
        // The structural counters stay flat; only the replay ran.
        assert_eq!(after.rewrite_passes, before.rewrite_passes);
        assert_eq!(after.coarsen_passes, before.coarsen_passes);
        assert_eq!(after.placement_passes, before.placement_passes);
        assert_eq!(after.renumeric_passes, before.renumeric_passes + 1);
        // The schedule object itself is reused, not rebuilt.
        assert_eq!(Arc::as_ptr(a.schedule().unwrap()), sched_before);
        // Phase clocks are per-window: a refresh charges no structural
        // phase any time (the replay itself may round to 0µs, so only the
        // structural clocks are asserted).
        let pt = a.phase_times();
        assert_eq!(pt.rewrite_us, 0);
        assert_eq!(pt.coarsen_us, 0);
        assert_eq!(pt.placement_us, 0);
        // And the refreshed analysis solves the NEW system.
        let b = vec![1.0; m2.nrows];
        let x = a.solve(&b);
        assert!(m2.residual_inf(&x, &b) < 1e-9);
        // Within 1e-12 of a from-scratch analysis of the new values.
        let fresh =
            analyze(&m2, &PlanSpec::parse("avgcost+scheduled").unwrap(), &opts()).unwrap();
        assert_allclose(&x, &fresh.solve(&b), 1e-12, 1e-12).unwrap();
    }

    #[test]
    fn refresh_reenforces_the_guarded_magnitude_cap() {
        // Build under a guarded rewrite whose cap the original values
        // satisfy, then refresh with a refactorization whose shrunken
        // diagonals push the replayed folds far past the cap: the refresh
        // must refuse (a fresh analysis would have rejected those
        // rewrites), leaving the analysis serving the old values.
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let mut a = analyze(&m, &PlanSpec::parse("guarded:20:1e6").unwrap(), &opts()).unwrap();
        assert!(a.transform().stats.rows_rewritten > 0);
        assert!(a.transform().stats.max_bcoeff_magnitude <= 1e6);
        let mut m2 = m.clone();
        // Shrink every diagonal by 1e8: substitution divides by the
        // dependency diagonal, so the replayed b-coefficients explode.
        for i in 0..m2.nrows {
            let d = m2.indptr[i + 1] - 1;
            m2.data[d] *= 1e-8;
        }
        let err = a.refresh_values(&m2).unwrap_err();
        assert!(
            err.to_string().contains("guarded magnitude cap"),
            "unexpected error: {err}"
        );
        // The analysis is untouched: it still solves the ORIGINAL system.
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&a.solve(&b), &b) < 1e-9);
    }

    #[test]
    fn refresh_rejects_changed_pattern() {
        let m = generate::tridiagonal(50, &Default::default());
        let mut a = analyze(&m, &PlanSpec::parse("manual:5").unwrap(), &opts()).unwrap();
        let other = generate::tridiagonal(51, &Default::default());
        assert!(a.refresh_values(&other).is_err());
        // The analysis is untouched and still solves.
        let b = vec![1.0; 50];
        assert!(m.residual_inf(&a.solve(&b), &b) < 1e-10);
    }

    #[test]
    fn auto_spec_consults_the_tuner_and_adopts_its_artifacts() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let a = analyze(&m, &PlanSpec::Auto, &opts()).unwrap();
        // The tuned plan parses and the backend matches its exec axis.
        let plan = SolvePlan::parse(a.plan_name()).unwrap();
        assert_eq!(&plan, a.plan());
        assert_eq!(a.solver().scheduled().is_some(), a.schedule().is_some());
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&a.solve(&b), &b) < 1e-9);
    }

    #[test]
    fn jacobi_exec_flows_through_analysis_and_refresh() {
        // The iterative backends ride the same analyze/refresh lifecycle
        // as every exact exec: no schedule is built, the rewrite still
        // applies, and a value refresh replays numerics without
        // structural passes.
        let m = generate::tridiagonal(120, &Default::default());
        let mut a = analyze(&m, &PlanSpec::parse("manual:5+jacobi:4").unwrap(), &opts()).unwrap();
        assert!(a.schedule().is_none());
        assert_eq!(a.rebuilds().coarsen_passes, 0);
        let b = vec![1.0; m.nrows];
        let j = a.solver().jacobi().unwrap();
        let mut x = vec![0.0; m.nrows];
        // At the nilpotency index the iteration is exact.
        j.solve_with_sweeps(&b, j.exact_sweeps(), &mut x);
        assert!(m.residual_inf(&x, &b) < 1e-9);
        let m2 = perturb(&m, 5);
        a.refresh_values(&m2).unwrap();
        assert_eq!(a.rebuilds().renumeric_passes, 1);
        let j = a.solver().jacobi().unwrap();
        let mut x2 = vec![0.0; m.nrows];
        j.solve_with_sweeps(&b, j.exact_sweeps(), &mut x2);
        assert!(m2.residual_inf(&x2, &b) < 1e-9);
    }

    #[test]
    fn every_exec_axis_refreshes() {
        let m = generate::lung2_like(&GenOptions::with_scale(0.03));
        let mut rng = Rng::new(11);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        for plan in ["avgcost+levelset", "avgcost+scheduled", "avgcost+syncfree", "avgcost+reorder"] {
            let mut a = analyze(&m, &PlanSpec::parse(plan).unwrap(), &opts()).unwrap();
            let m2 = perturb(&m, 23);
            a.refresh_values(&m2).unwrap();
            let x = a.solve(&b);
            assert!(m2.residual_inf(&x, &b) < 1e-9, "{plan}");
            let x_ref = crate::solver::serial::solve(&m2, &b);
            assert_allclose(&x, &x_ref, 1e-9, 1e-11).unwrap_or_else(|e| panic!("{plan}: {e}"));
        }
    }
}
