//! Schema-stamped serialization of an [`Analysis`]'s **structural**
//! artifacts: the resolved plan, the transform skeleton (levels +
//! rewrite decisions) and the built schedule.
//!
//! Matrix *values* are deliberately not stored: loading re-numerics the
//! folded equations against whatever same-pattern matrix is supplied
//! ([`super::renumeric`]), so one file serves every refactorization of a
//! structure — the same reason the tuner's plan cache keys on the
//! structural fingerprint. The format is the crate's own minimal JSON
//! (`util::json`): greppable, diffable, and stable across toolchains.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::error::Error;
use crate::sched::schedule::{Schedule, ScheduleStats};
use crate::sched::Block;
use crate::solver::dispatch::ExecSolver;
use crate::sparse::Csr;
use crate::trace::PhaseTimes;
use crate::transform::rewrite::RewriteRecord;
use crate::transform::{Exec, Rewrite, SolvePlan};
use crate::tuner::Fingerprint;
use crate::util::json::Json;

use super::renumeric::{renumeric, StructuralTransform};
use super::{Analysis, AnalyzeOptions, BuildCounters};

/// Format version stamped on every analysis file. Files written under a
/// different version are rejected on load (the caller falls back to a
/// fresh [`super::analyze`]): a persisted schedule is only as good as the
/// executor that will run it, so bump this whenever the transform replay,
/// schedule layout or solver semantics change incompatibly.
pub const ANALYSIS_SCHEMA_VERSION: u64 = 1;

const KIND: &str = "sptrsv-analysis";

fn u32s(v: &[u32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn usizes(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn parse_u32s(j: &Json, what: &str) -> Result<Vec<u32>, Error> {
    j.as_arr()
        .ok_or_else(|| Error::Invalid(format!("analysis file: {what} is not an array")))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|n| n as u32)
                .ok_or_else(|| Error::Invalid(format!("analysis file: bad entry in {what}")))
        })
        .collect()
}

fn parse_usizes(j: &Json, what: &str) -> Result<Vec<usize>, Error> {
    Ok(parse_u32s(j, what)?.into_iter().map(|x| x as usize).collect())
}

fn get<'a>(j: &'a Json, key: &str) -> Result<&'a Json, Error> {
    j.get(key)
        .ok_or_else(|| Error::Invalid(format!("analysis file: missing '{key}'")))
}

/// Serialize `a`'s structural artifacts to `path` (write-then-rename, so
/// a concurrent reader never observes a truncated file).
pub fn save(a: &Analysis, path: &Path) -> Result<(), Error> {
    let t = &a.t;
    let rewritten: Vec<u32> = (0..t.equations.len() as u32)
        .filter(|&i| t.equations[i as usize].is_some())
        .collect();
    let log: Vec<Json> = t
        .log
        .iter()
        .map(|r| {
            Json::Arr(vec![
                Json::Num(r.row as f64),
                Json::Num(r.from_level as f64),
                Json::Num(r.to_level as f64),
                Json::Num(r.substitutions as f64),
            ])
        })
        .collect();
    let mut root = vec![
        ("kind", Json::Str(KIND.to_string())),
        ("version", Json::Num(ANALYSIS_SCHEMA_VERSION as f64)),
        ("fingerprint", Json::Str(a.fingerprint.to_hex())),
        ("plan", Json::Str(a.plan.to_string())),
        ("plan_name", Json::Str(a.plan_name.clone())),
        ("nrows", Json::Num(a.m.nrows as f64)),
        (
            "levels",
            Json::Arr(t.levels.iter().map(|l| u32s(l)).collect()),
        ),
        ("rewritten", u32s(&rewritten)),
        ("log", Json::Arr(log)),
        ("levels_before", Json::Num(t.stats.levels_before as f64)),
        (
            "avg_level_cost_before",
            Json::Num(t.stats.avg_level_cost_before),
        ),
        (
            "total_level_cost_before",
            Json::Num(t.stats.total_level_cost_before as f64),
        ),
    ];
    if let Some(s) = &a.schedule {
        let blocks: Vec<Json> = s
            .blocks
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("rows", u32s(&b.rows)),
                    ("cost", Json::Num(b.cost as f64)),
                    ("level", Json::Num(b.level as f64)),
                ])
            })
            .collect();
        let st = &s.stats;
        root.push((
            "schedule",
            Json::obj(vec![
                ("nworkers", Json::Num(s.nworkers as f64)),
                ("blocks", Json::Arr(blocks)),
                ("worker_of", u32s(&s.worker_of)),
                ("pred_ptr", usizes(&s.pred_ptr)),
                ("preds", u32s(&s.preds)),
                (
                    "stats",
                    Json::obj(vec![
                        ("num_blocks", Json::Num(st.num_blocks as f64)),
                        ("chain_blocks", Json::Num(st.chain_blocks as f64)),
                        ("cut_edges", Json::Num(st.cut_edges as f64)),
                        ("max_worker_load", Json::Num(st.max_worker_load as f64)),
                        ("total_cost", Json::Num(st.total_cost as f64)),
                        (
                            "levelset_barriers",
                            Json::Num(st.levelset_barriers as f64),
                        ),
                        ("workers", Json::Num(st.workers as f64)),
                    ]),
                ),
            ]),
        ));
    }
    let text = Json::obj(root).to_string();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::Io(format!("create {}: {e}", dir.display())))?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, text).map_err(|e| Error::Io(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, path).map_err(|e| {
        std::fs::remove_file(&tmp).ok();
        Error::Io(format!("rename {} -> {}: {e}", tmp.display(), path.display()))
    })
}

/// Deserialize an analysis for `m`: verify the schema and the structural
/// fingerprint, replay the numerics against `m`'s values, and adopt the
/// persisted schedule when it fits the pool (rebuilding it — counted —
/// only when the pool has fewer workers than the schedule was placed
/// for).
pub fn load(path: &Path, m: Arc<Csr>, opts: &AnalyzeOptions) -> Result<Analysis, Error> {
    let start = Instant::now();
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
    let root = Json::parse(&text).map_err(|e| Error::Invalid(format!("analysis file: {e}")))?;
    if root.get("kind").and_then(Json::as_str) != Some(KIND) {
        return Err(Error::Invalid(format!(
            "{} is not an analysis file",
            path.display()
        )));
    }
    let version = get(&root, "version")?.as_f64().unwrap_or(0.0) as u64;
    if version != ANALYSIS_SCHEMA_VERSION {
        return Err(Error::Invalid(format!(
            "analysis file schema v{version}, this build reads v{ANALYSIS_SCHEMA_VERSION}"
        )));
    }
    let fp_str = get(&root, "fingerprint")?
        .as_str()
        .ok_or_else(|| Error::Invalid("analysis file: bad fingerprint".into()))?;
    let fingerprint = Fingerprint::from_hex(fp_str)
        .ok_or_else(|| Error::Invalid("analysis file: bad fingerprint".into()))?;
    let actual = Fingerprint::of(&m);
    if fingerprint != actual {
        return Err(Error::Invalid(format!(
            "analysis was saved for structure {fingerprint}, matrix has {actual}"
        )));
    }
    let nrows = get(&root, "nrows")?.as_usize().unwrap_or(0);
    if nrows != m.nrows {
        return Err(Error::Invalid(format!(
            "analysis was saved for {nrows} rows, matrix has {}",
            m.nrows
        )));
    }
    let plan_str = get(&root, "plan")?
        .as_str()
        .ok_or_else(|| Error::Invalid("analysis file: bad plan".into()))?;
    let plan = SolvePlan::parse(plan_str).map_err(Error::Invalid)?;
    let plan_name = root
        .get("plan_name")
        .and_then(Json::as_str)
        .unwrap_or(plan_str)
        .to_string();

    // Transform skeleton -> renumeric replay against m's values.
    let levels: Vec<Vec<u32>> = get(&root, "levels")?
        .as_arr()
        .ok_or_else(|| Error::Invalid("analysis file: levels is not an array".into()))?
        .iter()
        .map(|l| parse_u32s(l, "levels"))
        .collect::<Result<_, _>>()?;
    let mut level_of = vec![u32::MAX; m.nrows];
    for (lvl, rows) in levels.iter().enumerate() {
        for &r in rows {
            let ru = r as usize;
            if ru >= m.nrows || level_of[ru] != u32::MAX {
                return Err(Error::Invalid(format!(
                    "analysis file: row {r} out of range or in two levels"
                )));
            }
            level_of[ru] = lvl as u32;
        }
    }
    if level_of.iter().any(|&l| l == u32::MAX) {
        return Err(Error::Invalid("analysis file: levels do not cover all rows".into()));
    }
    let mut rewritten = vec![false; m.nrows];
    for r in parse_u32s(get(&root, "rewritten")?, "rewritten")? {
        let ru = r as usize;
        if ru >= m.nrows {
            return Err(Error::Invalid(format!("analysis file: rewritten row {r} out of range")));
        }
        rewritten[ru] = true;
    }
    let mut log = Vec::new();
    if let Some(arr) = root.get("log").and_then(Json::as_arr) {
        for rec in arr {
            let f = parse_u32s(rec, "log")?;
            if f.len() == 4 {
                log.push(RewriteRecord {
                    row: f[0],
                    from_level: f[1],
                    to_level: f[2],
                    substitutions: f[3],
                });
            }
        }
    }
    let skeleton = StructuralTransform {
        levels,
        level_of,
        rewritten,
        log,
        levels_before: get(&root, "levels_before")?.as_usize().unwrap_or(0),
        avg_level_cost_before: root
            .get("avg_level_cost_before")
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
        total_level_cost_before: root
            .get("total_level_cost_before")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64,
    };
    let t0 = Instant::now();
    let t = Arc::new(renumeric(&m, &skeleton).map_err(Error::Invalid)?);
    let mut phase_times = PhaseTimes {
        renumeric_us: t0.elapsed().as_micros() as u64,
        ..Default::default()
    };
    t.validate(&m).map_err(|e| {
        Error::Invalid(format!("analysis file: replayed transform invalid: {e}"))
    })?;
    // The guarded rewrite's magnitude cap is a property of the VALUES:
    // re-check it against the matrix this load replayed onto.
    super::check_guard_cap(&plan, &t)?;

    let pool = opts.resolve_pool();
    let mut counters = BuildCounters {
        renumeric_passes: 1,
        ..Default::default()
    };
    let schedule = match (&plan.exec, root.get("schedule")) {
        (Exec::Scheduled(_), Some(sj)) if !matches!(sj, Json::Null) => {
            let s = load_schedule(sj)?;
            if s.nworkers <= pool.len() {
                s.validate(&m, &t).map_err(|e| {
                    Error::Invalid(format!("analysis file: persisted schedule invalid: {e}"))
                })?;
                Some(Arc::new(s))
            } else {
                // A schedule placed for more workers than this pool has
                // cannot execute here: rebuild (and count it honestly).
                counters.coarsen_passes += 1;
                counters.placement_passes += 1;
                let o = match &plan.exec {
                    Exec::Scheduled(o) => o.or(opts.sched),
                    _ => unreachable!(),
                };
                let (s, coarsen, placement) =
                    Schedule::build_timed(&m, &t, pool.len(), o.block_target());
                phase_times.coarsen_us = coarsen.as_micros() as u64;
                phase_times.placement_us = placement.as_micros() as u64;
                Some(Arc::new(s))
            }
        }
        (Exec::Scheduled(o), _) => {
            // Scheduled plan but no persisted schedule (hand-edited or
            // older file): rebuild.
            counters.coarsen_passes += 1;
            counters.placement_passes += 1;
            let o = o.or(opts.sched);
            let (s, coarsen, placement) =
                Schedule::build_timed(&m, &t, pool.len(), o.block_target());
            phase_times.coarsen_us = coarsen.as_micros() as u64;
            phase_times.placement_us = placement.as_micros() as u64;
            Some(Arc::new(s))
        }
        _ => None,
    };
    // A hand-edited file could pair the identity plan with rewritten
    // rows; the replayed transform would be self-consistent but lie
    // about its plan — reject instead of serving the mismatch.
    if plan.rewrite == Rewrite::None && t.stats.rows_rewritten > 0 {
        return Err(Error::Invalid(
            "analysis file: identity plan but rewritten rows recorded".into(),
        ));
    }
    let solver = ExecSolver::build_with(
        Arc::clone(&m),
        Arc::clone(&t),
        &plan.exec,
        Arc::clone(&pool),
        opts.sched,
        schedule.clone(),
    )?;
    let fingerprint = actual;
    Ok(Analysis {
        m,
        plan,
        plan_name,
        fingerprint,
        t,
        schedule,
        solver,
        pool,
        sched: opts.sched,
        counters,
        prepare_time: start.elapsed(),
        phase_times,
    })
}

fn load_schedule(j: &Json) -> Result<Schedule, Error> {
    let blocks: Vec<Block> = get(j, "blocks")?
        .as_arr()
        .ok_or_else(|| Error::Invalid("analysis file: schedule.blocks not an array".into()))?
        .iter()
        .map(|b| {
            Ok(Block {
                rows: parse_u32s(get(b, "rows")?, "block rows")?,
                cost: get(b, "cost")?.as_f64().unwrap_or(0.0) as u64,
                level: get(b, "level")?.as_f64().unwrap_or(0.0) as u32,
            })
        })
        .collect::<Result<_, Error>>()?;
    let nworkers = get(j, "nworkers")?.as_usize().unwrap_or(1).max(1);
    let worker_of = parse_u32s(get(j, "worker_of")?, "worker_of")?;
    let pred_ptr = parse_usizes(get(j, "pred_ptr")?, "pred_ptr")?;
    let preds = parse_u32s(get(j, "preds")?, "preds")?;
    if worker_of.len() != blocks.len()
        || pred_ptr.len() != blocks.len() + 1
        || pred_ptr.last().copied().unwrap_or(0) != preds.len()
        || worker_of.iter().any(|&w| w as usize >= nworkers)
    {
        return Err(Error::Invalid("analysis file: schedule arrays inconsistent".into()));
    }
    let mut worker_lists: Vec<Vec<u32>> = vec![Vec::new(); nworkers];
    for (b, &w) in worker_of.iter().enumerate() {
        worker_lists[w as usize].push(b as u32);
    }
    let sj = get(j, "stats")?;
    let stats = ScheduleStats {
        num_blocks: get(sj, "num_blocks")?.as_usize().unwrap_or(blocks.len()),
        chain_blocks: get(sj, "chain_blocks")?.as_usize().unwrap_or(0),
        cut_edges: get(sj, "cut_edges")?.as_usize().unwrap_or(0),
        max_worker_load: get(sj, "max_worker_load")?.as_f64().unwrap_or(0.0) as u64,
        total_cost: get(sj, "total_cost")?.as_f64().unwrap_or(0.0) as u64,
        levelset_barriers: get(sj, "levelset_barriers")?.as_usize().unwrap_or(0),
        workers: get(sj, "workers")?.as_usize().unwrap_or(nworkers),
    };
    Ok(Schedule {
        nworkers,
        blocks,
        worker_of,
        worker_lists,
        pred_ptr,
        preds,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::transform::PlanSpec;
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sptrsv_{name}_{}.json", std::process::id()))
    }

    fn opts() -> AnalyzeOptions {
        AnalyzeOptions {
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn save_load_roundtrip_skips_structural_passes() {
        let path = tmp("analysis_roundtrip");
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = super::super::analyze(
            &m,
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &opts(),
        )
        .unwrap();
        a.save(&path).unwrap();
        let loaded = Analysis::load(&path, &m, &opts()).unwrap();
        // The acceptance criterion: a persisted schedule means NO
        // coarsening and NO placement on re-load.
        let c = loaded.rebuilds();
        assert_eq!(c.coarsen_passes, 0, "coarsening re-ran on load");
        assert_eq!(c.placement_passes, 0, "placement re-ran on load");
        assert_eq!(c.rewrite_passes, 0, "rewrite analysis re-ran on load");
        assert_eq!(c.renumeric_passes, 1);
        // Identical schedule shape, identical solves.
        assert_eq!(loaded.schedule().unwrap().stats, a.schedule().unwrap().stats);
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        assert_allclose(&loaded.solve(&b), &a.solve(&b), 1e-12, 1e-12).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_renumerics_against_new_values() {
        let path = tmp("analysis_newvals");
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = super::super::analyze(&m, &PlanSpec::parse("avgcost").unwrap(), &opts()).unwrap();
        a.save(&path).unwrap();
        // Same pattern, new values: the load replays numerics against the
        // matrix it is GIVEN, so the solve is exact for the new system.
        let mut m2 = m.clone();
        let mut rng = Rng::new(9);
        for v in &mut m2.data {
            *v *= 1.0 + 0.2 * rng.uniform(-1.0, 1.0);
        }
        let loaded = Analysis::load(&path, &m2, &opts()).unwrap();
        let b = vec![1.0; m2.nrows];
        assert!(m2.residual_inf(&loaded.solve(&b), &b) < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_mismatched_structure_and_garbage() {
        let path = tmp("analysis_reject");
        let m = generate::tridiagonal(40, &Default::default());
        let a = super::super::analyze(&m, &PlanSpec::parse("manual:5").unwrap(), &opts()).unwrap();
        a.save(&path).unwrap();
        let other = generate::tridiagonal(41, &Default::default());
        assert!(Analysis::load(&path, &other, &opts()).is_err());
        std::fs::write(&path, "{ not json").unwrap();
        assert!(Analysis::load(&path, &m, &opts()).is_err());
        std::fs::write(&path, r#"{"kind": "something-else", "version": 1}"#).unwrap();
        assert!(Analysis::load(&path, &m, &opts()).is_err());
        std::fs::remove_file(&path).ok();
    }
}
