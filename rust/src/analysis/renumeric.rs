//! Value-only refresh of a transformed system: replay the recorded
//! rewrite *decisions* against fresh matrix values, skipping every piece
//! of structural analysis (level building, costMap projection, coarsening,
//! placement).
//!
//! The key observation: once the transformed level assignment is fixed,
//! the folded equation of a rewritten row is determined by pure algebra —
//! it is the elimination of every variable at levels >= the row's target
//! level from the row's original equation, and Gaussian elimination of a
//! fixed variable set is order-independent in exact arithmetic. So a
//! same-pattern value update (the dominant scenario in preconditioned
//! iterative solves, where each refactorization keeps the sparsity
//! pattern) re-derives the numerics in one ascending sweep:
//!
//! * original rows need nothing — their values are read from the matrix
//!   at evaluation time;
//! * each rewritten row starts from its fresh original equation and
//!   substitutes any remaining dependency whose (final, structural) level
//!   is at or above the row's target level, using the already-refreshed
//!   equations of those dependencies (dependencies have strictly smaller
//!   row indices, so the ascending sweep always finds them final).
//!
//! Termination: every substitution replaces a level->=target dependency
//! with dependencies at strictly lower levels, and levels are bounded
//! below. Validity: the remaining dependencies are all below the target
//! level, which is exactly the invariant `TransformResult::validate`
//! checks. Note the replay substitutes the *final*-level dependency set —
//! during the original rewrite a dependency may have sat at a higher
//! level when the row was committed and moved down afterwards, in which
//! case the replay keeps it symbolic instead of eliminating it. Both
//! forms are exact reformulations of the same row of `Lx = b`, so solves
//! agree to rounding; the replayed form is never *more* work.

use crate::graph::analyze::LevelStats;
use crate::sparse::Csr;
use crate::transform::equation::Equation;
use crate::transform::plan::{TransformResult, TransformStats};
use crate::transform::rewrite::RewriteRecord;

/// The structural skeleton of a transform: everything `renumeric` needs
/// that does **not** depend on matrix values. Extracted from a live
/// [`TransformResult`] (value refresh) or deserialized from a persisted
/// analysis (cache load).
pub struct StructuralTransform {
    /// compacted levels of the transformed system
    pub levels: Vec<Vec<u32>>,
    /// level of each row in the compacted numbering
    pub level_of: Vec<u32>,
    /// which rows carry a rewritten equation
    pub rewritten: Vec<bool>,
    /// the original rewrite log (decisions; replayed counts may differ)
    pub log: Vec<RewriteRecord>,
    /// pre-transform stats of the raw matrix (structural; carried along
    /// so a refresh does not rebuild the raw level sets)
    pub levels_before: usize,
    pub avg_level_cost_before: f64,
    pub total_level_cost_before: u64,
}

impl StructuralTransform {
    /// Strip a live transform down to its structural skeleton.
    pub fn of(t: &TransformResult) -> StructuralTransform {
        StructuralTransform {
            levels: t.levels.clone(),
            level_of: t.level_of.clone(),
            rewritten: t.equations.iter().map(Option::is_some).collect(),
            log: t.log.clone(),
            levels_before: t.stats.levels_before,
            avg_level_cost_before: t.stats.avg_level_cost_before,
            total_level_cost_before: t.stats.total_level_cost_before,
        }
    }
}

/// Re-derive a full [`TransformResult`] from a structural skeleton and
/// fresh matrix values. No level building, no costMap, no coarsening —
/// one ascending substitution sweep over the rewritten rows only.
pub fn renumeric(m: &Csr, s: &StructuralTransform) -> Result<TransformResult, String> {
    let n = m.nrows;
    if s.level_of.len() != n || s.rewritten.len() != n {
        return Err(format!(
            "renumeric: skeleton is for {} rows, matrix has {n}",
            s.level_of.len()
        ));
    }
    let mut equations: Vec<Option<Box<Equation>>> = vec![None; n];
    let mut max_mag = 0.0f64;
    let mut substitutions: u64 = 0;
    for i in 0..n {
        if !s.rewritten[i] {
            continue;
        }
        let target = s.level_of[i];
        let mut eq = Equation::original(i as u32, m.row_deps(i), m.row_dep_vals(i), m.diag(i));
        loop {
            // Mirror the rewriter's order (highest-level dependency
            // first) so the replayed rounding matches a fresh transform
            // as closely as possible.
            let next = eq
                .coeffs
                .iter()
                .map(|&(c, _)| c)
                .filter(|&c| s.level_of[c as usize] >= target)
                .max_by_key(|&c| s.level_of[c as usize]);
            let Some(j) = next else { break };
            let dep_owned;
            let dep: &Equation = match &equations[j as usize] {
                Some(e) => e,
                None => {
                    let ju = j as usize;
                    dep_owned =
                        Equation::original(j, m.row_deps(ju), m.row_dep_vals(ju), m.diag(ju));
                    &dep_owned
                }
            };
            if !eq.substitute(dep) {
                return Err(format!("renumeric: row {i} lost dependency {j} mid-replay"));
            }
            substitutions += 1;
        }
        eq.fold();
        max_mag = max_mag.max(eq.max_bcoeff_magnitude());
        equations[i] = Some(Box::new(eq));
    }

    let row_costs: Vec<u64> = (0..n)
        .map(|i| match &equations[i] {
            Some(eq) => eq.cost(),
            None => m.row_cost(i) as u64,
        })
        .collect();
    let st_after = LevelStats::from_row_costs(&row_costs, &s.levels);
    let rows_rewritten = s.rewritten.iter().filter(|&&r| r).count();
    Ok(TransformResult {
        levels: s.levels.clone(),
        level_of: s.level_of.clone(),
        equations,
        row_costs,
        stats: TransformStats {
            levels_before: s.levels_before,
            levels_after: st_after.num_levels,
            avg_level_cost_before: s.avg_level_cost_before,
            avg_level_cost_after: st_after.avg_level_cost,
            total_level_cost_before: s.total_level_cost_before,
            total_level_cost_after: st_after.total_cost,
            rows_rewritten,
            nrows: n,
            max_bcoeff_magnitude: if rows_rewritten == 0 { 1.0 } else { max_mag },
            substitutions_total: substitutions,
        },
        log: s.log.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;
    use crate::transform::SolvePlan;
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    fn perturb(m: &Csr, seed: u64) -> Csr {
        let mut m2 = m.clone();
        let mut rng = Rng::new(seed);
        for v in &mut m2.data {
            *v *= 1.0 + 0.1 * rng.uniform(-1.0, 1.0);
        }
        m2
    }

    #[test]
    fn identity_skeleton_replays_to_identity() {
        let m = generate::tridiagonal(60, &Default::default());
        let t = TransformResult::identity(&m);
        let m2 = perturb(&m, 1);
        let t2 = renumeric(&m2, &StructuralTransform::of(&t)).unwrap();
        assert_eq!(t2.stats.rows_rewritten, 0);
        assert_eq!(t2.levels, t.levels);
        t2.validate(&m2).unwrap();
    }

    #[test]
    fn replay_matches_fresh_transform_solve() {
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.05));
        let plan = SolvePlan::parse("avgcost").unwrap();
        let t = plan.apply(&m);
        assert!(t.stats.rows_rewritten > 0);
        let m2 = perturb(&m, 2);
        let replayed = renumeric(&m2, &StructuralTransform::of(&t)).unwrap();
        replayed.validate(&m2).unwrap();
        assert_eq!(replayed.stats.rows_rewritten, t.stats.rows_rewritten);
        assert_eq!(replayed.levels, t.levels);
        // Solving the replayed system against the NEW matrix matches the
        // serial reference on the new values.
        let mut rng = Rng::new(3);
        let b: Vec<f64> = (0..m2.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let x_ref = crate::solver::serial::solve(&m2, &b);
        let s = crate::solver::executor::TransformedSolver::from_parts(m2, replayed, 2);
        assert_allclose(&s.solve(&b), &x_ref, 1e-9, 1e-11).unwrap();
    }

    #[test]
    fn replay_on_same_values_is_equivalent() {
        // Same values in = a system algebraically identical to the
        // original transform (solves agree far below the 1e-12 gate).
        let m = generate::torso2_like(&generate::GenOptions::with_scale(0.02));
        let t = SolvePlan::parse("avgcost").unwrap().apply(&m);
        let replayed = renumeric(&m, &StructuralTransform::of(&t)).unwrap();
        let mut rng = Rng::new(4);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let s1 =
            crate::solver::executor::TransformedSolver::from_parts(m.clone(), t, 1);
        let s2 = crate::solver::executor::TransformedSolver::from_parts(m, replayed, 1);
        assert_allclose(&s1.solve_serial(&b), &s2.solve_serial(&b), 1e-12, 1e-13).unwrap();
    }

    #[test]
    fn wrong_sized_skeleton_is_rejected() {
        let m = generate::tridiagonal(10, &Default::default());
        let t = TransformResult::identity(&m);
        let small = generate::tridiagonal(5, &Default::default());
        assert!(renumeric(&small, &StructuralTransform::of(&t)).is_err());
    }
}
