//! Directory-backed analysis cache: persisted analyses keyed by
//! `(structural fingerprint, canonical plan)`, stored next to the tuner's
//! plan cache so a service restart — or another replica sharing the
//! volume — re-registers known structures without re-running rewrite
//! analysis, coarsening or ETF placement.
//!
//! Filenames embed both key halves (`<fingerprint>.<plan>.analysis.json`
//! with non-filename-safe plan characters mapped to `_`); since distinct
//! plans can collide after sanitization, the load path re-verifies the
//! plan string recorded *inside* the file before trusting it.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::Error;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::SolvePlan;
use crate::tuner::Fingerprint;

use super::{persist, Analysis, AnalyzeOptions};
use crate::sched::SchedOptions;

pub struct AnalysisCache {
    dir: PathBuf,
}

impl AnalysisCache {
    pub fn new(dir: &Path) -> AnalysisCache {
        AnalysisCache {
            dir: dir.to_path_buf(),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Cache file for one `(fingerprint, plan)` key.
    pub fn path_for(&self, fp: Fingerprint, plan: &SolvePlan) -> PathBuf {
        let sanitized: String = plan
            .to_string()
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '+' | '-' | '.' | '_') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        self.dir.join(format!("{fp}.{sanitized}.analysis.json"))
    }

    /// Try to restore a persisted analysis for `(m, plan)`, where `fp`
    /// is `m`'s (caller-computed) structural fingerprint. Returns None
    /// on any miss — absent file, schema/fingerprint mismatch, or a
    /// sanitization collision where the file's recorded plan differs —
    /// warning only when a present file is unusable.
    pub fn load(
        &self,
        m: Arc<Csr>,
        fp: Fingerprint,
        plan: &SolvePlan,
        pool: &Arc<Pool>,
        sched: SchedOptions,
    ) -> Option<Analysis> {
        let path = self.path_for(fp, plan);
        if !path.exists() {
            return None;
        }
        let opts = AnalyzeOptions {
            workers: pool.len(),
            pool: Some(Arc::clone(pool)),
            sched,
        };
        match persist::load(&path, m, &opts) {
            Ok(a) if a.plan() == plan => Some(a),
            Ok(a) => {
                eprintln!(
                    "warning: analysis cache {} holds plan {} (wanted {plan}); ignoring",
                    path.display(),
                    a.plan()
                );
                None
            }
            Err(e) => {
                eprintln!(
                    "warning: ignoring analysis cache {}: {e}",
                    path.display()
                );
                None
            }
        }
    }

    /// Persist `a` under its `(fingerprint, plan)` key.
    pub fn save(&self, a: &Analysis) -> Result<(), Error> {
        persist::save(a, &self.path_for(a.fingerprint(), a.plan()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::transform::PlanSpec;

    #[test]
    fn cache_roundtrip_and_miss_paths() {
        let dir = std::env::temp_dir().join(format!("sptrsv_acache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = AnalysisCache::new(&dir);
        let pool = Arc::new(Pool::new(2));
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let plan = SolvePlan::parse("avgcost+scheduled").unwrap();

        let fp = Fingerprint::of(&m);
        // Cold: miss.
        assert!(cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .is_none());

        let a = super::super::analyze_arc(
            Arc::clone(&m),
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &super::super::AnalyzeOptions {
                pool: Some(Arc::clone(&pool)),
                ..Default::default()
            },
        )
        .unwrap();
        cache.save(&a).unwrap();

        // Warm: the load pays zero coarsening/placement.
        let warm = cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .expect("cache hit");
        assert_eq!(warm.rebuilds().coarsen_passes, 0);
        assert_eq!(warm.rebuilds().placement_passes, 0);
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&warm.solve(&b), &b) < 1e-9);

        // A different plan for the same structure is a distinct key.
        let other = SolvePlan::parse("avgcost+syncfree").unwrap();
        assert!(cache
            .load(Arc::clone(&m), fp, &other, &pool, SchedOptions::default())
            .is_none());
        assert_ne!(
            cache.path_for(a.fingerprint(), &plan),
            cache.path_for(a.fingerprint(), &other)
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
