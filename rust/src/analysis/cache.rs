//! Directory-backed analysis cache: persisted analyses keyed by
//! `(structural fingerprint, canonical plan)`, stored next to the tuner's
//! plan cache so a service restart — or another replica sharing the
//! volume — re-registers known structures without re-running rewrite
//! analysis, coarsening or ETF placement.
//!
//! Entries are binary `.spa` artifacts by default (mmap-validated on
//! load, see [`crate::artifact`]); `analysis_format = json` keeps the
//! legacy schema-stamped JSON for one release. Filenames embed both key
//! halves (`<fingerprint>.<plan>.spa`, legacy
//! `<fingerprint>.<plan>.analysis.json`, with non-filename-safe plan
//! characters mapped to `_`); since distinct plans can collide after
//! sanitization, the load path re-verifies the plan string recorded
//! *inside* the file before trusting it. Loads sniff the file content,
//! so a cache switched to `binary` still reads entries written by an
//! older JSON-configured replica (and vice versa) — the configured
//! format only governs what new saves write.
//!
//! The directory can be bounded ([`AnalysisCache::with_limits`], wired to
//! the `analysis_cache_cap` / `analysis_cache_ttl` config keys): every
//! save first drops entries older than the TTL, then evicts
//! least-recently-used entries beyond the cap. Recency is the file mtime
//! — a successful load *touches* its entry, so hot analyses survive the
//! LRU scan without any sidecar index. [`AnalysisCache::usage`] reports
//! the index the limits operate over: live entries and their real
//! on-disk bytes.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, SystemTime};

use crate::error::Error;
use crate::solver::pool::Pool;
use crate::sparse::Csr;
use crate::transform::SolvePlan;
use crate::tuner::Fingerprint;

use super::{Analysis, AnalysisFormat, AnalyzeOptions};
use crate::sched::SchedOptions;

pub struct AnalysisCache {
    dir: PathBuf,
    /// maximum entries kept after a save (0 = unbounded)
    cap: usize,
    /// maximum entry age kept after a save (None = never expires)
    ttl: Option<Duration>,
    /// on-disk format for new saves; loads sniff and accept either
    format: AnalysisFormat,
}

impl AnalysisCache {
    pub fn new(dir: &Path) -> AnalysisCache {
        AnalysisCache {
            dir: dir.to_path_buf(),
            cap: 0,
            ttl: None,
            format: AnalysisFormat::default(),
        }
    }

    /// A bounded cache: at most `cap` entries (0 = unbounded) no older
    /// than `ttl` (zero = never expires), enforced on every save.
    pub fn with_limits(dir: &Path, cap: usize, ttl: Duration) -> AnalysisCache {
        AnalysisCache {
            dir: dir.to_path_buf(),
            cap,
            ttl: (!ttl.is_zero()).then_some(ttl),
            format: AnalysisFormat::default(),
        }
    }

    /// Override the on-disk format for new saves (the `analysis_format`
    /// config key). Loads are format-agnostic either way.
    pub fn with_format(mut self, format: AnalysisFormat) -> AnalysisCache {
        self.format = format;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn format(&self) -> AnalysisFormat {
        self.format
    }

    /// Cache file for one `(fingerprint, plan)` key in the configured
    /// format.
    pub fn path_for(&self, fp: Fingerprint, plan: &SolvePlan) -> PathBuf {
        self.path_for_format(fp, plan, self.format)
    }

    fn path_for_format(
        &self,
        fp: Fingerprint,
        plan: &SolvePlan,
        format: AnalysisFormat,
    ) -> PathBuf {
        let sanitized: String = plan
            .to_string()
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '+' | '-' | '.' | '_') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        self.dir
            .join(format!("{fp}.{sanitized}.{}", format.suffix()))
    }

    /// Try to restore a persisted analysis for `(m, plan)`, where `fp`
    /// is `m`'s (caller-computed) structural fingerprint. Probes the
    /// configured-format path first, then the other format's suffix, so
    /// entries written before an `analysis_format` switch keep hitting.
    /// Returns None on any miss — absent file, corrupt/truncated
    /// artifact, schema/fingerprint mismatch, or a sanitization
    /// collision where the file's recorded plan differs — warning only
    /// when a present file is unusable (callers then fall back to a
    /// fresh analysis).
    pub fn load(
        &self,
        m: Arc<Csr>,
        fp: Fingerprint,
        plan: &SolvePlan,
        pool: &Arc<Pool>,
        sched: SchedOptions,
    ) -> Option<Analysis> {
        let alternate = match self.format {
            AnalysisFormat::Binary => AnalysisFormat::Json,
            AnalysisFormat::Json => AnalysisFormat::Binary,
        };
        let path = [self.format, alternate]
            .into_iter()
            .map(|f| self.path_for_format(fp, plan, f))
            .find(|p| p.exists())?;
        let opts = AnalyzeOptions {
            workers: pool.len(),
            pool: Some(Arc::clone(pool)),
            sched,
        };
        match Analysis::load_arc(&path, m, &opts) {
            Ok(a) if a.plan() == plan => {
                // LRU touch: bump the entry's mtime so hot analyses
                // outlive colder ones in the eviction scan.
                touch(&path);
                Some(a)
            }
            Ok(a) => {
                eprintln!(
                    "warning: analysis cache {} holds plan {} (wanted {plan}); ignoring",
                    path.display(),
                    a.plan()
                );
                None
            }
            Err(e) => {
                eprintln!(
                    "warning: ignoring analysis cache {}: {e}",
                    path.display()
                );
                None
            }
        }
    }

    /// Persist `a` under its `(fingerprint, plan)` key in the configured
    /// format, then enforce the TTL and LRU cap over the whole
    /// directory. The just-written entry carries the newest mtime, so it
    /// always survives its own save.
    pub fn save(&self, a: &Analysis) -> Result<(), Error> {
        a.save_format(&self.path_for(a.fingerprint(), a.plan()), self.format)?;
        self.enforce_limits();
        Ok(())
    }

    /// The cache's live index: `(entries, on_disk_bytes)` summed over
    /// both formats' entries. Bytes are real file sizes — for binary
    /// artifacts that is exactly what a warm start will mmap.
    pub fn usage(&self) -> (usize, u64) {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return (0, 0);
        };
        entries
            .flatten()
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .is_some_and(is_cache_entry_name)
            })
            .fold((0, 0), |(n, bytes), e| {
                (n + 1, bytes + e.metadata().map(|m| m.len()).unwrap_or(0))
            })
    }

    /// Drop TTL-expired entries, then the least-recently-used entries
    /// beyond the cap. Ties on mtime break by path, so the scan is
    /// deterministic. Unreadable entries or a missing directory are
    /// skipped silently — eviction is best-effort.
    fn enforce_limits(&self) {
        if self.cap == 0 && self.ttl.is_none() {
            return;
        }
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return;
        };
        let mut files: Vec<(SystemTime, PathBuf)> = entries
            .flatten()
            .filter_map(|e| {
                let path = e.path();
                if !path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(is_cache_entry_name)
                {
                    return None;
                }
                let mtime = e.metadata().ok()?.modified().ok()?;
                Some((mtime, path))
            })
            .collect();
        if let Some(ttl) = self.ttl {
            let now = SystemTime::now();
            files.retain(|(mtime, path)| {
                let expired = now
                    .duration_since(*mtime)
                    .is_ok_and(|age| age > ttl);
                if expired {
                    std::fs::remove_file(path).ok();
                }
                !expired
            });
        }
        if self.cap > 0 && files.len() > self.cap {
            files.sort();
            let excess = files.len() - self.cap;
            for (_, path) in files.into_iter().take(excess) {
                std::fs::remove_file(&path).ok();
            }
        }
    }
}

/// A directory entry this cache owns — either format's suffix. Limits
/// and usage accounting only ever consider these, so a tuner plan cache
/// sharing the directory is untouched.
fn is_cache_entry_name(name: &str) -> bool {
    name.ends_with(".spa") || name.ends_with(".analysis.json")
}

/// Best-effort mtime bump without platform-specific utimes: rewrite the
/// file's first byte in place. (Rewriting the byte unchanged keeps
/// binary artifacts' checksums valid.)
fn touch(path: &Path) {
    use std::io::{Read, Seek, SeekFrom, Write};
    let Ok(mut f) = std::fs::OpenOptions::new().read(true).write(true).open(path) else {
        return;
    };
    let mut b = [0u8; 1];
    if f.read_exact(&mut b).is_ok() && f.seek(SeekFrom::Start(0)).is_ok() {
        f.write_all(&b).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::transform::PlanSpec;

    #[test]
    fn cache_roundtrip_and_miss_paths() {
        let dir = std::env::temp_dir().join(format!("sptrsv_acache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = AnalysisCache::new(&dir);
        let pool = Arc::new(Pool::new(2));
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let plan = SolvePlan::parse("avgcost+scheduled").unwrap();

        let fp = Fingerprint::of(&m);
        // Cold: miss.
        assert!(cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .is_none());

        let a = super::super::analyze_arc(
            Arc::clone(&m),
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &super::super::AnalyzeOptions {
                pool: Some(Arc::clone(&pool)),
                ..Default::default()
            },
        )
        .unwrap();
        cache.save(&a).unwrap();

        // Warm: the load pays zero coarsening/placement.
        let warm = cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .expect("cache hit");
        assert_eq!(warm.rebuilds().coarsen_passes, 0);
        assert_eq!(warm.rebuilds().placement_passes, 0);
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&warm.solve(&b), &b) < 1e-9);

        // A different plan for the same structure is a distinct key.
        let other = SolvePlan::parse("avgcost+syncfree").unwrap();
        assert!(cache
            .load(Arc::clone(&m), fp, &other, &pool, SchedOptions::default())
            .is_none());
        assert_ne!(
            cache.path_for(a.fingerprint(), &plan),
            cache.path_for(a.fingerprint(), &other)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    fn entries(dir: &Path) -> usize {
        std::fs::read_dir(dir)
            .map(|rd| {
                rd.flatten()
                    .filter(|e| {
                        e.file_name()
                            .to_str()
                            .is_some_and(is_cache_entry_name)
                    })
                    .count()
            })
            .unwrap_or(0)
    }

    fn build(n: usize, pool: &Arc<Pool>) -> (Arc<Csr>, Analysis) {
        let m = Arc::new(generate::tridiagonal(n, &Default::default()));
        let a = super::super::analyze_arc(
            Arc::clone(&m),
            &PlanSpec::parse("none").unwrap(),
            &super::super::AnalyzeOptions {
                pool: Some(Arc::clone(pool)),
                ..Default::default()
            },
        )
        .unwrap();
        (m, a)
    }

    #[test]
    fn lru_cap_evicts_oldest_and_load_touches() {
        let dir = std::env::temp_dir().join(format!("sptrsv_acache_lru_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = AnalysisCache::with_limits(&dir, 2, Duration::ZERO);
        let pool = Arc::new(Pool::new(1));
        let plan = SolvePlan::parse("none").unwrap();

        // Three distinct structures; sleeps keep the mtimes ordered.
        let (m1, a1) = build(11, &pool);
        let (m2, a2) = build(12, &pool);
        let (_m3, a3) = build(13, &pool);
        cache.save(&a1).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        cache.save(&a2).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        cache.save(&a3).unwrap();
        // Cap 2: the oldest entry (a1) was evicted by a3's save.
        assert_eq!(entries(&dir), 2);
        assert!(!cache.path_for(a1.fingerprint(), &plan).exists());
        assert!(cache
            .load(Arc::clone(&m1), Fingerprint::of(&m1), &plan, &pool, SchedOptions::default())
            .is_none());

        // Loading a2 touches it; the next save evicts a3, not a2.
        std::thread::sleep(Duration::from_millis(30));
        assert!(cache
            .load(Arc::clone(&m2), Fingerprint::of(&m2), &plan, &pool, SchedOptions::default())
            .is_some());
        std::thread::sleep(Duration::from_millis(30));
        let (_, a4) = build(14, &pool);
        cache.save(&a4).unwrap();
        assert_eq!(entries(&dir), 2);
        assert!(cache.path_for(a2.fingerprint(), &plan).exists(), "touched entry survives");
        assert!(!cache.path_for(a3.fingerprint(), &plan).exists(), "untouched entry evicted");
        assert!(cache.path_for(a4.fingerprint(), &plan).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn binary_default_with_legacy_json_fallback_and_usage() {
        let dir = std::env::temp_dir().join(format!("sptrsv_acache_fmt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let pool = Arc::new(Pool::new(2));
        let plan = SolvePlan::parse("avgcost+scheduled").unwrap();
        let m = Arc::new(generate::lung2_like(&GenOptions::with_scale(0.03)));
        let fp = Fingerprint::of(&m);
        let a = super::super::analyze_arc(
            Arc::clone(&m),
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &super::super::AnalyzeOptions {
                pool: Some(Arc::clone(&pool)),
                ..Default::default()
            },
        )
        .unwrap();

        // Default cache writes a binary .spa artifact and tracks its
        // real on-disk bytes.
        let cache = AnalysisCache::new(&dir);
        assert_eq!(cache.format(), AnalysisFormat::Binary);
        cache.save(&a).unwrap();
        let spa = cache.path_for(fp, &plan);
        assert!(spa.extension().is_some_and(|e| e == "spa"));
        assert!(spa.exists());
        let (n, bytes) = cache.usage();
        assert_eq!(n, 1);
        assert_eq!(bytes, std::fs::metadata(&spa).unwrap().len());
        let warm = cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .expect("binary cache hit");
        assert_eq!(warm.rebuilds().coarsen_passes, 0);
        assert_eq!(warm.rebuilds().placement_passes, 0);

        // An entry written by a JSON-configured replica still hits a
        // binary-configured cache (and vice versa): loads probe the
        // alternate suffix and sniff content.
        std::fs::remove_file(&spa).unwrap();
        AnalysisCache::new(&dir)
            .with_format(AnalysisFormat::Json)
            .save(&a)
            .unwrap();
        assert!(!spa.exists());
        let legacy = cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .expect("legacy json entry hit from binary-configured cache");
        assert_eq!(legacy.rebuilds().coarsen_passes, 0);
        let b = vec![1.0; m.nrows];
        assert!(m.residual_inf(&legacy.solve(&b), &b) < 1e-9);

        // A corrupt binary entry is a miss, not an error.
        cache.save(&a).unwrap();
        let len = std::fs::metadata(&spa).unwrap().len();
        let data = std::fs::read(&spa).unwrap();
        std::fs::write(&spa, &data[..len as usize / 2]).unwrap();
        std::fs::remove_file(cache.path_for_format(fp, &plan, AnalysisFormat::Json)).unwrap();
        assert!(cache
            .load(Arc::clone(&m), fp, &plan, &pool, SchedOptions::default())
            .is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ttl_expires_stale_entries_on_save() {
        let dir = std::env::temp_dir().join(format!("sptrsv_acache_ttl_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = AnalysisCache::with_limits(&dir, 0, Duration::from_millis(50));
        let pool = Arc::new(Pool::new(1));
        let plan = SolvePlan::parse("none").unwrap();
        let (_, a1) = build(21, &pool);
        cache.save(&a1).unwrap();
        assert_eq!(entries(&dir), 1);
        std::thread::sleep(Duration::from_millis(120));
        let (_, a2) = build(22, &pool);
        cache.save(&a2).unwrap();
        // a1 aged past the TTL and was dropped by a2's save.
        assert_eq!(entries(&dir), 1);
        assert!(!cache.path_for(a1.fingerprint(), &plan).exists());
        assert!(cache.path_for(a2.fingerprint(), &plan).exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
