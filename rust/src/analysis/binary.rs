//! The `Analysis` <-> binary artifact bridge: encodes the structural
//! artifacts into the `.spa` container (`crate::artifact`) and decodes
//! them back by viewing mapped sections — the JSON path's semantics
//! (fingerprint check, renumeric replay, guard-cap re-check, identity
//! plan rejection) with none of its parse cost.
//!
//! One artifact stores the block schedule for **several worker counts**
//! (the serving pool's size, one less, half, and 1), each as its own
//! `SCHEDULE` section. A load picks the largest stored count that fits
//! the pool it is given, so a shrunken pool adopts a stored placement
//! instead of re-running coarsening + ETF — and since a one-worker
//! schedule is always stored, a binary load never rebuilds.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::artifact::container::{
    ArtifactWriter, SEC_CSR, SEC_LEVELS, SEC_PLAN, SEC_REWRITE, SEC_SCHEDULE,
};
use crate::artifact::pack::{
    put_f64, put_monotone, put_u32s, put_u64, put_varint, Cursor,
};
use crate::artifact::{ArtifactError, ArtifactReader};
use crate::error::Error;
use crate::sched::schedule::{Schedule, ScheduleStats};
use crate::sched::Block;
use crate::solver::dispatch::ExecSolver;
use crate::sparse::Csr;
use crate::trace::PhaseTimes;
use crate::transform::rewrite::RewriteRecord;
use crate::transform::{Exec, Rewrite, SolvePlan};
use crate::tuner::Fingerprint;

use super::renumeric::{renumeric, StructuralTransform};
use super::{Analysis, AnalyzeOptions, BuildCounters};

fn malformed(what: impl Into<String>) -> Error {
    Error::Artifact(ArtifactError::Malformed(what.into()))
}

/// Worker counts persisted alongside the analysis' own: one smaller (a
/// pool that lost a worker), half (a heavily shrunken pool), and 1 (the
/// floor that makes every load adoptable). Deduplicated, descending.
fn stored_worker_counts(w: usize) -> Vec<usize> {
    let mut counts = vec![w, w.saturating_sub(1).max(1), (w / 2).max(1), 1];
    counts.sort_unstable_by(|a, b| b.cmp(a));
    counts.dedup();
    counts
}

/// Serialize `a`'s structural artifacts as a binary container at `path`.
pub(super) fn save(a: &Analysis, path: &Path) -> Result<(), Error> {
    let t = &a.t;
    let mut w = ArtifactWriter::new(a.fingerprint.0, a.m.nrows as u64);

    // PLAN: pre-transform stats + the plan strings.
    let mut plan = Vec::new();
    put_u64(&mut plan, t.stats.levels_before as u64);
    put_f64(&mut plan, t.stats.avg_level_cost_before);
    put_u64(&mut plan, t.stats.total_level_cost_before);
    let ps = a.plan.to_string();
    put_varint(&mut plan, ps.len() as u64);
    plan.extend_from_slice(ps.as_bytes());
    put_varint(&mut plan, a.plan_name.len() as u64);
    plan.extend_from_slice(a.plan_name.as_bytes());
    w.section(SEC_PLAN, plan);

    // CSR: the sparsity structure itself (indptr delta-packed, indices
    // raw). The fingerprint already guards reuse; the explicit structure
    // makes the artifact self-describing for `artifact inspect` and lets
    // a load cross-check beyond the hash.
    let mut csr = Vec::new();
    put_u64(&mut csr, a.m.ncols as u64);
    let indptr: Vec<u64> = a.m.indptr.iter().map(|&p| p as u64).collect();
    put_monotone(&mut csr, &indptr).map_err(Error::Artifact)?;
    put_u32s(&mut csr, &a.m.indices);
    w.section(SEC_CSR, csr);

    // LEVELS: level_ptr delta-packed + the rows of every level, flat.
    let mut lv = Vec::new();
    let mut level_ptr = Vec::with_capacity(t.levels.len() + 1);
    let mut acc = 0u64;
    level_ptr.push(0);
    for l in &t.levels {
        acc += l.len() as u64;
        level_ptr.push(acc);
    }
    put_monotone(&mut lv, &level_ptr).map_err(Error::Artifact)?;
    let flat: Vec<u32> = t.levels.iter().flat_map(|l| l.iter().copied()).collect();
    put_u32s(&mut lv, &flat);
    w.section(SEC_LEVELS, lv);

    // REWRITE: which rows carry folded equations + the decision log.
    let mut rw = Vec::new();
    let rewritten: Vec<u64> = (0..t.equations.len() as u64)
        .filter(|&i| t.equations[i as usize].is_some())
        .collect();
    put_monotone(&mut rw, &rewritten).map_err(Error::Artifact)?;
    put_varint(&mut rw, t.log.len() as u64);
    for r in &t.log {
        put_varint(&mut rw, r.row as u64);
        put_varint(&mut rw, r.from_level as u64);
        put_varint(&mut rw, r.to_level as u64);
        put_varint(&mut rw, r.substitutions as u64);
    }
    w.section(SEC_REWRITE, rw);

    // SCHEDULE x stored worker counts. The analysis' own schedule is
    // emitted as-is; the extra counts are built here, once, at save time
    // — that is the whole point: pay placement offline so no future
    // load, on any plausible pool size, re-places.
    if let Some(own) = &a.schedule {
        let block_target = match &a.plan.exec {
            Exec::Scheduled(o) => o.or(a.sched).block_target(),
            _ => crate::sched::DEFAULT_BLOCK_TARGET,
        };
        for count in stored_worker_counts(own.nworkers) {
            let built;
            let s: &Schedule = if count == own.nworkers {
                own
            } else {
                built = Schedule::build(&a.m, t, count, block_target);
                &built
            };
            w.section(SEC_SCHEDULE, encode_schedule(s)?);
        }
    }

    w.write(path).map_err(Error::Artifact)
}

fn encode_schedule(s: &Schedule) -> Result<Vec<u8>, Error> {
    let mut out = Vec::new();
    put_u64(&mut out, s.nworkers as u64);
    let st = &s.stats;
    put_u64(&mut out, st.num_blocks as u64);
    put_u64(&mut out, st.chain_blocks as u64);
    put_u64(&mut out, st.cut_edges as u64);
    put_u64(&mut out, st.max_worker_load);
    put_u64(&mut out, st.total_cost);
    put_u64(&mut out, st.levelset_barriers as u64);
    put_u64(&mut out, st.workers as u64);

    let mut block_ptr = Vec::with_capacity(s.blocks.len() + 1);
    let mut acc = 0u64;
    block_ptr.push(0);
    for b in &s.blocks {
        acc += b.rows.len() as u64;
        block_ptr.push(acc);
    }
    put_monotone(&mut out, &block_ptr).map_err(Error::Artifact)?;
    for b in &s.blocks {
        put_varint(&mut out, b.cost);
    }
    // Blocks sit in (head level, head row) topological order, so their
    // levels are non-decreasing — delta-packable like an offset array.
    let levels: Vec<u64> = s.blocks.iter().map(|b| b.level as u64).collect();
    put_monotone(&mut out, &levels).map_err(Error::Artifact)?;
    put_u32s(&mut out, &s.worker_of);
    let pred_ptr: Vec<u64> = s.pred_ptr.iter().map(|&p| p as u64).collect();
    put_monotone(&mut out, &pred_ptr).map_err(Error::Artifact)?;
    put_u32s(&mut out, &s.preds);
    let rows_flat: Vec<u32> = s
        .blocks
        .iter()
        .flat_map(|b| b.rows.iter().copied())
        .collect();
    put_u32s(&mut out, &rows_flat);
    Ok(out)
}

fn decode_schedule(payload: &[u8]) -> Result<Schedule, ArtifactError> {
    let mut cur = Cursor::new(payload);
    let nworkers = (cur.u64()? as usize).max(1);
    let stats = ScheduleStats {
        num_blocks: cur.u64()? as usize,
        chain_blocks: cur.u64()? as usize,
        cut_edges: cur.u64()? as usize,
        max_worker_load: cur.u64()?,
        total_cost: cur.u64()?,
        levelset_barriers: cur.u64()? as usize,
        workers: cur.u64()? as usize,
    };
    let block_ptr = cur.monotone()?;
    if block_ptr.is_empty() {
        return Err(ArtifactError::Malformed("schedule without block_ptr".into()));
    }
    let nblocks = block_ptr.len() - 1;
    let mut costs = Vec::with_capacity(nblocks);
    for _ in 0..nblocks {
        costs.push(cur.varint()?);
    }
    let levels = cur.monotone()?;
    let worker_of = cur.u32s()?.into_owned();
    let pred_ptr_u64 = cur.monotone()?;
    let preds = cur.u32s()?.into_owned();
    let rows_flat = cur.u32s()?;
    if levels.len() != nblocks
        || worker_of.len() != nblocks
        || pred_ptr_u64.len() != nblocks + 1
        || pred_ptr_u64.last().copied().unwrap_or(0) as usize != preds.len()
        || *block_ptr.last().unwrap() as usize != rows_flat.len()
        || worker_of.iter().any(|&w| w as usize >= nworkers)
    {
        return Err(ArtifactError::Malformed(
            "schedule arrays inconsistent".into(),
        ));
    }
    let blocks: Vec<Block> = (0..nblocks)
        .map(|b| Block {
            rows: rows_flat[block_ptr[b] as usize..block_ptr[b + 1] as usize].to_vec(),
            cost: costs[b],
            level: levels[b] as u32,
        })
        .collect();
    let mut worker_lists: Vec<Vec<u32>> = vec![Vec::new(); nworkers];
    for (b, &w) in worker_of.iter().enumerate() {
        worker_lists[w as usize].push(b as u32);
    }
    Ok(Schedule {
        nworkers,
        blocks,
        worker_of,
        worker_lists,
        pred_ptr: pred_ptr_u64.into_iter().map(|p| p as usize).collect(),
        preds,
        stats,
    })
}

/// Worker count of a `SCHEDULE` section without decoding it.
fn peek_nworkers(payload: &[u8]) -> Result<usize, ArtifactError> {
    Cursor::new(payload).u64().map(|w| w as usize)
}

/// Restore an analysis from a binary artifact for `m`. Mirrors the JSON
/// loader's checks exactly; adopts the **largest stored placement that
/// fits the pool** instead of ever re-placing.
pub(super) fn load(path: &Path, m: Arc<Csr>, opts: &AnalyzeOptions) -> Result<Analysis, Error> {
    let start = Instant::now();
    let r = ArtifactReader::open(path).map_err(Error::Artifact)?;

    let fingerprint = Fingerprint(r.fingerprint());
    let actual = Fingerprint::of(&m);
    if fingerprint != actual {
        return Err(Error::Invalid(format!(
            "analysis was saved for structure {fingerprint}, matrix has {actual}"
        )));
    }
    if r.nrows() as usize != m.nrows {
        return Err(Error::Invalid(format!(
            "analysis was saved for {} rows, matrix has {}",
            r.nrows(),
            m.nrows
        )));
    }

    // PLAN.
    let pb = r.section(SEC_PLAN).ok_or_else(|| malformed("missing PLAN section"))?;
    let mut cur = Cursor::new(pb);
    let (levels_before, avg_before, total_before) = (|| -> Result<_, ArtifactError> {
        Ok((cur.u64()? as usize, cur.f64()?, cur.u64()?))
    })()
    .map_err(Error::Artifact)?;
    let plan_str = read_str(&mut cur, pb.len()).map_err(Error::Artifact)?;
    let plan_name = read_str(&mut cur, pb.len()).map_err(Error::Artifact)?;
    let plan = SolvePlan::parse(&plan_str).map_err(Error::Invalid)?;
    let plan_name = if plan_name.is_empty() { plan_str } else { plan_name };

    // CSR cross-check: the fingerprint already hashed the structure, but
    // the explicit arrays are stored — verify them (a cheap memcmp-scale
    // scan next to the renumeric pass that follows).
    if let Some(cb) = r.section(SEC_CSR) {
        let mut cur = Cursor::new(cb);
        let check = (|| -> Result<bool, ArtifactError> {
            let ncols = cur.u64()? as usize;
            let indptr = cur.monotone()?;
            let indices = cur.u32s()?;
            Ok(ncols == m.ncols
                && indptr.len() == m.indptr.len()
                && indptr.iter().zip(&m.indptr).all(|(&a, &b)| a as usize == b)
                && indices.as_ref() == &m.indices[..])
        })()
        .map_err(Error::Artifact)?;
        if !check {
            return Err(malformed(
                "stored CSR structure does not match the matrix (fingerprint collision or \
                 corrupt section)",
            ));
        }
    }

    // LEVELS -> levels + level_of, with the same coverage checks the
    // JSON loader runs.
    let lb = r
        .section(SEC_LEVELS)
        .ok_or_else(|| malformed("missing LEVELS section"))?;
    let mut cur = Cursor::new(lb);
    let (level_ptr, flat) = (|| -> Result<_, ArtifactError> {
        Ok((cur.monotone()?, cur.u32s()?))
    })()
    .map_err(Error::Artifact)?;
    if level_ptr.first().copied().unwrap_or(1) != 0
        || level_ptr.last().copied().unwrap_or(0) as usize != flat.len()
    {
        return Err(malformed("LEVELS pointers inconsistent"));
    }
    let levels: Vec<Vec<u32>> = level_ptr
        .windows(2)
        .map(|w| flat[w[0] as usize..w[1] as usize].to_vec())
        .collect();
    let mut level_of = vec![u32::MAX; m.nrows];
    for (lvl, rows) in levels.iter().enumerate() {
        for &row in rows {
            let ru = row as usize;
            if ru >= m.nrows || level_of[ru] != u32::MAX {
                return Err(malformed(format!(
                    "row {row} out of range or in two levels"
                )));
            }
            level_of[ru] = lvl as u32;
        }
    }
    if level_of.iter().any(|&l| l == u32::MAX) {
        return Err(malformed("levels do not cover all rows"));
    }

    // REWRITE.
    let wb = r
        .section(SEC_REWRITE)
        .ok_or_else(|| malformed("missing REWRITE section"))?;
    let mut cur = Cursor::new(wb);
    let mut rewritten = vec![false; m.nrows];
    let log = (|| -> Result<Vec<RewriteRecord>, ArtifactError> {
        for row in cur.monotone()? {
            let ru = row as usize;
            if ru >= m.nrows {
                return Err(ArtifactError::Malformed(format!(
                    "rewritten row {row} out of range"
                )));
            }
            rewritten[ru] = true;
        }
        let n = cur.varint()? as usize;
        let mut log = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            log.push(RewriteRecord {
                row: cur.varint()? as u32,
                from_level: cur.varint()? as u32,
                to_level: cur.varint()? as u32,
                substitutions: cur.varint()? as u32,
            });
        }
        Ok(log)
    })()
    .map_err(Error::Artifact)?;

    let skeleton = StructuralTransform {
        levels,
        level_of,
        rewritten,
        log,
        levels_before,
        avg_level_cost_before: avg_before,
        total_level_cost_before: total_before,
    };
    let t0 = Instant::now();
    let t = Arc::new(renumeric(&m, &skeleton).map_err(Error::Invalid)?);
    let phase_times = PhaseTimes {
        renumeric_us: t0.elapsed().as_micros() as u64,
        ..Default::default()
    };
    t.validate(&m)
        .map_err(|e| malformed(format!("replayed transform invalid: {e}")))?;
    super::check_guard_cap(&plan, &t)?;
    if plan.rewrite == Rewrite::None && t.stats.rows_rewritten > 0 {
        return Err(malformed("identity plan but rewritten rows recorded"));
    }

    let pool = opts.resolve_pool();
    let counters = BuildCounters {
        renumeric_passes: 1,
        ..Default::default()
    };
    let schedule = match &plan.exec {
        Exec::Scheduled(_) => {
            // Nearest fit: the largest stored worker count this pool can
            // run. A 1-worker schedule is always stored, so a binary
            // load never pays coarsening or placement again.
            let mut best: Option<(usize, &[u8])> = None;
            for payload in r.sections_of(SEC_SCHEDULE) {
                let w = peek_nworkers(payload).map_err(Error::Artifact)?;
                if w <= pool.len() && w > best.map(|(bw, _)| bw).unwrap_or(0) {
                    best = Some((w, payload));
                }
            }
            let (_, payload) = best.ok_or_else(|| {
                malformed(format!(
                    "no stored placement fits a {}-worker pool",
                    pool.len()
                ))
            })?;
            let s = decode_schedule(payload).map_err(Error::Artifact)?;
            s.validate(&m, &t)
                .map_err(|e| malformed(format!("persisted schedule invalid: {e}")))?;
            Some(Arc::new(s))
        }
        _ => None,
    };
    let solver = ExecSolver::build_with(
        Arc::clone(&m),
        Arc::clone(&t),
        &plan.exec,
        Arc::clone(&pool),
        opts.sched,
        schedule.clone(),
    )?;
    Ok(Analysis {
        m,
        plan,
        plan_name,
        fingerprint: actual,
        t,
        schedule,
        solver,
        pool,
        sched: opts.sched,
        counters,
        prepare_time: start.elapsed(),
        phase_times,
    })
}

fn read_str(cur: &mut Cursor<'_>, cap: usize) -> Result<String, ArtifactError> {
    let n = cur.varint()? as usize;
    if n > cap {
        return Err(ArtifactError::Malformed(format!(
            "string length {n} exceeds section"
        )));
    }
    let bytes = cur.bytes(n)?;
    String::from_utf8(bytes.to_vec())
        .map_err(|_| ArtifactError::Malformed("string is not UTF-8".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::{self, GenOptions};
    use crate::transform::PlanSpec;
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sptrsv_{name}_{}.spa", std::process::id()))
    }

    fn opts(workers: usize) -> AnalyzeOptions {
        AnalyzeOptions {
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn binary_roundtrip_flat_counters_and_identical_solves() {
        let path = tmp("bin_roundtrip");
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = super::super::analyze(
            &m,
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &opts(2),
        )
        .unwrap();
        save(&a, &path).unwrap();
        let loaded = load(&path, Arc::new(m.clone()), &opts(2)).unwrap();
        let c = loaded.rebuilds();
        assert_eq!(c.coarsen_passes, 0, "coarsening re-ran on binary load");
        assert_eq!(c.placement_passes, 0, "placement re-ran on binary load");
        assert_eq!(c.rewrite_passes, 0);
        assert_eq!(c.renumeric_passes, 1);
        assert_eq!(loaded.plan_name(), a.plan_name());
        assert_eq!(loaded.schedule().unwrap().stats, a.schedule().unwrap().stats);
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-1.0, 1.0)).collect();
        assert_allclose(&loaded.solve(&b), &a.solve(&b), 1e-12, 1e-12).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shrunken_pool_adopts_a_stored_placement() {
        let path = tmp("bin_shrunk");
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = super::super::analyze(
            &m,
            &PlanSpec::parse("avgcost+scheduled").unwrap(),
            &opts(4),
        )
        .unwrap();
        assert_eq!(a.schedule().unwrap().nworkers, 4);
        save(&a, &path).unwrap();
        // W-1: the artifact holds a 3-worker placement; the load adopts
        // it with ZERO structural passes.
        for w in [3usize, 2, 1] {
            let loaded = load(&path, Arc::new(m.clone()), &opts(w)).unwrap();
            let c = loaded.rebuilds();
            assert_eq!(c.coarsen_passes, 0, "pool {w}: coarsening re-ran");
            assert_eq!(c.placement_passes, 0, "pool {w}: placement re-ran");
            assert_eq!(loaded.schedule().unwrap().nworkers, w);
            let b = vec![1.0; m.nrows];
            assert!(m.residual_inf(&loaded.solve(&b), &b) < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stored_worker_counts_dedup_descending() {
        assert_eq!(stored_worker_counts(8), vec![8, 7, 4, 1]);
        assert_eq!(stored_worker_counts(4), vec![4, 3, 2, 1]);
        assert_eq!(stored_worker_counts(2), vec![2, 1]);
        assert_eq!(stored_worker_counts(1), vec![1]);
    }

    #[test]
    fn binary_load_renumerics_against_new_values() {
        let path = tmp("bin_newvals");
        let m = generate::lung2_like(&GenOptions::with_scale(0.04));
        let a = super::super::analyze(&m, &PlanSpec::parse("avgcost").unwrap(), &opts(2)).unwrap();
        save(&a, &path).unwrap();
        let mut m2 = m.clone();
        let mut rng = Rng::new(9);
        for v in &mut m2.data {
            *v *= 1.0 + 0.2 * rng.uniform(-1.0, 1.0);
        }
        let loaded = load(&path, Arc::new(m2.clone()), &opts(2)).unwrap();
        let b = vec![1.0; m2.nrows];
        assert!(m2.residual_inf(&loaded.solve(&b), &b) < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_load_rejects_mismatched_structure() {
        let path = tmp("bin_reject");
        let m = generate::tridiagonal(40, &Default::default());
        let a = super::super::analyze(&m, &PlanSpec::parse("manual:5").unwrap(), &opts(2)).unwrap();
        save(&a, &path).unwrap();
        let other = generate::tridiagonal(41, &Default::default());
        assert!(load(&path, Arc::new(other), &opts(2)).is_err());
        std::fs::remove_file(&path).ok();
    }
}
