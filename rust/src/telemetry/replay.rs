//! Journal → scenario: turn a captured traffic journal back into a
//! [`Scenario`] the bench harness can replay, so a production traffic
//! shape becomes a repeatable benchmark (`sptrsv replay --journal FILE`
//! emits a standard `BENCH_*.json` through the same [`crate::bench`]
//! path as a hand-written scenario).
//!
//! The journal records request *shape*, not matrix payloads — shipping
//! every registered matrix would make journaling unaffordable on the
//! hot path. Replay therefore rebuilds each registered matrix as a
//! `random` generator of the journaled dimensions (rows, and a
//! dependency budget from the journaled nnz), keeps the journaled plan,
//! and weights each matrix by its observed share of solve traffic. Lane
//! mix, deadline distribution, tolerance mix, block size, refresh
//! cadence and mean arrival gap are all lifted from the event stream, so
//! the replayed load exercises the same serving policies the live
//! traffic did.

use std::path::Path;

use crate::bench::{MatrixSpec, Scenario};
use crate::error::Error;
use crate::telemetry::journal::{self, Record};

/// Replayed scenarios get deterministic matrices from this fixed seed;
/// two replays of the same journal are identical runs.
const REPLAY_SEED: u64 = 0x5EED;

/// Build a [`Scenario`] named `name` from the journal at `path`.
pub fn scenario_from_journal(path: &Path, name: &str) -> Result<Scenario, Error> {
    let records = journal::read(path)?;
    scenario_from_records(&records, name, path)
}

/// Ids whose journaled payload digests show the sparsity pattern
/// changing mid-capture: a re-registration or value update whose
/// structure digest differs from the digest the id first registered
/// with. Replay keeps each id's first shape, so these matrices are
/// approximated more loosely than the rest — worth a warning, not an
/// error. Captures from builds without digests report nothing.
pub fn structural_divergence(records: &[Record]) -> Vec<String> {
    let mut first: std::collections::BTreeMap<&str, u64> = std::collections::BTreeMap::new();
    let mut divergent: Vec<String> = Vec::new();
    for r in records {
        if r.ev.kind != "register" && r.ev.kind != "update_values" {
            continue;
        }
        let Some(s) = r.ev.sdigest else { continue };
        let seen = *first.entry(r.ev.id.as_str()).or_insert(s);
        if seen != s && !divergent.iter().any(|d| d == &r.ev.id) {
            divergent.push(r.ev.id.clone());
        }
    }
    divergent
}

fn scenario_from_records(records: &[Record], name: &str, path: &Path) -> Result<Scenario, Error> {
    if name.is_empty()
        || !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    {
        return Err(Error::Invalid(format!(
            "replay: name '{name}' must be non-empty [A-Za-z0-9_-]"
        )));
    }

    let mut matrices: Vec<MatrixSpec> = Vec::new();
    let mut solves = 0usize;
    let mut interactive = 0usize;
    let mut with_deadline = 0usize;
    let mut deadline_min = u64::MAX;
    let mut deadline_max = 0u64;
    let mut block_size = 1usize;
    let mut updates = 0usize;
    let mut arrivals: Vec<u64> = Vec::new();
    let mut with_tolerance = 0usize;
    let mut tolerance_min = f64::INFINITY;

    for r in records {
        match r.ev.kind.as_str() {
            "register" => {
                if matrices.iter().any(|m| m.id == r.ev.id) {
                    continue; // re-registration: keep the first shape
                }
                let n = r.ev.nrows.max(1);
                // Average sub-diagonal entries per row → the `random`
                // generator's dependency budget (minus the diagonal).
                let deps = (r.ev.nnz / n).saturating_sub(1).clamp(1, 16);
                matrices.push(MatrixSpec {
                    id: r.ev.id.clone(),
                    kind: "random".to_string(),
                    n,
                    scale: 0.02,
                    bandwidth: 8,
                    max_deps: deps,
                    plan: r.ev.plan.clone(),
                    weight: 0.0, // filled from solve traffic below
                });
            }
            "solve" | "solve_many" => {
                solves += 1;
                arrivals.push(r.t_us);
                if r.ev.interactive {
                    interactive += 1;
                }
                if let Some(d) = r.ev.deadline_us {
                    with_deadline += 1;
                    deadline_min = deadline_min.min(d);
                    deadline_max = deadline_max.max(d);
                }
                if let Some(t) = r.ev.tol {
                    with_tolerance += 1;
                    tolerance_min = tolerance_min.min(t);
                }
                block_size = block_size.max(r.ev.block);
                if let Some(m) = matrices.iter_mut().find(|m| m.id == r.ev.id) {
                    m.weight += 1.0;
                }
            }
            "update_values" => updates += 1,
            _ => {} // cancel sweeps and future kinds shape nothing here
        }
    }

    for id in structural_divergence(records) {
        eprintln!(
            "replay: warning: '{id}' changed sparsity structure mid-capture \
             in {}; replaying its first registered shape only",
            path.display()
        );
    }

    if matrices.is_empty() {
        return Err(Error::Invalid(format!("replay: no registrations in {}", path.display())));
    }
    if solves == 0 {
        return Err(Error::Invalid(format!("replay: no solve traffic in {}", path.display())));
    }
    // A registered matrix that saw no traffic still replays (weight 1),
    // matching how it occupied the live service.
    for m in &mut matrices {
        if m.weight == 0.0 {
            m.weight = 1.0;
        }
    }

    let span_us = arrivals.last().copied().unwrap_or(0)
        - arrivals.first().copied().unwrap_or(0);
    let gap_us = if solves > 1 { span_us / (solves as u64 - 1) } else { 0 };

    let sc = Scenario {
        name: name.to_string(),
        seed: REPLAY_SEED,
        requests: solves,
        matrices,
        interactive_fraction: interactive as f64 / solves as f64,
        // Per-request accuracy bounds ride the journal's `tol` field:
        // the replayed traffic states tolerances at the captured rate,
        // bounded by the tightest tolerance any request stated (so the
        // replay's accuracy ladder is stressed at least as hard as the
        // live traffic stressed it). Captures from builds without the
        // field — and exact-only traffic — replay with no tolerances.
        tolerance_fraction: with_tolerance as f64 / solves as f64,
        tolerance: if with_tolerance > 0 { tolerance_min } else { 1e-8 },
        deadline_fraction: with_deadline as f64 / solves as f64,
        deadline_min_us: if with_deadline > 0 { deadline_min } else { 1_000 },
        deadline_max_us: if with_deadline > 0 {
            deadline_max.max(deadline_min)
        } else {
            100_000
        },
        gap_us,
        burst: 1,
        block_size,
        refresh_every: if updates > 0 {
            (solves / updates).max(1)
        } else {
            0
        },
    };
    Ok(sc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::journal::{Event, Journal};

    fn capture(name: &str, events: &[Event]) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("sptrsv_replay_{}_{name}.jsonl", std::process::id()));
        let j = Journal::create(&p).unwrap();
        for ev in events {
            j.record(ev.clone());
        }
        drop(j);
        p
    }

    #[test]
    fn journal_maps_onto_a_faithful_scenario() {
        let p = capture(
            "map",
            &[
                Event::register("hot", 200, 760, "avgcost"),
                Event::register("cold", 80, 200, "none"),
                Event::solve("hot", 1, true, Some(4_000), None).with_tolerance(Some(1e-6)),
                Event::solve("hot", 1, false, Some(9_000), None),
                Event::solve("hot", 2, false, None, Some("acme")).with_tolerance(Some(1e-9)),
                Event::update("hot"),
                Event::solve("cold", 1, true, None, None),
                Event::cancel(),
            ],
        );
        let sc = scenario_from_journal(&p, "replayed").unwrap();
        std::fs::remove_file(&p).ok();

        assert_eq!(sc.name, "replayed");
        assert_eq!(sc.requests, 4, "one request per journaled solve event");
        assert_eq!(sc.matrices.len(), 2);
        let hot = &sc.matrices[0];
        assert_eq!(hot.id, "hot");
        assert_eq!(hot.kind, "random");
        assert_eq!(hot.n, 200);
        // 760 nnz over 200 rows ≈ 3.8/row → 2 sub-diagonal deps.
        assert_eq!(hot.max_deps, 2);
        assert_eq!(hot.plan, "avgcost");
        assert_eq!(hot.weight, 3.0, "weighted by observed traffic");
        assert_eq!(sc.matrices[1].weight, 1.0);
        // Lane / deadline / block / refresh shape lifted from events.
        assert_eq!(sc.interactive_fraction, 0.5);
        assert_eq!(sc.deadline_fraction, 0.5);
        assert_eq!((sc.deadline_min_us, sc.deadline_max_us), (4_000, 9_000));
        assert_eq!(sc.block_size, 2);
        assert_eq!(sc.refresh_every, 4);
        assert_eq!(sc.burst, 1);
        // Toleranced traffic regenerates at the captured rate, at the
        // tightest captured bound.
        assert_eq!(sc.tolerance_fraction, 0.5);
        assert_eq!(sc.tolerance, 1e-9);
    }

    #[test]
    fn exact_only_captures_replay_without_tolerances() {
        let p = capture(
            "exact",
            &[
                Event::register("m", 40, 100, "none"),
                Event::solve("m", 1, false, None, None),
            ],
        );
        let sc = scenario_from_journal(&p, "exact").unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(sc.tolerance_fraction, 0.0);
    }

    #[test]
    fn digests_flag_structural_divergence_across_a_capture() {
        use crate::sparse::generate;
        let m1 = generate::random_lower(60, 2, 0.8, &Default::default());
        let mut refreshed = m1.clone();
        for v in &mut refreshed.data {
            *v *= 1.1;
        }
        let m2 = generate::random_lower(60, 4, 0.8, &Default::default());
        let p = capture(
            "diverge",
            &[
                Event::register("stable", 60, m1.nnz(), "none").with_matrix(&m1),
                // Same pattern, new numerics: NOT a divergence.
                Event::update("stable").with_matrix(&refreshed),
                Event::register("swapped", 60, m1.nnz(), "none").with_matrix(&m1),
                // Re-registration with a different sparsity pattern: is.
                Event::register("swapped", 60, m2.nnz(), "none").with_matrix(&m2),
                // Digest-less legacy events flag nothing.
                Event::register("legacy", 10, 10, "none"),
                Event::register("legacy", 99, 300, "none"),
                Event::solve("stable", 1, false, None, None),
            ],
        );
        let records = crate::telemetry::journal::read(&p).unwrap();
        assert_eq!(structural_divergence(&records), vec!["swapped".to_string()]);
        // The warning path is non-fatal: the scenario still builds, on
        // the first registered shape.
        let sc = scenario_from_journal(&p, "diverge").unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(sc.matrices.iter().filter(|m| m.id == "swapped").count(), 1);
    }

    #[test]
    fn rejects_journals_replay_cannot_drive() {
        let p = capture("noreg", &[Event::solve("ghost", 1, false, None, None)]);
        assert!(scenario_from_journal(&p, "x").is_err());
        std::fs::remove_file(&p).ok();

        let p = capture("nosolve", &[Event::register("m", 10, 10, "none")]);
        assert!(scenario_from_journal(&p, "x").is_err());
        std::fs::remove_file(&p).ok();

        let p = capture(
            "badname",
            &[
                Event::register("m", 10, 10, "none"),
                Event::solve("m", 1, false, None, None),
            ],
        );
        assert!(scenario_from_journal(&p, "bad name!").is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn replayed_scenario_runs_deterministically_through_bench() {
        // The record→replay determinism criterion: the same journal,
        // replayed twice with the same seed and no deadlines, yields
        // identical ticket-outcome tallies and lane mixes. (Deadline
        // misses are wall-clock dependent, so the capture uses none.)
        let p = capture(
            "det",
            &[
                Event::register("a", 60, 170, "none"),
                Event::solve("a", 1, true, None, None),
                Event::solve("a", 1, false, None, None),
                Event::solve("a", 2, false, None, None),
                Event::solve("a", 1, true, None, None),
            ],
        );
        let sc = scenario_from_journal(&p, "det").unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(sc.requests, 4);

        let dir = std::env::temp_dir().join(format!("sptrsv_replay_bench_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = crate::config::Config {
            workers: 2,
            use_xla: false,
            bench_out_dir: dir.to_str().unwrap().to_string(),
            ..Default::default()
        };
        let one = crate::bench::run(&sc, &cfg).unwrap();
        let two = crate::bench::run(&sc, &cfg).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        for r in [&one.report, &two.report] {
            assert_eq!(r.get("requests").and_then(crate::util::json::Json::as_f64), Some(4.0));
        }
        // Identical outcome tallies: every replayed ticket resolved Ok
        // both times (no deadlines → nothing wall-clock dependent).
        for out in [&one, &two] {
            let tickets = out.report.get("tickets").unwrap();
            assert_eq!(tickets.get("ok").and_then(crate::util::json::Json::as_f64), Some(4.0));
        }
        // And the rng-driven lane split is identical run to run.
        assert_eq!(one.snapshot.interactive.solves, two.snapshot.interactive.solves);
        assert_eq!(one.snapshot.batch.solves, two.snapshot.batch.solves);
        assert_eq!(
            one.snapshot.interactive.solves + one.snapshot.batch.solves,
            8,
            "4 requests × block_size 2 right-hand sides"
        );
    }
}
