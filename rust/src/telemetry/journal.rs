//! Live-traffic journal: every shaping-relevant request the service
//! loop sees — register / solve / solve_many / update_values / cancel
//! sweeps — appended as one JSONL event with its arrival offset.
//!
//! The journal must never add latency to the service loop, so
//! [`Journal::record`] only stamps the arrival offset and `try_send`s
//! the event to a dedicated writer thread over a **bounded** channel;
//! when the writer falls behind, events are dropped and counted
//! ([`Journal::dropped`]) rather than ever blocking a solve. The first
//! line of every journal is a header record carrying
//! [`JOURNAL_SCHEMA_VERSION`]; [`read`] refuses files whose header
//! disagrees, so replay never misinterprets an old capture.
//!
//! `sptrsv replay --journal FILE` turns a capture back into offered
//! load (see [`crate::telemetry::replay`]).

use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::Config;
use crate::error::Error;
use crate::sparse::Csr;
use crate::util::json::Json;

/// Stamped into the journal's header line; bump on any event-shape
/// change so old captures fail loudly instead of replaying nonsense.
/// (Purely additive optional fields — like the `digest` on matrix
/// events — do not bump: old captures still replay correctly, they
/// just carry less information.)
pub const JOURNAL_SCHEMA_VERSION: u64 = 1;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn digest_of(m: &Csr, with_values: bool) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = fnv1a(h, &(m.nrows as u64).to_le_bytes());
    h = fnv1a(h, &(m.ncols as u64).to_le_bytes());
    for &p in &m.indptr {
        h = fnv1a(h, &(p as u64).to_le_bytes());
    }
    for &c in &m.indices {
        h = fnv1a(h, &c.to_le_bytes());
    }
    if with_values {
        for &v in &m.data {
            h = fnv1a(h, &v.to_bits().to_le_bytes());
        }
    }
    h
}

/// 64-bit FNV-1a digest of a CSR payload: shape, both structure arrays,
/// and the bit patterns of the values. Journaled with `register` and
/// `update_values` events so replay can tell when a capture's matrices
/// structurally diverged mid-stream (a re-registration that swapped the
/// sparsity pattern) versus merely refreshing numerics.
pub fn matrix_digest(m: &Csr) -> u64 {
    digest_of(m, true)
}

/// The structure-only half of [`matrix_digest`]: same FNV-1a stream
/// minus the value bits, so refreshed numerics hash equal while a
/// swapped sparsity pattern does not.
pub fn structure_digest(m: &Csr) -> u64 {
    digest_of(m, false)
}

const KIND: &str = "sptrsv-journal";

/// Bounded depth of the writer channel: deep enough to absorb a burst,
/// small enough that a stuck disk costs memory, not the service loop.
const CHANNEL_DEPTH: usize = 4096;

/// One journaled service event. `kind` is the wire tag (`register`,
/// `solve`, `solve_many`, `update_values`, `cancel`); the remaining
/// fields are meaningful per kind and default-empty otherwise.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Event {
    pub kind: String,
    /// matrix id (`register`/`solve*`/`update_values`)
    pub id: String,
    /// matrix shape at registration, enough for replay to size a
    /// structurally comparable generator
    pub nrows: usize,
    pub nnz: usize,
    /// resolved plan name the registration prepared with
    pub plan: String,
    /// right-hand sides in the request (`solve*`)
    pub block: usize,
    /// whether the request rode the interactive lane (`solve*`)
    pub interactive: bool,
    /// deadline budget relative to submission, when the request had one
    pub deadline_us: Option<u64>,
    /// relative-residual tolerance the request stated, when it did
    /// (`solve*`; additive field, schema stays at version 1)
    pub tol: Option<f64>,
    /// tenant the request named explicitly, when it did
    pub tenant: Option<String>,
    /// [`matrix_digest`] of the payload (`register`/`update_values`)
    pub digest: Option<u64>,
    /// [`structure_digest`] of the payload (`register`/`update_values`)
    pub sdigest: Option<u64>,
}

impl Event {
    pub fn register(id: &str, nrows: usize, nnz: usize, plan: &str) -> Event {
        Event {
            kind: "register".to_string(),
            id: id.to_string(),
            nrows,
            nnz,
            plan: plan.to_string(),
            ..Default::default()
        }
    }

    /// A solve request: single-RHS submissions journal as `solve`,
    /// multi-RHS blocks as `solve_many`.
    pub fn solve(
        id: &str,
        block: usize,
        interactive: bool,
        deadline_us: Option<u64>,
        tenant: Option<&str>,
    ) -> Event {
        Event {
            kind: if block > 1 { "solve_many" } else { "solve" }.to_string(),
            id: id.to_string(),
            block: block.max(1),
            interactive,
            deadline_us,
            tenant: tenant.map(str::to_string),
            ..Default::default()
        }
    }

    pub fn update(id: &str) -> Event {
        Event {
            kind: "update_values".to_string(),
            id: id.to_string(),
            ..Default::default()
        }
    }

    /// Attach the tolerance a solve request stated, so replay can
    /// regenerate toleranced traffic instead of flattening every capture
    /// to exact solves.
    pub fn with_tolerance(mut self, tol: Option<f64>) -> Event {
        self.tol = tol.filter(|t| *t > 0.0);
        self
    }

    /// Attach the payload digests of the matrix this event carried.
    /// Hashing happens on the caller's thread (the service loop), but an
    /// FNV pass over the CSR arrays is linear and branch-free — noise
    /// next to the preparation the same payload just paid for.
    pub fn with_matrix(mut self, m: &Csr) -> Event {
        self.digest = Some(matrix_digest(m));
        self.sdigest = Some(structure_digest(m));
        self
    }

    /// A cancellation wakeup swept the queues.
    pub fn cancel() -> Event {
        Event {
            kind: "cancel".to_string(),
            ..Default::default()
        }
    }

    fn to_json(&self, t_us: u64) -> Json {
        let mut fields = vec![
            ("t_us", Json::Num(t_us as f64)),
            ("ev", Json::Str(self.kind.clone())),
        ];
        if !self.id.is_empty() {
            fields.push(("id", Json::Str(self.id.clone())));
        }
        if self.kind == "register" {
            fields.push(("nrows", Json::Num(self.nrows as f64)));
            fields.push(("nnz", Json::Num(self.nnz as f64)));
            fields.push(("plan", Json::Str(self.plan.clone())));
        }
        if self.kind.starts_with("solve") {
            fields.push(("block", Json::Num(self.block as f64)));
            let lane = if self.interactive { "interactive" } else { "batch" };
            fields.push(("lane", Json::Str(lane.to_string())));
            if let Some(d) = self.deadline_us {
                fields.push(("deadline_us", Json::Num(d as f64)));
            }
            if let Some(t) = self.tol {
                fields.push(("tol", Json::Num(t)));
            }
            if let Some(t) = &self.tenant {
                fields.push(("tenant", Json::Str(t.clone())));
            }
        }
        // Digests print as fixed-width hex strings: a u64 does not
        // survive a round-trip through a JSON f64.
        if let Some(d) = self.digest {
            fields.push(("digest", Json::Str(format!("{d:016x}"))));
        }
        if let Some(d) = self.sdigest {
            fields.push(("sdigest", Json::Str(format!("{d:016x}"))));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> Option<Event> {
        let kind = j.get("ev").and_then(Json::as_str)?.to_string();
        Some(Event {
            kind,
            id: j.get("id").and_then(Json::as_str).unwrap_or("").to_string(),
            nrows: j.get("nrows").and_then(Json::as_usize).unwrap_or(0),
            nnz: j.get("nnz").and_then(Json::as_usize).unwrap_or(0),
            plan: j.get("plan").and_then(Json::as_str).unwrap_or("").to_string(),
            block: j.get("block").and_then(Json::as_usize).unwrap_or(0),
            interactive: j.get("lane").and_then(Json::as_str) == Some("interactive"),
            deadline_us: j
                .get("deadline_us")
                .and_then(Json::as_f64)
                .map(|d| d as u64),
            tol: j.get("tol").and_then(Json::as_f64).filter(|t| *t > 0.0),
            tenant: j
                .get("tenant")
                .and_then(Json::as_str)
                .map(str::to_string),
            digest: hex_u64(j.get("digest")),
            sdigest: hex_u64(j.get("sdigest")),
        })
    }
}

fn hex_u64(j: Option<&Json>) -> Option<u64> {
    j.and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok())
}

/// One line of a parsed journal: the event plus its arrival offset from
/// the journal's start.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub t_us: u64,
    pub ev: Event,
}

/// The recording half: owned by the service loop, writes happen on a
/// background thread. Dropping the journal closes the channel and joins
/// the writer, flushing everything already enqueued.
pub struct Journal {
    tx: Option<SyncSender<(u64, Event)>>,
    dropped: Arc<AtomicU64>,
    join: Option<JoinHandle<()>>,
    start: Instant,
}

impl Journal {
    /// The service-side constructor: `None` unless `journal_enabled`
    /// (an unwritable path logs to stderr and disables journaling
    /// rather than failing service startup).
    pub fn from_config(cfg: &Config) -> Option<Journal> {
        if !cfg.journal_enabled || cfg.journal_path.is_empty() {
            return None;
        }
        match Journal::create(Path::new(&cfg.journal_path)) {
            Ok(j) => Some(j),
            Err(e) => {
                eprintln!("journal disabled: {e}");
                None
            }
        }
    }

    /// Start a journal at `path` (truncating — a journal file is one
    /// capture) and spawn its writer thread.
    pub fn create(path: &Path) -> Result<Journal, Error> {
        let file = std::fs::File::create(path)
            .map_err(|e| Error::Io(format!("journal {}: {e}", path.display())))?;
        let (tx, rx) = mpsc::sync_channel::<(u64, Event)>(CHANNEL_DEPTH);
        let join = std::thread::Builder::new()
            .name("sptrsv-journal".into())
            .spawn(move || {
                let mut w = BufWriter::new(file);
                let header = Json::obj(vec![
                    ("journal_schema_version", Json::Num(JOURNAL_SCHEMA_VERSION as f64)),
                    ("kind", Json::Str(KIND.to_string())),
                ]);
                let _ = writeln!(w, "{header}");
                while let Ok((t_us, ev)) = rx.recv() {
                    let _ = writeln!(w, "{}", ev.to_json(t_us));
                }
                let _ = w.flush();
            })
            .map_err(|e| Error::Io(format!("journal writer thread: {e}")))?;
        Ok(Journal {
            tx: Some(tx),
            dropped: Arc::new(AtomicU64::new(0)),
            join: Some(join),
            start: Instant::now(),
        })
    }

    /// Enqueue one event, stamped with its arrival offset. Never blocks:
    /// a full channel drops the event and counts it instead.
    pub fn record(&self, ev: Event) {
        let t_us = self.start.elapsed().as_micros() as u64;
        if let Some(tx) = &self.tx {
            match tx.try_send((t_us, ev)) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Disconnected(_)) => {}
            }
        }
    }

    /// Events dropped because the writer could not keep up.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel: the writer drains and exits
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Parse a journal file back into records. The header line must carry
/// the current [`JOURNAL_SCHEMA_VERSION`].
pub fn read(path: &Path) -> Result<Vec<Record>, Error> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines
        .next()
        .ok_or_else(|| Error::Invalid(format!("{}: empty journal", path.display())))?;
    let hj = Json::parse(header)
        .map_err(|e| Error::Invalid(format!("{}: bad header: {e}", path.display())))?;
    let version = hj
        .get("journal_schema_version")
        .and_then(Json::as_f64)
        .map(|v| v as u64);
    if version != Some(JOURNAL_SCHEMA_VERSION) {
        return Err(Error::Invalid(format!(
            "{}: journal schema {:?}, this build reads {}",
            path.display(),
            version,
            JOURNAL_SCHEMA_VERSION
        )));
    }
    let mut records = Vec::new();
    for (i, line) in lines.enumerate() {
        let j = Json::parse(line)
            .map_err(|e| Error::Invalid(format!("{}:{}: bad event: {e}", path.display(), i + 2)))?;
        let t_us = j.get("t_us").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let ev = Event::from_json(&j).ok_or_else(|| {
            Error::Invalid(format!("{}:{}: event without 'ev'", path.display(), i + 2))
        })?;
        records.push(Record { t_us, ev });
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sptrsv_journal_{}_{name}", std::process::id()))
    }

    #[test]
    fn journal_roundtrips_through_the_reader() {
        let p = tmp("rt.jsonl");
        let j = Journal::create(&p).unwrap();
        j.record(Event::register("m", 120, 456, "avgcost"));
        j.record(Event::solve("m", 1, true, Some(5_000), None).with_tolerance(Some(1e-6)));
        j.record(Event::solve("m", 4, false, None, Some("acme")));
        j.record(Event::update("m"));
        j.record(Event::cancel());
        assert_eq!(j.dropped(), 0);
        drop(j); // flush

        let recs = read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(recs.len(), 5);
        assert_eq!(recs[0].ev, Event::register("m", 120, 456, "avgcost"));
        assert_eq!(recs[1].ev.kind, "solve");
        assert!(recs[1].ev.interactive);
        assert_eq!(recs[1].ev.deadline_us, Some(5_000));
        assert_eq!(recs[1].ev.block, 1);
        // The stated tolerance rides along; requests without one carry
        // no `tol` field at all.
        assert_eq!(recs[1].ev.tol, Some(1e-6));
        // Multi-RHS submissions journal as solve_many with their tenant.
        assert_eq!(recs[2].ev.kind, "solve_many");
        assert_eq!(recs[2].ev.block, 4);
        assert!(!recs[2].ev.interactive);
        assert_eq!(recs[2].ev.tenant.as_deref(), Some("acme"));
        assert_eq!(recs[2].ev.tol, None);
        assert_eq!(recs[3].ev.kind, "update_values");
        assert_eq!(recs[4].ev.kind, "cancel");
        // Arrival offsets are monotone.
        assert!(recs.windows(2).all(|w| w[0].t_us <= w[1].t_us));
    }

    #[test]
    fn matrix_digests_separate_value_refreshes_from_structure_swaps() {
        use crate::sparse::generate;
        let m = generate::random_lower(80, 3, 0.8, &Default::default());
        // A value refresh moves the payload digest but not the
        // structural one; a different sparsity pattern moves both.
        let mut refreshed = m.clone();
        for v in &mut refreshed.data {
            *v *= 1.01;
        }
        let swapped = generate::random_lower(80, 4, 0.8, &Default::default());
        assert_ne!(matrix_digest(&m), matrix_digest(&refreshed));
        assert_eq!(structure_digest(&m), structure_digest(&refreshed));
        assert_ne!(structure_digest(&m), structure_digest(&swapped));

        // Digests survive the JSONL round-trip as full-width u64s.
        let p = tmp("digest.jsonl");
        let j = Journal::create(&p).unwrap();
        j.record(Event::register("m", m.nrows, m.nnz(), "none").with_matrix(&m));
        j.record(Event::update("m").with_matrix(&refreshed));
        drop(j);
        let recs = read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(recs[0].ev.digest, Some(matrix_digest(&m)));
        assert_eq!(recs[0].ev.sdigest, Some(structure_digest(&m)));
        assert_eq!(recs[1].ev.digest, Some(matrix_digest(&refreshed)));
        assert_eq!(recs[1].ev.sdigest, Some(structure_digest(&m)));
    }

    #[test]
    fn reader_rejects_wrong_schema_and_garbage() {
        let p = tmp("bad.jsonl");
        std::fs::write(&p, "{\"journal_schema_version\": 99}\n").unwrap();
        assert!(read(&p).is_err(), "future schema refused");
        std::fs::write(&p, "").unwrap();
        assert!(read(&p).is_err(), "empty journal refused");
        std::fs::write(
            &p,
            format!("{{\"journal_schema_version\": {JOURNAL_SCHEMA_VERSION}}}\nnot json\n"),
        )
        .unwrap();
        assert!(read(&p).is_err(), "garbage event line refused");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn journal_from_config_respects_the_enable_gate() {
        let cfg = Config::default();
        assert!(Journal::from_config(&cfg).is_none(), "off by default");
        let p = tmp("cfg.jsonl");
        let cfg = Config {
            journal_enabled: true,
            journal_path: p.to_str().unwrap().to_string(),
            ..Default::default()
        };
        let j = Journal::from_config(&cfg).expect("enabled journal opens");
        j.record(Event::cancel());
        drop(j);
        assert_eq!(read(&p).unwrap().len(), 1);
        std::fs::remove_file(&p).ok();
    }
}
