//! The telemetry plane: capture live traffic, replay it as a benchmark,
//! and gate on the trend between runs.
//!
//! Three legs, wired end to end:
//!
//! 1. **[`journal`]** — with `journal_enabled`, the service appends
//!    every shaping-relevant request (register / solve / solve_many /
//!    update_values / cancel sweeps) to a schema-stamped JSONL file at
//!    `journal_path`, via a bounded background writer that drops under
//!    pressure instead of ever blocking the service loop.
//! 2. **[`replay`]** — `sptrsv replay --journal FILE` turns a capture
//!    back into a [`crate::bench::Scenario`] (matrices rebuilt at the
//!    journaled dimensions, traffic shape lifted from the events) and
//!    runs it through the standard bench harness, emitting a normal
//!    `BENCH_*.json` trajectory.
//! 3. **[`trend`]** — `sptrsv bench --compare BASE.json NEW.json`
//!    diffs two trajectories (throughput, per-lane percentiles,
//!    deadline misses, elastic counters) and exits nonzero when a
//!    lane's p95 regressed beyond `--p95-tolerance`.
//!
//! Together: production traffic becomes a repeatable benchmark, and the
//! benchmark's history becomes a regression gate.

pub mod journal;
pub mod replay;
pub mod trend;

pub use journal::{Event, Journal, Record, JOURNAL_SCHEMA_VERSION};
pub use replay::scenario_from_journal;
pub use trend::{compare, TrendReport};
