//! Trend regression gating: diff two `BENCH_*.json` trajectories and
//! decide whether the new run regressed. `sptrsv bench --compare
//! BASE.json NEW.json [--p95-tolerance PCT]` prints the report and
//! exits nonzero when a gated lane's p95 degraded beyond tolerance —
//! the CI hook that turns the archived trajectory from a curiosity
//! into a gate.
//!
//! Only per-lane p95 latency gates: it is the serving SLO, and the
//! log2-bucketed histograms make it stable enough to compare (a p95
//! can only move in power-of-two steps, so a generous tolerance —
//! CI uses several hundred percent — separates noise from a real
//! cliff). Throughput, p50/p99, the deadline-miss rate and the elastic
//! counters are reported for eyes, not gated: they swing too wildly on
//! shared CI runners to fail a build over.

use crate::error::Error;
use crate::util::json::Json;

/// The outcome of one comparison: human-readable lines plus the gate
/// verdict.
#[derive(Debug, Clone)]
pub struct TrendReport {
    pub lines: Vec<String>,
    /// true when any gated lane's p95 degraded beyond tolerance
    pub regressed: bool,
}

impl std::fmt::Display for TrendReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for l in &self.lines {
            writeln!(f, "{l}")?;
        }
        Ok(())
    }
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn lane<'a>(report: &'a Json, name: &str) -> Option<&'a Json> {
    report.get("latency_us").and_then(|l| l.get(name))
}

fn pct_change(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (new - base) / base * 100.0
    }
}

/// Compare two bench reports. `p95_tolerance_pct` is how much worse the
/// new p95 may be, per lane, before the comparison counts as a
/// regression (e.g. `50.0` allows up to +50%).
pub fn compare(base: &Json, new: &Json, p95_tolerance_pct: f64) -> Result<TrendReport, Error> {
    for (which, j) in [("base", base), ("new", new)] {
        if j.get("kind").and_then(Json::as_str) != Some("sptrsv-bench") {
            return Err(Error::Invalid(format!(
                "compare: {which} report is not a sptrsv-bench trajectory"
            )));
        }
    }
    let mut lines = Vec::new();
    let (bv, nv) = (num(base, "schema_version"), num(new, "schema_version"));
    lines.push(format!(
        "trend: {} (schema {}) -> {} (schema {}), p95 tolerance +{:.0}%",
        base.get("scenario").and_then(Json::as_str).unwrap_or("?"),
        bv,
        new.get("scenario").and_then(Json::as_str).unwrap_or("?"),
        nv,
        p95_tolerance_pct
    ));
    if bv != nv {
        lines.push(format!(
            "  note: schema versions differ ({bv} vs {nv}); comparing shared fields"
        ));
    }

    let (bt, nt) = (num(base, "throughput_rps"), num(new, "throughput_rps"));
    lines.push(format!("  throughput_rps {bt:.1} -> {nt:.1} ({:+.1}%)", pct_change(bt, nt)));
    let (bm, nm) = (num(base, "deadline_miss_rate"), num(new, "deadline_miss_rate"));
    lines.push(format!("  deadline_miss_rate {bm:.4} -> {nm:.4}"));

    let mut regressed = false;
    for name in ["interactive", "batch", "combined"] {
        let (Some(b), Some(n)) = (lane(base, name), lane(new, name)) else {
            lines.push(format!("  {name}: missing in one report, skipped"));
            continue;
        };
        let (bs, ns) = (num(b, "solves"), num(n, "solves"));
        if bs == 0.0 || ns == 0.0 {
            lines.push(format!(
                "  {name}: no traffic in {} run, not gated",
                if bs == 0.0 { "base" } else { "new" }
            ));
            continue;
        }
        let (bp95, np95) = (num(b, "p95_us"), num(n, "p95_us"));
        let delta = pct_change(bp95, np95);
        let gate_fails = bp95 > 0.0 && delta > p95_tolerance_pct;
        lines.push(format!(
            "  {name}: p50 {:.0}->{:.0}us  p95 {bp95:.0}->{np95:.0}us ({delta:+.1}%){}  p99 {:.0}->{:.0}us",
            num(b, "p50_us"),
            num(n, "p50_us"),
            if gate_fails { "  REGRESSED" } else { "" },
            num(b, "p99_us"),
            num(n, "p99_us"),
        ));
        regressed |= gate_fails;
    }

    if let (Some(be), Some(ne)) = (base.get("elastic"), new.get("elastic")) {
        lines.push(format!(
            "  elastic waits {:.0}->{:.0} ooo {:.0}->{:.0} steals {:.0}->{:.0}",
            num(be, "waits"),
            num(ne, "waits"),
            num(be, "ooo"),
            num(ne, "ooo"),
            num(be, "steals"),
            num(ne, "steals"),
        ));
    }
    lines.push(if regressed {
        "  verdict: REGRESSED (p95 beyond tolerance)".to_string()
    } else {
        "  verdict: ok".to_string()
    });
    Ok(TrendReport { lines, regressed })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(p95_interactive: f64, p95_batch: f64, throughput: f64) -> Json {
        let lane = |p95: f64| {
            Json::obj(vec![
                ("solves", Json::Num(10.0)),
                ("mean_us", Json::Num(p95 / 2.0)),
                ("p50_us", Json::Num(p95 / 2.0)),
                ("p95_us", Json::Num(p95)),
                ("p99_us", Json::Num(p95 * 2.0)),
            ])
        };
        Json::obj(vec![
            ("schema_version", Json::Num(3.0)),
            ("kind", Json::Str("sptrsv-bench".to_string())),
            ("scenario", Json::Str("unit".to_string())),
            ("throughput_rps", Json::Num(throughput)),
            ("deadline_miss_rate", Json::Num(0.0)),
            (
                "latency_us",
                Json::obj(vec![
                    ("interactive", lane(p95_interactive)),
                    ("batch", lane(p95_batch)),
                    ("combined", lane(p95_interactive.max(p95_batch))),
                ]),
            ),
            (
                "elastic",
                Json::obj(vec![
                    ("waits", Json::Num(1.0)),
                    ("ooo", Json::Num(2.0)),
                    ("steals", Json::Num(3.0)),
                ]),
            ),
        ])
    }

    #[test]
    fn equal_runs_pass_the_gate() {
        let base = report(128.0, 4096.0, 100.0);
        let t = compare(&base, &base, 50.0).unwrap();
        assert!(!t.regressed, "{t}");
        assert!(t.to_string().contains("verdict: ok"), "{t}");
    }

    #[test]
    fn p95_beyond_tolerance_regresses_and_within_passes() {
        let base = report(128.0, 4096.0, 100.0);
        // Interactive p95 doubled: +100% > 50% tolerance.
        let worse = report(256.0, 4096.0, 90.0);
        let t = compare(&base, &worse, 50.0).unwrap();
        assert!(t.regressed, "{t}");
        assert!(t.to_string().contains("REGRESSED"), "{t}");
        // The same doubling passes a 150% tolerance.
        let t = compare(&base, &worse, 150.0).unwrap();
        assert!(!t.regressed, "{t}");
        // An improvement is never a regression.
        let better = report(64.0, 2048.0, 140.0);
        let t = compare(&base, &better, 0.0).unwrap();
        assert!(!t.regressed, "{t}");
    }

    #[test]
    fn empty_lanes_and_throughput_are_not_gated() {
        let mut_lane_zero = |mut j: Json, name: &str| {
            if let Json::Obj(ref mut o) = j {
                if let Some(Json::Obj(lat)) = o.get_mut("latency_us") {
                    if let Some(Json::Obj(l)) = lat.get_mut(name) {
                        l.insert("solves".to_string(), Json::Num(0.0));
                        l.insert("p95_us".to_string(), Json::Num(0.0));
                    }
                }
            }
            j
        };
        let base = mut_lane_zero(report(128.0, 4096.0, 100.0), "interactive");
        let new = mut_lane_zero(report(999_999.0, 4096.0, 1.0), "interactive");
        // Interactive lane empty in base → skipped; throughput collapse
        // alone (100 → 1 rps) is informational, never a gate.
        let t = compare(&base, &new, 50.0).unwrap();
        assert!(!t.regressed, "{t}");
        assert!(t.to_string().contains("not gated"), "{t}");
    }

    #[test]
    fn refuses_non_bench_files() {
        let base = report(128.0, 4096.0, 100.0);
        let junk = Json::obj(vec![("kind", Json::Str("something".to_string()))]);
        assert!(compare(&base, &junk, 50.0).is_err());
        assert!(compare(&junk, &base, 50.0).is_err());
    }
}
