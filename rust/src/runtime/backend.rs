//! XLA-backed solver: executes the registry's AOT executables against a
//! padded system. This is the L3->L2/L1 bridge on the request path.

use std::sync::Arc;

use crate::error::Error;
use crate::runtime::padded::{PadShape, PaddedSystem};
use crate::runtime::registry::Registry;

pub struct XlaSolver {
    pub registry: Arc<Registry>,
}

/// A padded system staged on the PJRT device: the four structure arrays
/// (rows/vals/cols/inv_diag) are uploaded ONCE and reused across solves —
/// only the right-hand side moves per request. §Perf finding: rebuilding
/// the literals per call cost ~20 ms/solve; staged buffers cut the solve
/// to ~1 ms (see EXPERIMENTS.md §Perf).
pub struct StagedSystem {
    solve_name: String,
    /// batched-solve executable sharing the same system arrays, if one
    /// exists at this exact shape: (name, batch size)
    batch: Option<(String, usize)>,
    device_args: Vec<xla::PjRtBuffer>,
}

impl StagedSystem {
    pub fn batch_size(&self) -> Option<usize> {
        self.batch.as_ref().map(|&(_, b)| b)
    }
}

impl XlaSolver {
    pub fn new(registry: Arc<Registry>) -> XlaSolver {
        XlaSolver { registry }
    }

    /// Upload the system arrays to the device for the exact-fit solve
    /// executable.
    pub fn stage(&self, p: &PaddedSystem) -> Result<StagedSystem, Error> {
        let meta = self
            .registry
            .best_fit("solve", &p.shape)
            .filter(|m| m.pad_shape() == p.shape)
            .ok_or_else(|| Error::NoFit(format!("no solve artifact for {:?}", p.shape)))?;
        let solve_name = meta.name.clone();
        let batch = self
            .registry
            .metas
            .iter()
            .find(|m| m.entry == "solve_batched" && m.pad_shape() == p.shape)
            .and_then(|m| m.b.map(|b| (m.name.clone(), b)));
        let client = &self.registry.client;
        let PadShape { l, r, k, .. } = p.shape;
        let buf_i32 = |data: &[i32], dims: &[usize]| {
            client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| Error::Runtime(format!("stage i32 buffer: {e}")))
        };
        let buf_f64 = |data: &[f64], dims: &[usize]| {
            client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| Error::Runtime(format!("stage f64 buffer: {e}")))
        };
        let device_args = vec![
            buf_i32(&p.rows, &[l, r])?,
            buf_f64(&p.vals, &[l, r, k])?,
            buf_i32(&p.cols, &[l, r, k])?,
            buf_f64(&p.inv_diag, &[l, r])?,
        ];
        Ok(StagedSystem {
            solve_name,
            batch,
            device_args,
        })
    }

    /// Batched solve against a staged system (bs.len() must equal the
    /// staged batch size).
    pub fn solve_batched_staged(
        &self,
        staged: &StagedSystem,
        p: &PaddedSystem,
        bs: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>, Error> {
        let (name, bsz) = staged
            .batch
            .as_ref()
            .ok_or_else(|| Error::NoFit("no staged batch executable".into()))?;
        if bs.len() != *bsz {
            return Err(Error::NoFit(format!(
                "staged batch is {bsz}, got {}",
                bs.len()
            )));
        }
        let exe = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("'{name}' not loaded")))?;
        let n = p.shape.n;
        let mut flat = Vec::with_capacity(bs.len() * n);
        for b in bs {
            flat.extend_from_slice(&p.map_rhs(b));
        }
        let bbuf = self
            .registry
            .client
            .buffer_from_host_buffer(&flat, &[bs.len(), n], None)
            .map_err(|e| Error::Runtime(format!("b buffer: {e}")))?;
        let mut args: Vec<&xla::PjRtBuffer> = staged.device_args.iter().collect();
        args.push(&bbuf);
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        let x: Vec<f64> = out
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(x.chunks(n).map(|c| c[..p.nrows].to_vec()).collect())
    }

    /// Solve against a staged system: only b is transferred.
    pub fn solve_staged(
        &self,
        staged: &StagedSystem,
        p: &PaddedSystem,
        b: &[f64],
    ) -> Result<Vec<f64>, Error> {
        let exe = self
            .registry
            .get(&staged.solve_name)
            .ok_or_else(|| Error::Runtime(format!("'{}' not loaded", staged.solve_name)))?;
        let bp = p.map_rhs(b);
        let bbuf = self
            .registry
            .client
            .buffer_from_host_buffer(&bp, &[p.shape.n], None)
            .map_err(|e| Error::Runtime(format!("b buffer: {e}")))?;
        let mut args: Vec<&xla::PjRtBuffer> = staged.device_args.iter().collect();
        args.push(&bbuf);
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", staged.solve_name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        let x: Vec<f64> = out
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(p.trim_solution(x))
    }

    fn lit_f64(data: &[f64], dims: &[i64]) -> Result<xla::Literal, Error> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| Error::Runtime(format!("reshape f64 {dims:?}: {e}")))
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal, Error> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| Error::Runtime(format!("reshape i32 {dims:?}: {e}")))
    }

    fn run(
        &self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<xla::Literal, Error> {
        let exe = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("executable '{name}' not loaded")))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal {name}: {e}")))?;
        lit.to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))
    }

    fn system_literals(&self, p: &PaddedSystem) -> Result<[xla::Literal; 4], Error> {
        let PadShape { l, r, k, .. } = p.shape;
        Ok([
            Self::lit_i32(&p.rows, &[l as i64, r as i64])?,
            Self::lit_f64(&p.vals, &[l as i64, r as i64, k as i64])?,
            Self::lit_i32(&p.cols, &[l as i64, r as i64, k as i64])?,
            Self::lit_f64(&p.inv_diag, &[l as i64, r as i64])?,
        ])
    }

    /// Full solve via the `solve` executable matching `p.shape` exactly.
    pub fn solve(&self, p: &PaddedSystem, b: &[f64]) -> Result<Vec<f64>, Error> {
        let meta = self
            .registry
            .best_fit("solve", &p.shape)
            .filter(|m| m.pad_shape() == p.shape)
            .ok_or_else(|| Error::NoFit(format!("no solve artifact for {:?}", p.shape)))?;
        let [rows, vals, cols, inv_diag] = self.system_literals(p)?;
        let bp = p.map_rhs(b);
        let bl = Self::lit_f64(&bp, &[p.shape.n as i64])?;
        let out = self.run(&meta.name.clone(), &[rows, vals, cols, inv_diag, bl])?;
        let x: Vec<f64> = out
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(p.trim_solution(x))
    }

    /// Batched solve: `bs` right-hand sides (bs.len() == artifact batch).
    pub fn solve_batched(
        &self,
        p: &PaddedSystem,
        bs: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>, Error> {
        let meta = self
            .registry
            .metas
            .iter()
            .filter(|m| m.entry == "solve_batched" && m.pad_shape() == p.shape)
            .find(|m| m.b == Some(bs.len()))
            .ok_or_else(|| {
                Error::NoFit(format!(
                    "no batched artifact for {:?} x{}",
                    p.shape,
                    bs.len()
                ))
            })?;
        let name = meta.name.clone();
        let [rows, vals, cols, inv_diag] = self.system_literals(p)?;
        let n = p.shape.n;
        let mut flat = Vec::with_capacity(bs.len() * n);
        for b in bs {
            flat.extend_from_slice(&p.map_rhs(b));
        }
        let bl = Self::lit_f64(&flat, &[bs.len() as i64, n as i64])?;
        let out = self.run(&name, &[rows, vals, cols, inv_diag, bl])?;
        let x: Vec<f64> = out
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(x.chunks(n)
            .map(|c| c[..p.nrows].to_vec())
            .collect())
    }

    /// ||Lx - b||_inf via the residual executable (shape must match).
    pub fn residual(&self, p: &PaddedSystem, b: &[f64], x: &[f64]) -> Result<f64, Error> {
        let meta = self
            .registry
            .metas
            .iter()
            .find(|m| m.entry == "residual" && m.pad_shape() == p.shape)
            .ok_or_else(|| Error::NoFit(format!("no residual artifact for {:?}", p.shape)))?;
        let name = meta.name.clone();
        let [rows, vals, cols, inv_diag] = self.system_literals(p)?;
        let n = p.shape.n;
        let mut bp = p.map_rhs(b);
        bp.resize(n, 0.0);
        let mut xp = x.to_vec();
        xp.resize(n, 0.0);
        let bl = Self::lit_f64(&bp, &[n as i64])?;
        let xl = Self::lit_f64(&xp, &[n as i64])?;
        let out = self.run(&name, &[rows, vals, cols, inv_diag, bl, xl])?;
        let v: Vec<f64> = out
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(v[0])
    }
}
