//! Padded-level representation: the bridge between the (transformed)
//! sparse system and the statically-shaped XLA executables.
//!
//! Layout (matching `python/compile/model.py`):
//!   rows     (L, R) i32 — row id per slot, `n` (the dummy) on padding
//!   vals     (L, R, K) f64 — dependency coefficients, 0 on padding
//!   cols     (L, R, K) i32 — dependency columns, 0 on padding
//!   inv_diag (L, R) f64 — 1/diag per row, 0 on padding
//!
//! For rewritten rows the equation is `x = (Σ w_m b[m] - Σ a_k x_k)` with
//! the division folded, which fits the same kernel once the right-hand
//! side is pre-mapped: `b'[i] = Σ w_m b[m]` (identity for original rows).
//! The sparse map W is kept here and applied per request in O(nnz(W)).

use crate::error::Error;
use crate::sparse::Csr;
use crate::transform::TransformResult;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PadShape {
    pub l: usize,
    pub r: usize,
    pub k: usize,
    pub n: usize,
}

#[derive(Debug)]
pub struct PaddedSystem {
    pub shape: PadShape,
    /// real rows in the system (n <= shape.n)
    pub nrows: usize,
    pub rows: Vec<i32>,     // L*R
    pub vals: Vec<f64>,     // L*R*K
    pub cols: Vec<i32>,     // L*R*K
    pub inv_diag: Vec<f64>, // L*R
    /// RHS functional per row: None = identity (original row),
    /// Some(w) = b'[i] = Σ w_m b[m]
    bmap: Vec<Option<Vec<(u32, f64)>>>,
}

impl PaddedSystem {
    /// Requirements of a system before padding: (levels, max level width,
    /// max deps per row, n).
    pub fn requirements(m: &Csr, t: &TransformResult) -> PadShape {
        let l = t.levels.len();
        let r = t.levels.iter().map(Vec::len).max().unwrap_or(0);
        let mut k = 1;
        for i in 0..m.nrows {
            let nd = match &t.equations[i] {
                Some(eq) => eq.ndeps(),
                None => m.indegree(i),
            };
            k = k.max(nd);
        }
        PadShape {
            l,
            r,
            k,
            n: m.nrows,
        }
    }

    /// Build the padded arrays for a target artifact shape. Fails if the
    /// system does not fit.
    pub fn build(m: &Csr, t: &TransformResult, shape: PadShape) -> Result<PaddedSystem, Error> {
        let req = Self::requirements(m, t);
        if req.l > shape.l || req.r > shape.r || req.k > shape.k || req.n > shape.n {
            return Err(Error::NoFit(format!(
                "system needs (l={},r={},k={},n={}), artifact offers (l={},r={},k={},n={})",
                req.l, req.r, req.k, req.n, shape.l, shape.r, shape.k, shape.n
            )));
        }
        let (l, r, k) = (shape.l, shape.r, shape.k);
        let dummy = shape.n as i32; // padded rows scatter into slot N
        let mut rows = vec![dummy; l * r];
        let mut vals = vec![0.0; l * r * k];
        let mut cols = vec![0i32; l * r * k];
        let mut inv_diag = vec![0.0; l * r];
        let mut bmap: Vec<Option<Vec<(u32, f64)>>> = vec![None; m.nrows];

        for (li, level) in t.levels.iter().enumerate() {
            for (ri, &row) in level.iter().enumerate() {
                let i = row as usize;
                let slot = li * r + ri;
                rows[slot] = row as i32;
                let base = slot * k;
                match &t.equations[i] {
                    None => {
                        for (d, (&c, &v)) in
                            m.row_deps(i).iter().zip(m.row_dep_vals(i)).enumerate()
                        {
                            cols[base + d] = c as i32;
                            vals[base + d] = v;
                        }
                        inv_diag[slot] = 1.0 / m.diag(i);
                    }
                    Some(eq) => {
                        for (d, &(c, a)) in eq.coeffs.iter().enumerate() {
                            cols[base + d] = c as i32;
                            vals[base + d] = a;
                        }
                        inv_diag[slot] = 1.0 / eq.diag; // 1.0 once folded
                        bmap[i] = Some(eq.bcoeffs.clone());
                    }
                }
            }
        }
        Ok(PaddedSystem {
            shape,
            nrows: m.nrows,
            rows,
            vals,
            cols,
            inv_diag,
            bmap,
        })
    }

    /// Apply the RHS functional: b -> b' (padded to shape.n with zeros).
    pub fn map_rhs(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.nrows);
        let mut out = vec![0.0; self.shape.n];
        for i in 0..self.nrows {
            out[i] = match &self.bmap[i] {
                None => b[i],
                Some(w) => w.iter().map(|&(m, wm)| wm * b[m as usize]).sum(),
            };
        }
        out
    }

    /// Trim a shape.n-sized solution back to the real rows.
    pub fn trim_solution(&self, x: Vec<f64>) -> Vec<f64> {
        let mut x = x;
        x.truncate(self.nrows);
        x
    }

    /// VMEM-footprint estimate per level block (bytes) for the DESIGN.md
    /// §Hardware-Adaptation roofline discussion: one (block_r x K) tile of
    /// vals+cols, plus rows/b/inv_diag vectors.
    pub fn vmem_per_block(&self, block_r: usize) -> usize {
        let k = self.shape.k;
        block_r * k * (8 + 4) + block_r * (8 + 8 + 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate;
    use crate::transform::{Rewrite, SolvePlan};

    fn fits(m: &Csr, t: &TransformResult) -> PaddedSystem {
        let mut req = PaddedSystem::requirements(m, t);
        req.n += 3; // leave padding slack to exercise the dummy slot
        req.r += 2;
        req.k += 1;
        req.l += 1;
        PaddedSystem::build(m, t, req).unwrap()
    }

    /// CPU-side emulation of the L2 scan (exactly what the HLO computes):
    /// used to check the padded arrays are laid out correctly without
    /// needing the PJRT client in unit tests.
    fn emulate(p: &PaddedSystem, b: &[f64]) -> Vec<f64> {
        let PadShape { l, r, k, n } = p.shape;
        let bp = p.map_rhs(b);
        let mut b_ext = bp.clone();
        b_ext.push(0.0);
        let mut x = vec![0.0; n + 1];
        for li in 0..l {
            let mut xl = vec![0.0; r];
            for ri in 0..r {
                let slot = li * r + ri;
                let mut s = 0.0;
                for d in 0..k {
                    s += p.vals[slot * k + d] * x[p.cols[slot * k + d] as usize];
                }
                let row = p.rows[slot] as usize;
                xl[ri] = (b_ext[row] - s) * p.inv_diag[slot];
            }
            for ri in 0..r {
                x[p.rows[li * r + ri] as usize] = xl[ri];
            }
        }
        x.truncate(p.nrows);
        x
    }

    #[test]
    fn emulated_padded_solve_matches_serial() {
        for strat in ["none", "avgcost", "manual:5"] {
            let m = generate::random_lower(150, 3, 0.8, &Default::default());
            let t = SolvePlan::parse(strat).unwrap().apply(&m);
            let p = fits(&m, &t);
            let mut rng = crate::util::rng::Rng::new(11);
            let b: Vec<f64> = (0..m.nrows).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let x = emulate(&p, &b);
            let x_ref = crate::solver::serial::solve(&m, &b);
            crate::util::prop::assert_allclose(&x, &x_ref, 1e-9, 1e-12)
                .unwrap_or_else(|e| panic!("{strat}: {e}"));
        }
    }

    #[test]
    fn requirements_shrink_after_transform() {
        let m = generate::lung2_like(&generate::GenOptions::with_scale(0.05));
        let t0 = Rewrite::None.apply(&m);
        let t1 = SolvePlan::parse("avgcost").unwrap().apply(&m);
        let r0 = PaddedSystem::requirements(&m, &t0);
        let r1 = PaddedSystem::requirements(&m, &t1);
        assert!(r1.l < r0.l, "levels {} -> {}", r0.l, r1.l);
    }

    #[test]
    fn no_fit_is_detected() {
        let m = generate::random_lower(100, 3, 0.8, &Default::default());
        let t = Rewrite::None.apply(&m);
        let req = PaddedSystem::requirements(&m, &t);
        let too_small = PadShape { n: 50, ..req };
        assert!(matches!(
            PaddedSystem::build(&m, &t, too_small),
            Err(Error::NoFit(_))
        ));
    }

    #[test]
    fn map_rhs_identity_without_rewrites() {
        let m = generate::random_lower(50, 2, 0.5, &Default::default());
        let t = Rewrite::None.apply(&m);
        let p = fits(&m, &t);
        let b: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let bp = p.map_rhs(&b);
        assert_eq!(&bp[..50], &b[..]);
        assert!(bp[50..].iter().all(|&v| v == 0.0));
    }
}
