//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and execute them from the
//! rust hot path. Python never runs at request time.
//!
//! * [`registry`] — parses `artifacts/manifest.json`, loads + compiles
//!   every artifact on the PJRT CPU client, and picks the smallest shape
//!   that fits a padded system.
//! * [`padded`]   — converts a [`crate::transform::TransformResult`] into
//!   the padded-level representation the L1/L2 kernels consume (plus the
//!   RHS functional `b' = W b` for rewritten rows).
//! * [`backend`]  — the XLA-backed solver implementing solve / batched
//!   solve / residual over the registry executables.

pub mod backend;
pub mod padded;
pub mod registry;

pub use backend::XlaSolver;
pub use padded::PaddedSystem;
pub use registry::{ArtifactMeta, Registry};
