//! Artifact registry: manifest parsing, PJRT compilation, shape fitting.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::Error;
use crate::runtime::padded::PadShape;
use crate::util::json::Json;

/// One artifact's metadata row from manifest.json.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "level_step" | "solve" | "solve_batched" | "residual"
    pub entry: String,
    pub l: Option<usize>,
    pub r: usize,
    pub k: usize,
    pub n: usize,
    pub b: Option<usize>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta, Error> {
        let s = |k: &str| -> Result<String, Error> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| Error::Invalid(format!("manifest entry missing '{k}'")))
        };
        let u = |k: &str| v.get(k).and_then(Json::as_usize);
        Ok(ArtifactMeta {
            name: s("name")?,
            file: s("file")?,
            entry: s("entry")?,
            l: u("l"),
            r: u("r").ok_or_else(|| Error::Invalid("manifest entry missing 'r'".into()))?,
            k: u("k").ok_or_else(|| Error::Invalid("manifest entry missing 'k'".into()))?,
            n: u("n").ok_or_else(|| Error::Invalid("manifest entry missing 'n'".into()))?,
            b: u("b"),
        })
    }

    pub fn pad_shape(&self) -> PadShape {
        PadShape {
            l: self.l.unwrap_or(1),
            r: self.r,
            k: self.k,
            n: self.n,
        }
    }

    /// Does a system with requirements `req` fit this artifact?
    pub fn fits(&self, req: &PadShape) -> bool {
        self.l.unwrap_or(usize::MAX) >= req.l
            && self.r >= req.r
            && self.k >= req.k
            && self.n >= req.n
    }

    /// Padded-volume proxy used to pick the *smallest* fitting shape.
    pub fn volume(&self) -> usize {
        self.l.unwrap_or(1) * self.r * self.k * self.b.unwrap_or(1) + self.n
    }
}

/// Loaded + compiled artifacts, ready to execute.
pub struct Registry {
    pub client: xla::PjRtClient,
    pub metas: Vec<ArtifactMeta>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

impl Registry {
    /// Parse manifest.json only (no PJRT) — used by tests and tooling
    /// that just needs shape metadata.
    pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>, Error> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Io(format!("read manifest in {}: {e}", dir.display())))?;
        let v = Json::parse(&text).map_err(|e| Error::Invalid(e.to_string()))?;
        v.as_arr()
            .ok_or_else(|| Error::Invalid("manifest is not an array".into()))?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect()
    }

    /// Load every artifact in `dir` and compile it on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Registry, Error> {
        let metas = Self::read_manifest(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let mut executables = BTreeMap::new();
        for meta in &metas {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Io("non-utf8 path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", meta.name)))?;
            executables.insert(meta.name.clone(), exe);
        }
        Ok(Registry {
            client,
            metas,
            executables,
            dir: dir.to_path_buf(),
        })
    }

    pub fn get(&self, name: &str) -> Option<&xla::PjRtLoadedExecutable> {
        self.executables.get(name)
    }

    /// Smallest fitting artifact of a given entry kind.
    pub fn best_fit(&self, entry: &str, req: &PadShape) -> Option<&ArtifactMeta> {
        self.metas
            .iter()
            .filter(|m| m.entry == entry && m.fits(req))
            .min_by_key(|m| m.volume())
    }

    pub fn names(&self) -> Vec<&str> {
        self.metas.iter().map(|m| m.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(entry: &str, l: usize, r: usize, k: usize, n: usize) -> ArtifactMeta {
        ArtifactMeta {
            name: format!("{entry}_{l}_{r}_{k}_{n}"),
            file: String::new(),
            entry: entry.to_string(),
            l: Some(l),
            r,
            k,
            n,
            b: None,
        }
    }

    #[test]
    fn fits_logic() {
        let m = meta("solve", 64, 256, 4, 8192);
        assert!(m.fits(&PadShape { l: 10, r: 100, k: 4, n: 5000 }));
        assert!(!m.fits(&PadShape { l: 65, r: 100, k: 4, n: 5000 }));
        assert!(!m.fits(&PadShape { l: 10, r: 257, k: 4, n: 5000 }));
        assert!(!m.fits(&PadShape { l: 10, r: 100, k: 5, n: 5000 }));
        assert!(!m.fits(&PadShape { l: 10, r: 100, k: 4, n: 9000 }));
    }

    #[test]
    fn manifest_parses_real_artifacts() {
        // `make artifacts` must have produced a manifest in artifacts/.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let metas = Registry::read_manifest(&dir).unwrap();
        assert!(metas.len() >= 5);
        assert!(metas.iter().any(|m| m.entry == "solve"));
        assert!(metas.iter().any(|m| m.entry == "level_step"));
        assert!(metas.iter().any(|m| m.entry == "solve_batched"));
    }

    #[test]
    fn best_fit_prefers_smallest() {
        let metas = vec![
            meta("solve", 512, 8, 2, 8192),
            meta("solve", 64, 256, 4, 8192),
        ];
        let reg_like = |req: &PadShape| -> Option<String> {
            metas
                .iter()
                .filter(|m| m.entry == "solve" && m.fits(req))
                .min_by_key(|m| m.volume())
                .map(|m| m.name.clone())
        };
        // Thin chain fits the chain artifact (smaller volume).
        let thin = PadShape { l: 400, r: 4, k: 2, n: 4000 };
        assert_eq!(reg_like(&thin).unwrap(), "solve_512_8_2_8192");
        // Fat short system only fits the wide artifact.
        let fat = PadShape { l: 20, r: 200, k: 3, n: 4000 };
        assert_eq!(reg_like(&fat).unwrap(), "solve_64_256_4_8192");
        // Nothing fits.
        let huge = PadShape { l: 20, r: 200, k: 3, n: 50_000 };
        assert!(reg_like(&huge).is_none());
    }
}
