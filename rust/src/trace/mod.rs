//! Per-solve and per-registration phase tracing.
//!
//! The serving stack reports *what* it did through [`crate::coordinator::Metrics`];
//! this module records *where the time went*. Call sites push [`Span`]s —
//! a `(matrix, phase, duration)` triple — into a fixed-capacity ring
//! buffer owned by the coordinator service, which drains it into
//! per-matrix [`PhaseTotals`] after every message. Two levels only:
//!
//! * **off** (`trace_enabled = false`, the default): every record call is
//!   a single relaxed atomic load and an early return — no allocation,
//!   no lock, nothing retained.
//! * **on** (`trace_enabled = true`, forced by `sptrsv bench`): spans are
//!   buffered and folded into aggregates; a full ring folds the oldest
//!   span on push, so nothing is ever silently dropped.
//!
//! Phases cover the whole lifecycle the ISSUE's papers care about:
//! analyze passes (rewrite / coarsen / placement / renumeric, wall-clock
//! timers threaded through [`crate::analysis::Analysis`]), the batcher
//! queue wait (admission → dispatch), and execution (dispatch → done),
//! with the elastic executor's stall/lookahead counters attributed
//! per matrix alongside the time totals.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Default ring capacity; the service drains after every message, so the
/// ring only fills under sustained bursts (at which point the oldest
/// spans fold into the aggregates instead of being lost).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// A traced lifecycle phase. The first four are analyze-side passes
/// (mirroring [`crate::analysis::BuildCounters`]); `Wait` is the batcher
/// queue wait from admission to dispatch; `Execute` is dispatch to done
/// (including the pool rendezvous and the numeric solve); `Residual` is
/// the post-solve achieved-residual check toleranced requests pay on top
/// of the solve itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Rewrite,
    Coarsen,
    Placement,
    Renumeric,
    Execute,
    Wait,
    Residual,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Rewrite => "rewrite",
            Phase::Coarsen => "coarsen",
            Phase::Placement => "placement",
            Phase::Renumeric => "renumeric",
            Phase::Execute => "execute",
            Phase::Wait => "wait",
            Phase::Residual => "residual",
        }
    }
}

/// Wall-clock split of one analysis build/refresh, recorded where the
/// work happens (rewrite in `Analysis::build`, coarsen/placement in
/// `Schedule::build_timed`, renumeric in the refresh path). Kept outside
/// [`crate::sched::ScheduleStats`] on purpose: schedules are
/// deterministic and comparable, timings are neither.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseTimes {
    pub rewrite_us: u64,
    pub coarsen_us: u64,
    pub placement_us: u64,
    pub renumeric_us: u64,
}

impl PhaseTimes {
    pub fn is_zero(&self) -> bool {
        *self == PhaseTimes::default()
    }
}

/// One recorded span. Durations are measured at the call site (the
/// coordinator already holds the relevant `Instant`s), so the tracer
/// itself never reads a clock.
#[derive(Debug, Clone)]
pub struct Span {
    pub matrix: String,
    pub phase: Phase,
    pub dur: Duration,
}

/// Per-matrix aggregate the ring drains into: summed microseconds per
/// phase plus the elastic executor's counters for the same solves.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseTotals {
    pub rewrite_us: u64,
    pub coarsen_us: u64,
    pub placement_us: u64,
    pub renumeric_us: u64,
    pub execute_us: u64,
    pub wait_us: u64,
    /// time spent computing achieved residuals for toleranced solves
    pub residual_us: u64,
    /// spans folded into this aggregate
    pub spans: u64,
    /// elastic frontier stalls attributed to this matrix's solves
    pub elastic_waits: u64,
    /// elastic out-of-order (lookahead) block executions
    pub elastic_ooo: u64,
    /// elastic blocks executed via work stealing
    pub elastic_steals: u64,
}

impl PhaseTotals {
    /// Field count of the wire array ([`Self::to_array`]). Bumped from 10
    /// when the residual phase was added; a decoder seeing the wrong
    /// length degrades to no trace rather than misreading fields.
    pub const WIRE_LEN: usize = 11;

    /// Flatten into the fixed-order array the shard protocol ships:
    /// seven phase microsecond sums, the span count, then the three
    /// elastic counters.
    pub fn to_array(&self) -> [u64; Self::WIRE_LEN] {
        [
            self.rewrite_us,
            self.coarsen_us,
            self.placement_us,
            self.renumeric_us,
            self.execute_us,
            self.wait_us,
            self.residual_us,
            self.spans,
            self.elastic_waits,
            self.elastic_ooo,
            self.elastic_steals,
        ]
    }

    /// Inverse of [`Self::to_array`].
    pub fn from_array(a: [u64; Self::WIRE_LEN]) -> PhaseTotals {
        PhaseTotals {
            rewrite_us: a[0],
            coarsen_us: a[1],
            placement_us: a[2],
            renumeric_us: a[3],
            execute_us: a[4],
            wait_us: a[5],
            residual_us: a[6],
            spans: a[7],
            elastic_waits: a[8],
            elastic_ooo: a[9],
            elastic_steals: a[10],
        }
    }

    pub fn is_zero(&self) -> bool {
        *self == PhaseTotals::default()
    }

    /// Field-wise `self - o`, clamped at zero. Used to turn cumulative
    /// per-matrix totals polled from a shard into fold-once increments:
    /// a fresh worker generation restarts from zero, so a plain
    /// subtraction could underflow right after a respawn.
    pub fn saturating_sub(&self, o: &PhaseTotals) -> PhaseTotals {
        PhaseTotals {
            rewrite_us: self.rewrite_us.saturating_sub(o.rewrite_us),
            coarsen_us: self.coarsen_us.saturating_sub(o.coarsen_us),
            placement_us: self.placement_us.saturating_sub(o.placement_us),
            renumeric_us: self.renumeric_us.saturating_sub(o.renumeric_us),
            execute_us: self.execute_us.saturating_sub(o.execute_us),
            wait_us: self.wait_us.saturating_sub(o.wait_us),
            residual_us: self.residual_us.saturating_sub(o.residual_us),
            spans: self.spans.saturating_sub(o.spans),
            elastic_waits: self.elastic_waits.saturating_sub(o.elastic_waits),
            elastic_ooo: self.elastic_ooo.saturating_sub(o.elastic_ooo),
            elastic_steals: self.elastic_steals.saturating_sub(o.elastic_steals),
        }
    }

    fn add_span(&mut self, phase: Phase, dur: Duration) {
        let us = dur.as_micros() as u64;
        match phase {
            Phase::Rewrite => self.rewrite_us += us,
            Phase::Coarsen => self.coarsen_us += us,
            Phase::Placement => self.placement_us += us,
            Phase::Renumeric => self.renumeric_us += us,
            Phase::Execute => self.execute_us += us,
            Phase::Wait => self.wait_us += us,
            Phase::Residual => self.residual_us += us,
        }
        self.spans += 1;
    }

    /// Phase microseconds as `(phase, us)` pairs in breakdown order.
    pub fn phases_us(&self) -> [(Phase, u64); 7] {
        [
            (Phase::Rewrite, self.rewrite_us),
            (Phase::Coarsen, self.coarsen_us),
            (Phase::Placement, self.placement_us),
            (Phase::Renumeric, self.renumeric_us),
            (Phase::Execute, self.execute_us),
            (Phase::Wait, self.wait_us),
            (Phase::Residual, self.residual_us),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = self
            .phases_us()
            .iter()
            .map(|&(p, us)| (p.as_str(), Json::Num(us as f64)))
            .collect();
        pairs.push(("spans", Json::Num(self.spans as f64)));
        pairs.push(("elastic_waits", Json::Num(self.elastic_waits as f64)));
        pairs.push(("elastic_ooo", Json::Num(self.elastic_ooo as f64)));
        pairs.push(("elastic_steals", Json::Num(self.elastic_steals as f64)));
        Json::obj(pairs)
    }
}

impl std::ops::Add for PhaseTotals {
    type Output = PhaseTotals;
    fn add(self, o: PhaseTotals) -> PhaseTotals {
        PhaseTotals {
            rewrite_us: self.rewrite_us + o.rewrite_us,
            coarsen_us: self.coarsen_us + o.coarsen_us,
            placement_us: self.placement_us + o.placement_us,
            renumeric_us: self.renumeric_us + o.renumeric_us,
            execute_us: self.execute_us + o.execute_us,
            wait_us: self.wait_us + o.wait_us,
            residual_us: self.residual_us + o.residual_us,
            spans: self.spans + o.spans,
            elastic_waits: self.elastic_waits + o.elastic_waits,
            elastic_ooo: self.elastic_ooo + o.elastic_ooo,
            elastic_steals: self.elastic_steals + o.elastic_steals,
        }
    }
}

/// Drained view of the tracer: per-matrix totals plus their sum, as
/// handed out by `SolveHandle::trace_report`.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    pub matrices: Vec<(String, PhaseTotals)>,
}

impl TraceReport {
    /// Sum across matrices — the BENCH per-phase breakdown.
    pub fn totals(&self) -> PhaseTotals {
        self.matrices
            .iter()
            .fold(PhaseTotals::default(), |acc, (_, t)| acc + *t)
    }

    pub fn get(&self, matrix: &str) -> Option<&PhaseTotals> {
        self.matrices
            .iter()
            .find(|(id, _)| id == matrix)
            .map(|(_, t)| t)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("totals", self.totals().to_json()),
            (
                "matrices",
                Json::Obj(
                    self.matrices
                        .iter()
                        .map(|(id, t)| (id.clone(), t.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

struct Ring {
    buf: Vec<Span>,
    capacity: usize,
    aggregates: BTreeMap<String, PhaseTotals>,
}

impl Ring {
    fn fold(&mut self) {
        for span in self.buf.drain(..) {
            self.aggregates
                .entry(span.matrix)
                .or_default()
                .add_span(span.phase, span.dur);
        }
    }
}

/// The recorder. One per service; shared by reference with the dispatch
/// path. All record calls are no-ops (one relaxed load) while disabled.
pub struct Tracer {
    enabled: AtomicBool,
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new(enabled: bool, capacity: usize) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(enabled),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                capacity: capacity.max(1),
                aggregates: BTreeMap::new(),
            }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one span. When the ring is full the whole buffer folds into
    /// the aggregates first — bounded memory, nothing dropped.
    pub fn record(&self, matrix: &str, phase: Phase, dur: Duration) {
        if !self.enabled() {
            return;
        }
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        if ring.buf.len() >= ring.capacity {
            ring.fold();
        }
        ring.buf.push(Span {
            matrix: matrix.to_string(),
            phase,
            dur,
        });
    }

    /// Record the analyze-side wall-clock split in one call (zero
    /// entries are skipped, so a memo hit records nothing).
    pub fn record_phases(&self, matrix: &str, t: PhaseTimes) {
        if !self.enabled() || t.is_zero() {
            return;
        }
        for (phase, us) in [
            (Phase::Rewrite, t.rewrite_us),
            (Phase::Coarsen, t.coarsen_us),
            (Phase::Placement, t.placement_us),
            (Phase::Renumeric, t.renumeric_us),
        ] {
            if us > 0 {
                self.record(matrix, phase, Duration::from_micros(us));
            }
        }
    }

    /// Attribute an elastic execution's stall/lookahead/steal counter
    /// deltas to `matrix` (counts, not time — they ride the aggregates
    /// directly).
    pub fn record_elastic(&self, matrix: &str, waits: u64, ooo: u64, steals: u64) {
        if !self.enabled() || (waits == 0 && ooo == 0 && steals == 0) {
            return;
        }
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        let agg = ring.aggregates.entry(matrix.to_string()).or_default();
        agg.elastic_waits += waits;
        agg.elastic_ooo += ooo;
        agg.elastic_steals += steals;
    }

    /// Fold a whole pre-aggregated [`PhaseTotals`] delta into `matrix`'s
    /// aggregate. This is how spans measured in a shard worker's own
    /// tracer cross back into the coordinator's: the wire carries the
    /// totals, not the individual spans.
    pub fn fold_totals(&self, matrix: &str, delta: PhaseTotals) {
        if !self.enabled() || delta.is_zero() {
            return;
        }
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        let agg = ring.aggregates.entry(matrix.to_string()).or_default();
        *agg = *agg + delta;
    }

    /// Fold buffered spans into the aggregates. The service calls this
    /// after each message; push also folds on overflow.
    pub fn drain(&self) {
        if !self.enabled() {
            return;
        }
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).fold();
    }

    /// Drain and snapshot the per-matrix aggregates.
    pub fn report(&self) -> TraceReport {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        ring.fold();
        TraceReport {
            matrices: ring
                .aggregates
                .iter()
                .map(|(id, t)| (id.clone(), *t))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(false, 8);
        t.record("a", Phase::Execute, Duration::from_micros(10));
        t.record_elastic("a", 5, 2, 1);
        t.record_phases(
            "a",
            PhaseTimes {
                rewrite_us: 1,
                ..Default::default()
            },
        );
        assert!(t.report().matrices.is_empty());
    }

    #[test]
    fn spans_aggregate_per_phase_and_matrix() {
        let t = Tracer::new(true, 64);
        t.record("a", Phase::Wait, Duration::from_micros(5));
        t.record("a", Phase::Wait, Duration::from_micros(7));
        t.record("a", Phase::Execute, Duration::from_micros(100));
        t.record("b", Phase::Execute, Duration::from_micros(40));
        t.record_elastic("b", 3, 1, 2);
        let r = t.report();
        let a = r.get("a").unwrap();
        assert_eq!(a.wait_us, 12);
        assert_eq!(a.execute_us, 100);
        assert_eq!(a.spans, 3);
        assert_eq!(a.elastic_waits, 0);
        let b = r.get("b").unwrap();
        assert_eq!(b.execute_us, 40);
        assert_eq!((b.elastic_waits, b.elastic_ooo, b.elastic_steals), (3, 1, 2));
        // The sum covers both matrices.
        assert_eq!(r.totals().execute_us, 140);
        assert_eq!(r.totals().spans, 4);
    }

    #[test]
    fn full_ring_folds_instead_of_dropping() {
        let t = Tracer::new(true, 4);
        for i in 0..37 {
            t.record("m", Phase::Execute, Duration::from_micros(i));
        }
        let r = t.report();
        let m = r.get("m").unwrap();
        assert_eq!(m.spans, 37, "overflow must fold, not drop");
        assert_eq!(m.execute_us, (0..37).sum::<u64>());
    }

    #[test]
    fn record_phases_skips_zero_entries() {
        let t = Tracer::new(true, 16);
        t.record_phases(
            "m",
            PhaseTimes {
                rewrite_us: 3,
                coarsen_us: 0,
                placement_us: 9,
                renumeric_us: 0,
            },
        );
        let r = t.report();
        let m = r.get("m").unwrap();
        assert_eq!(m.rewrite_us, 3);
        assert_eq!(m.placement_us, 9);
        assert_eq!(m.spans, 2, "zero phases must not add empty spans");
        // A memo hit (all zeros) records nothing at all.
        t.record_phases("memo", PhaseTimes::default());
        assert!(t.report().get("memo").is_none());
    }

    #[test]
    fn concurrent_solves_do_not_cross_matrices() {
        // The satellite regression: spans recorded from many threads for
        // different matrices must land in their own aggregates with
        // nothing lost or misattributed, even while the ring overflows.
        let t = Arc::new(Tracer::new(true, 8));
        let threads: Vec<_> = (0..4)
            .map(|w| {
                let t = Arc::clone(&t);
                thread::spawn(move || {
                    let id = format!("m{w}");
                    for _ in 0..200 {
                        t.record(&id, Phase::Execute, Duration::from_micros(w + 1));
                        t.record(&id, Phase::Wait, Duration::from_micros(1));
                    }
                    t.record_elastic(&id, w, 2 * w, 3 * w);
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let r = t.report();
        assert_eq!(r.matrices.len(), 4);
        for w in 0..4u64 {
            let m = r.get(&format!("m{w}")).unwrap();
            assert_eq!(m.execute_us, 200 * (w + 1));
            assert_eq!(m.wait_us, 200);
            assert_eq!(m.spans, 400);
            assert_eq!(
                (m.elastic_waits, m.elastic_ooo, m.elastic_steals),
                (w, 2 * w, 3 * w)
            );
        }
        assert_eq!(r.totals().spans, 1600);
    }

    #[test]
    fn wire_array_roundtrips_and_fold_totals_accumulates() {
        let t = Tracer::new(true, 16);
        let delta = PhaseTotals {
            execute_us: 120,
            wait_us: 7,
            spans: 2,
            elastic_waits: 3,
            elastic_steals: 1,
            ..Default::default()
        };
        assert_eq!(PhaseTotals::from_array(delta.to_array()), delta);
        t.record("m", Phase::Execute, Duration::from_micros(10));
        t.fold_totals("m", delta);
        t.fold_totals("m", delta);
        // A zero delta is a no-op, not an empty aggregate entry.
        t.fold_totals("ghost", PhaseTotals::default());
        let r = t.report();
        let m = r.get("m").unwrap();
        assert_eq!(m.execute_us, 250);
        assert_eq!(m.spans, 5);
        assert_eq!(m.elastic_waits, 6);
        assert!(r.get("ghost").is_none());
        // saturating_sub clamps per field (a respawned worker restarts
        // its cumulative totals from zero).
        let older = PhaseTotals {
            execute_us: 500,
            spans: 9,
            ..Default::default()
        };
        let inc = delta.saturating_sub(&older);
        assert_eq!(inc.execute_us, 0);
        assert_eq!(inc.spans, 0);
        assert_eq!(inc.elastic_waits, 3);
        // Disabled tracer ignores folds entirely.
        let off = Tracer::new(false, 16);
        off.fold_totals("m", delta);
        assert!(off.report().matrices.is_empty());
    }

    #[test]
    fn residual_phase_aggregates_and_rides_the_wire() {
        let t = Tracer::new(true, 16);
        t.record("m", Phase::Residual, Duration::from_micros(9));
        t.record("m", Phase::Residual, Duration::from_micros(4));
        t.record("m", Phase::Execute, Duration::from_micros(50));
        let r = t.report();
        let m = r.get("m").unwrap();
        assert_eq!(m.residual_us, 13);
        assert_eq!(m.spans, 3);
        // The wire array carries the new field and round-trips.
        assert_eq!(PhaseTotals::from_array(m.to_array()), *m);
        assert_eq!(m.to_array().len(), PhaseTotals::WIRE_LEN);
        // JSON report exposes it under the phase name.
        let j = r.to_json();
        assert_eq!(
            j.get("totals").unwrap().get("residual").unwrap().as_f64(),
            Some(13.0)
        );
    }

    #[test]
    fn report_json_shape() {
        let t = Tracer::new(true, 16);
        t.record("m", Phase::Coarsen, Duration::from_micros(11));
        let j = t.report().to_json();
        assert_eq!(
            j.get("totals").unwrap().get("coarsen").unwrap().as_f64(),
            Some(11.0)
        );
        let m = j.get("matrices").unwrap().get("m").unwrap();
        assert_eq!(m.get("spans").unwrap().as_f64(), Some(1.0));
        // Round-trips through the writer/parser.
        let s = j.to_string();
        assert_eq!(crate::util::json::Json::parse(&s).unwrap(), j);
    }
}
