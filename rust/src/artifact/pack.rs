//! Byte-level codecs for artifact section payloads: LEB128 varints,
//! delta packing for monotone offset arrays, and an alignment-tracking
//! writer/cursor pair so raw `u32` arrays land on addresses the
//! zero-copy views can use.

use std::borrow::Cow;

use super::ArtifactError;

/// Append `v` as an LEB128 varint (7 bits per byte, high bit = more).
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Append a monotone non-decreasing sequence as `count` + first value +
/// successive deltas, all varints. The classic trick for CSR-style
/// offset arrays: deltas are row lengths, almost always one byte.
pub fn put_monotone(out: &mut Vec<u8>, vals: &[u64]) -> Result<(), ArtifactError> {
    put_varint(out, vals.len() as u64);
    let mut prev = 0u64;
    for (i, &v) in vals.iter().enumerate() {
        if i == 0 {
            put_varint(out, v);
        } else {
            let d = v.checked_sub(prev).ok_or_else(|| {
                ArtifactError::Malformed(format!("monotone sequence decreases at index {i}"))
            })?;
            put_varint(out, d);
        }
        prev = v;
    }
    Ok(())
}

/// Pad `out` with zero bytes until its length is a multiple of `align`.
pub fn pad_to(out: &mut Vec<u8>, align: usize) {
    while out.len() % align != 0 {
        out.push(0);
    }
}

/// Append a `u32` slice as raw little-endian words, 4-byte aligned
/// (count first, as a varint, then padding, then the words).
pub fn put_u32s(out: &mut Vec<u8>, vals: &[u32]) {
    put_varint(out, vals.len() as u64);
    pad_to(out, 4);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Forward-only reader over a section payload. Positions are relative to
/// the payload start; payloads themselves sit on 8-byte file offsets and
/// the mapping base is 8-byte aligned, so payload-relative alignment is
/// address alignment.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn short(&self, what: &str) -> ArtifactError {
        ArtifactError::Malformed(format!(
            "payload ends inside {what} (offset {} of {})",
            self.pos,
            self.buf.len()
        ))
    }

    pub fn varint(&mut self) -> Result<u64, ArtifactError> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let &b = self
                .buf
                .get(self.pos)
                .ok_or_else(|| self.short("varint"))?;
            self.pos += 1;
            if shift >= 64 {
                return Err(ArtifactError::Malformed("varint overflows u64".into()));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Read `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| self.short("byte run"))?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u64(&mut self) -> Result<u64, ArtifactError> {
        let end = self.pos + 8;
        let bytes = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| self.short("u64"))?;
        self.pos = end;
        Ok(u64::from_le_bytes(bytes.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, ArtifactError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Decode a [`put_monotone`] sequence.
    pub fn monotone(&mut self) -> Result<Vec<u64>, ArtifactError> {
        let n = self.varint()? as usize;
        if n > self.remaining().saturating_mul(8) + 1 {
            // A delta stream spends at least one byte per element; a
            // count beyond that is corruption, not data.
            return Err(ArtifactError::Malformed(format!(
                "monotone count {n} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(n);
        let mut acc = 0u64;
        for i in 0..n {
            let d = self.varint()?;
            acc = if i == 0 {
                d
            } else {
                acc.checked_add(d).ok_or_else(|| {
                    ArtifactError::Malformed("monotone sequence overflows u64".into())
                })?
            };
            out.push(acc);
        }
        Ok(out)
    }

    /// Decode a [`put_u32s`] array. Zero-copy on little-endian targets
    /// (the words are viewed in place); a copying decode elsewhere.
    pub fn u32s(&mut self) -> Result<Cow<'a, [u32]>, ArtifactError> {
        let n = self.varint()? as usize;
        while self.pos % 4 != 0 {
            if self.pos >= self.buf.len() {
                return Err(self.short("u32 padding"));
            }
            self.pos += 1;
        }
        let bytes_len = n
            .checked_mul(4)
            .ok_or_else(|| ArtifactError::Malformed("u32 array length overflows".into()))?;
        let end = self
            .pos
            .checked_add(bytes_len)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| self.short("u32 array"))?;
        let bytes = &self.buf[self.pos..end];
        self.pos = end;
        #[cfg(target_endian = "little")]
        {
            debug_assert_eq!(bytes.as_ptr() as usize % 4, 0, "u32 view misaligned");
            if bytes.as_ptr() as usize % 4 == 0 {
                // SAFETY: the region is in bounds, 4-byte aligned (just
                // checked) and u32 has no invalid bit patterns.
                let words =
                    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u32, n) };
                return Ok(Cow::Borrowed(words));
            }
        }
        Ok(Cow::Owned(
            bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        ))
    }
}

/// Fixed-width u64 append (header fields, float bits).
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_boundaries() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_varint(&mut buf, v);
        }
        let mut cur = Cursor::new(&buf);
        for &v in &vals {
            assert_eq!(cur.varint().unwrap(), v);
        }
        assert_eq!(cur.remaining(), 0);
    }

    #[test]
    fn monotone_roundtrip_and_rejects_decrease() {
        let vals: Vec<u64> = vec![0, 0, 3, 7, 7, 100, 1_000_000];
        let mut buf = Vec::new();
        put_monotone(&mut buf, &vals).unwrap();
        // Delta coding keeps this tiny: 7 entries in well under 7*8 bytes.
        assert!(buf.len() < 16, "monotone encoding too large: {}", buf.len());
        let mut cur = Cursor::new(&buf);
        assert_eq!(cur.monotone().unwrap(), vals);

        let mut bad = Vec::new();
        assert!(put_monotone(&mut bad, &[5, 3]).is_err());
    }

    #[test]
    fn u32s_roundtrip_at_odd_start() {
        let vals: Vec<u32> = (0..37).map(|i| i * 17 + 3).collect();
        let mut buf = Vec::new();
        put_varint(&mut buf, 9); // leave the cursor at an odd offset
        put_u32s(&mut buf, &vals);
        let mut cur = Cursor::new(&buf);
        assert_eq!(cur.varint().unwrap(), 9);
        assert_eq!(cur.u32s().unwrap().as_ref(), &vals[..]);
        assert_eq!(cur.remaining(), 0);
    }

    #[test]
    fn truncated_streams_error_not_panic() {
        let mut buf = Vec::new();
        put_u32s(&mut buf, &[1, 2, 3, 4]);
        for cut in 0..buf.len() {
            let mut cur = Cursor::new(&buf[..cut]);
            let r = cur.u32s();
            assert!(r.is_err() || r.unwrap().len() < 4);
        }
        let mut cur = Cursor::new(&[0x80, 0x80]);
        assert!(cur.varint().is_err(), "unterminated varint");
    }
}
