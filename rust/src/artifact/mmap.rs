//! Getting artifact bytes into the address space: `mmap` on unix, an
//! owned read everywhere else. Both arms hand out an 8-byte-aligned base
//! address — page alignment from the kernel, or a `u64`-backed buffer
//! for the owned copy — which is what lets the section cursors view raw
//! `u32` arrays in place instead of decoding them.
//!
//! The crate carries no libc dependency, so the two syscall wrappers are
//! declared directly; they are the stable POSIX ABI.

use std::ops::Deref;
use std::path::Path;

use super::ArtifactError;

#[cfg(unix)]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A read-only byte region backed by a file mapping or an owned,
/// 8-byte-aligned buffer. Derefs to `&[u8]`.
pub enum Mapped {
    #[cfg(unix)]
    Mmap {
        ptr: *mut core::ffi::c_void,
        len: usize,
    },
    Owned {
        /// backing store; `u64` words so the base address is 8-aligned
        buf: Vec<u64>,
        len: usize,
    },
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE and never mutated after
// construction; sharing immutable bytes across threads is sound.
unsafe impl Send for Mapped {}
unsafe impl Sync for Mapped {}

impl Mapped {
    /// Copy `bytes` into an owned, 8-byte-aligned buffer.
    pub fn from_bytes(bytes: &[u8]) -> Mapped {
        let words = bytes.len().div_ceil(8);
        let mut buf = vec![0u64; words];
        if !bytes.is_empty() {
            // SAFETY: buf holds words*8 >= bytes.len() writable bytes and
            // the two allocations cannot overlap.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    buf.as_mut_ptr() as *mut u8,
                    bytes.len(),
                );
            }
        }
        Mapped::Owned {
            buf,
            len: bytes.len(),
        }
    }

    /// Map `path` read-only (unix), or read it into an aligned buffer.
    pub fn open(path: &Path) -> Result<Mapped, ArtifactError> {
        #[cfg(unix)]
        {
            match Self::map_unix(path) {
                Ok(m) => return Ok(m),
                Err(MapFail::Io(e)) => return Err(e),
                // Mapping refused (weird filesystem): fall through to read.
                Err(MapFail::Unsupported) => {}
            }
        }
        let bytes = std::fs::read(path)
            .map_err(|e| ArtifactError::Io(format!("read {}: {e}", path.display())))?;
        Ok(Mapped::from_bytes(&bytes))
    }

    #[cfg(unix)]
    fn map_unix(path: &Path) -> Result<Mapped, MapFail> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)
            .map_err(|e| MapFail::Io(ArtifactError::Io(format!("open {}: {e}", path.display()))))?;
        let len = f
            .metadata()
            .map_err(|e| MapFail::Io(ArtifactError::Io(format!("stat {}: {e}", path.display()))))?
            .len() as usize;
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty file has nothing to map.
            return Ok(Mapped::from_bytes(&[]));
        }
        // SAFETY: fd is open for the duration of the call; the kernel
        // validates every argument and returns MAP_FAILED on error.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(MapFail::Unsupported);
        }
        Ok(Mapped::Mmap { ptr, len })
    }
}

#[cfg(unix)]
enum MapFail {
    Io(ArtifactError),
    Unsupported,
}

impl Deref for Mapped {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            // SAFETY: the mapping covers exactly `len` readable bytes and
            // lives until Drop.
            Mapped::Mmap { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr as *const u8, *len)
            },
            Mapped::Owned { buf, len } => {
                // SAFETY: buf owns >= len bytes.
                unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len) }
            }
        }
    }
}

impl Drop for Mapped {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Mapped::Mmap { ptr, len } = self {
            // SAFETY: exactly the region mmap returned.
            unsafe {
                sys::munmap(*ptr, *len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_buffer_is_aligned_and_exact() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 1000] {
            let bytes: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            let m = Mapped::from_bytes(&bytes);
            assert_eq!(&*m, &bytes[..]);
            if n > 0 {
                assert_eq!(m.as_ptr() as usize % 8, 0, "base not 8-aligned");
            }
        }
    }

    #[test]
    fn open_maps_file_contents() {
        let path = std::env::temp_dir().join(format!("sptrsv_mmap_{}.bin", std::process::id()));
        let bytes: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&path, &bytes).unwrap();
        let m = Mapped::open(&path).unwrap();
        assert_eq!(&*m, &bytes[..]);
        assert_eq!(m.as_ptr() as usize % 8, 0);
        drop(m);
        // Empty files fall back to an owned empty buffer.
        std::fs::write(&path, b"").unwrap();
        let m = Mapped::open(&path).unwrap();
        assert!(m.is_empty());
        std::fs::remove_file(&path).ok();
        assert!(Mapped::open(Path::new("/nonexistent/sptrsv.spa")).is_err());
    }
}
